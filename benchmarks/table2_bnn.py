"""Paper Table 2 analogue: BNN CIFAR-10 inference under the three
kernel modes (§4.3/§4.4).

2019 rows -> our rows (CPU/XLA, same-graph comparisons):

  PyTorch       -> XLA float conv path (vendor-optimized analogue)
  Control Group -> float32 im2col+GEMM forward graph (Figure 2), jit'd
  Our Kernel    -> packed 1-bit weights, unpack+dot packed-storage
                   engine ("xla", SPMD-safe) + the true xnor-popcount
                   Pallas kernel validated in interpret mode

The paper's wall-clock *speedup* claim is hardware-specific (x86
POPCNT / CUDA __popc); the invariant we reproduce on any backend is
(a) bit-exactness of the xnor-popcount path against the ±1 float GEMM
and (b) the 32x weight compression; the TPU-side speed story is the
roofline analysis (EXPERIMENTS.md §Roofline). Wall times below are
reported for completeness.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bnn_cifar import (
    CONTROL_GROUP,
    PAPER_KERNEL,
    SIMULATION,
    XLA_PACKED,
)
from repro.core.binarize import QuantMode
from repro.core.bnn import BNNConfig, bnn_apply, init_bnn_params, pack_bnn_params
from repro.data.pipeline import DataConfig, synthetic_cifar_batches


def _bytes_of(tree) -> int:
    return sum(
        np.asarray(x).nbytes for x in jax.tree.leaves(tree)
        if hasattr(x, "nbytes") or isinstance(x, (np.ndarray, jnp.ndarray))
    )


def run(batch: int = 64, num_batches: int = 4, verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    params = init_bnn_params(key)
    packed = pack_bnn_params(params)

    data = synthetic_cifar_batches(DataConfig(global_batch=batch))
    batches = [next(data)["images"] for _ in range(num_batches)]

    rows = {}
    for name, cfg, p in [
        ("float_xla (PyTorch row)", CONTROL_GROUP, params),
        ("fake_quant (simulation)", SIMULATION, params),
        ("packed_xla (Our Kernel)", XLA_PACKED, packed),
    ]:
        fn = jax.jit(lambda pr, x, c=cfg: bnn_apply(pr, x, c))
        fn(p, batches[0]).block_until_ready()  # compile
        t0 = time.time()
        for x in batches:
            out = fn(p, x)
        out.block_until_ready()
        dt = time.time() - t0
        rows[name] = {
            "seconds": dt,
            "imgs_per_s": batch * num_batches / dt,
            "weight_bytes": _bytes_of(
                [q for q in jax.tree.leaves(p)]
            ),
        }
        if verbose:
            print(f"{name:28s} {dt:7.3f}s  {rows[name]['imgs_per_s']:8.1f} img/s"
                  f"  weights {rows[name]['weight_bytes']/1e6:7.2f} MB")

    # bit-exactness of the paper's xnor kernel vs the ±1 float GEMM
    from repro.core import bitops
    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    w = jnp.asarray(np.sign(rng.normal(size=(64, 256))) + 0.0)
    x = jnp.asarray(np.sign(rng.normal(size=(256, 32))) + 0.0)
    wp = bitops.pack_bits(w, axis=1)
    xp = bitops.pack_bits(x, axis=0)
    ref = (w @ x).astype(np.int32)
    got = kops.xnor_gemm(wp, xp, 256)
    exact = bool(jnp.all(got == ref))
    rows["xnor_bit_exact"] = exact
    compression = (
        rows["float_xla (PyTorch row)"]["weight_bytes"]
        / rows["packed_xla (Our Kernel)"]["weight_bytes"]
    )
    rows["weight_compression_x"] = compression
    if verbose:
        print(f"xnor-popcount bit-exact vs ±1 GEMM: {exact}")
        print(f"weight compression: {compression:.1f}x")
    return rows


if __name__ == "__main__":
    run()
