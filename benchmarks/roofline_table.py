"""Render the 40-cell roofline table from experiments/dryrun JSONs.

Used both as a benchmark report and to generate EXPERIMENTS.md sections.
"""

from __future__ import annotations

import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR, tag: str = "") -> list[dict]:
    cells = []
    if not os.path.isdir(dryrun_dir):
        return cells
    for name in sorted(os.listdir(dryrun_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, name)) as f:
            cell = json.load(f)
        # each cell records the sweep tag it was produced under; "" is
        # the baseline sweep, "opt" the final optimized one, hc* are
        # hillclimb iterations
        if cell.get("tag", "") == tag:
            cells.append(cell)
    return cells


def fmt_row(c: dict) -> str:
    base = f"| {c['arch']} | {c['shape']} | {c['mesh']} "
    if c["status"] == "skipped":
        return base + f"| skipped | — | — | — | — | — | {c['reason'][:60]} |"
    if c["status"] == "error":
        return base + f"| ERROR | — | — | — | — | — | {c['error'][:60]} |"
    r = c["roofline"]
    return base + (
        f"| ok | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
        f"| {r['collective_s']:.3f} | {r['bottleneck']} "
        f"| {r['mfu']:.3f} | useful={r['useful_flops_fraction']:.2f} |"
    )


def render(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | status | compute_s | memory_s | "
           "collective_s | bottleneck | roofline MFU | notes |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr] + [fmt_row(c) for c in cells]
    return "\n".join(lines)


def run(verbose: bool = True) -> str:
    cells = load_cells()
    table = render(cells)
    ok = sum(c["status"] == "ok" for c in cells)
    skip = sum(c["status"] == "skipped" for c in cells)
    err = sum(c["status"] == "error" for c in cells)
    summary = f"\n{ok} ok / {skip} skipped / {err} errors over {len(cells)} cells"
    if verbose:
        print(table)
        print(summary)
    return table + summary


if __name__ == "__main__":
    run()
