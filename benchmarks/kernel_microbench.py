"""Kernel-level benchmark: arithmetic intensity + HBM traffic of the
three binary-GEMM engines (paper §3.2 adapted to TPU, DESIGN.md §2).

No TPU here, so the numbers that matter are *structural*: bytes moved
per output element and per-engine FLOP/byte, computed from shapes —
plus interpret-mode wall times at validation scale for completeness.

``--tile-sweep`` (DESIGN.md §6) additionally measures the
broadcast-vs-loop accumulator wall clock at the legacy default tiles,
sweeps the autotuner's candidate block grid, and writes
``BENCH_autotune.json`` with the per-step VMEM model (the >=5x
reduction claim of ISSUE 3 is recorded there).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.kernels import autotune
from repro.kernels import ops as kops

from benchmarks._util import bench_path, write_bench

BENCH_AUTOTUNE_PATH = bench_path("autotune")


def _ceil_div(a: int, b: int) -> int:
    return -(a // -b)


def traffic_model(m: int, k: int, n: int) -> dict:
    """Bytes/HBM per GEMM for each engine (weights resident in HBM).

    Packed word counts use CEILING division: k % 32 != 0 still moves
    ceil(k/32) words per row (the pad bits ride along in the last word).
    """
    f32 = 4
    kw = _ceil_div(k, 32)  # packed words per K row, incl. partial word
    mw = _ceil_div(m, 32)  # packed words per output column (fused out)
    rows = {
        # float GEMM: w[m,k] f32 + x[k,n] f32 + out f32
        "float_gemm": (m * k + k * n + m * n) * f32,
        # paper xnor: packed w [m,kw] i32 + packed x [kw,n] i32 + out i32
        "xnor_packed": (m * kw + kw * n) * 4 + m * n * 4,
        # unpack-MXU: packed w + bf16 x + f32 out
        "unpack_mxu": m * kw * 4 + k * n * 2 + m * n * 4,
        # fused chain layer: packed w + packed x in, PACKED out — the
        # [m, n] float/int32 activation never reaches HBM (DESIGN.md §4)
        "fused_chain": (m * kw + kw * n) * 4 + mw * n * 4,
    }
    flops = 2 * m * k * n
    return {
        name: {"bytes": b, "flops_per_byte": flops / b}
        for name, b in rows.items()
    }


def conv_traffic_model(
    n: int, h: int, w: int, c: int, d: int,
    kh: int = 3, kw: int = 3, stride: int = 1, pad: int = 1,
) -> dict:
    """Per-conv-layer HBM bytes: im2col fused chain vs direct kernel.

    Both paths read the channel-packed map and the packed filters and
    write the packed output. The im2col path ADDITIONALLY writes the
    packed patch matrix ``[N*OH*OW, kH*kW*ceil(C/32)]`` to HBM and reads
    it back for the GEMM — a ~kH*kW/stride^2 blow-up over the map it was
    gathered from. The direct kernel (DESIGN.md §5) gathers windows from
    the VMEM-resident map, so that term vanishes.
    """
    cw = _ceil_div(c, 32)
    dw = _ceil_div(d, 32)
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    map_bytes = n * h * w * cw * 4
    patch_bytes = n * oh * ow * kh * kw * cw * 4
    w_bytes = d * kh * kw * cw * 4
    out_bytes = n * oh * ow * dw * 4
    im2col = map_bytes + 2 * patch_bytes + w_bytes + out_bytes
    direct = map_bytes + w_bytes + out_bytes
    return {
        "shape": {"n": n, "h": h, "w": w, "c": c, "d": d,
                  "kh": kh, "kw": kw, "stride": stride, "pad": pad},
        "map_bytes": map_bytes,
        "patch_matrix_bytes": patch_bytes,
        "weight_bytes": w_bytes,
        "out_bytes": out_bytes,
        "im2col_fused_bytes": im2col,
        "direct_bytes": direct,
        "bytes_ratio": im2col / direct,
    }


def direct_conv_chain_traffic(batch: int = 64) -> dict:
    """conv_traffic_model over every interior binary conv of the CIFAR
    BNN (first conv keeps its float boundary and is excluded), spatial
    sizes tracked through the maxpools."""
    from repro.core.bnn import CONV_CHANNELS, POOL_AFTER

    out = {}
    hw = 32
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        if i > 0:
            out[f"conv{i}"] = conv_traffic_model(batch, hw, hw, cin, cout)
        if i in POOL_AFTER:
            hw //= 2
    tot_i = sum(r["im2col_fused_bytes"] for r in out.values())
    tot_d = sum(r["direct_bytes"] for r in out.values())
    out["total"] = {
        "im2col_fused_bytes": tot_i,
        "direct_bytes": tot_d,
        "bytes_ratio": tot_i / tot_d,
    }
    return out


# The CIFAR BNN's binary conv/FC chain: (M=out_channels, K, N=pixels)
# per interior binary layer at batch B, derived from the model's own
# architecture constants so this never drifts from the network. First
# conv and last FC keep float boundaries and are excluded.
def _bnn_binary_chain(batch: int):
    from repro.core.bnn import CONV_CHANNELS, FC_SIZES, POOL_AFTER

    shapes = []
    hw = 32
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        if i > 0:  # first conv: float boundary
            shapes.append((f"conv{i}", cout, 9 * cin, batch * hw * hw))
        if i in POOL_AFTER:
            hw //= 2
    for j, (fin, fout) in enumerate(FC_SIZES[:-1]):  # last FC: float out
        shapes.append((f"fc{j}", fout, fin, batch))
    return shapes


def fused_chain_traffic(batch: int = 64) -> dict:
    """Inter-layer HBM bytes + kernel launches, unfused vs fused, for
    every interior binary layer of the CIFAR BNN.

    Unfused boundary (per layer), conservatively modelled: one float
    [M, N] activation write (GEMM out) + one read (by pack_rows), plus
    the packed-word write + read — BN/clip are assumed XLA-fused into
    the producer/consumer, so their extra float passes are NOT counted
    (counting them would only raise the unfused side, ~49x vs ~33x).
    Fused boundary: the epilogue writes packed words; the next layer
    reads them. Nothing else exists.
    """
    out = {}
    for name, m, k, n in _bnn_binary_chain(batch):
        mw = _ceil_div(m, 32)
        f32_act = m * n * 4
        packed_act = mw * n * 4
        unfused = 2 * f32_act + 2 * packed_act  # write+read float, write+read packed
        fused = 2 * packed_act                  # write+read packed only
        out[name] = {
            "m,k,n": (m, k, n),
            "unfused_bytes": unfused,
            "fused_bytes": fused,
            "bytes_ratio": unfused / fused,
            "launches_per_layer": {"unfused": 2, "fused": 1},  # pack+gemm vs fused
        }
    tot_u = sum(r["unfused_bytes"] for r in out.values())
    tot_f = sum(r["fused_bytes"] for r in out.values())
    out["total"] = {
        "unfused_bytes": tot_u,
        "fused_bytes": tot_f,
        "bytes_ratio": tot_u / tot_f,
    }
    return out


def megakernel_stage_traffic(batch: int = 64) -> dict:
    """Inter-layer HBM bytes + launches/forward: per-layer fused chain
    vs the stage megakernel (DESIGN.md §8). Shape-derived.

    The fused chain writes+reads one packed activation tensor per
    interior layer boundary (7 of them: conv1..conv5, fc0, fc1). The
    megakernel keeps every boundary INSIDE a stage in VMEM; HBM sees
    only the three pooled stage-output maps (conv stages) — the FC
    trunk's boundaries (fc0->fc1->fc2) all live in the launch. Pooled
    maps are 4x smaller than the conv outputs the per-layer chain
    round-trips, so the win compounds: fewer boundaries AND smaller
    tensors.
    """
    from repro.core.bnn import CONV_CHANNELS, CONV_STAGES, FC_SIZES, POOL_AFTER

    chain = fused_chain_traffic(batch)
    n_interior = (len(CONV_CHANNELS) - 1) + (len(FC_SIZES) - 1)
    stages = {}
    hw = 32
    mega_bytes = 0
    for si, stage in enumerate(CONV_STAGES):
        for i in stage:
            if i in POOL_AFTER:
                hw //= 2
        cout = CONV_CHANNELS[stage[-1]][1]
        words = batch * hw * hw * _ceil_div(cout, 32)
        b = 2 * words * 4  # stage-output map: one write + one read
        in_stage = [f"conv{i}" for i in stage]
        stages[f"stage{si + 1}"] = {
            "convs": in_stage,
            "boundary_bytes": b,
            "chain_bytes": sum(chain[c]["fused_bytes"] for c in in_stage),
        }
        mega_bytes += b
    fc_chain = sum(
        chain[f"fc{j}"]["fused_bytes"] for j in range(len(FC_SIZES) - 1)
    )
    stages["fc_trunk"] = {
        "convs": [f"fc{j}" for j in range(len(FC_SIZES) - 1)],
        "boundary_bytes": 0,  # fc0->fc1->fc2 all inside the launch
        "chain_bytes": fc_chain,
    }
    total_chain = chain["total"]["fused_bytes"]
    return {
        "batch": batch,
        "per_stage": stages,
        "total": {
            "fused_chain_bytes": total_chain,
            "megakernel_bytes": mega_bytes,
            "bytes_ratio": total_chain / mega_bytes,
        },
        "launches_per_forward": {
            "unfused_packed": 2 * n_interior,      # pack + gemm per layer
            "fused_chain": n_interior + 1,          # 1/interior + final head
            "megakernel": len(CONV_STAGES) + 1,     # 1/stage + FC trunk
        },
    }


def run(verbose: bool = True) -> dict:
    shapes = [(256, 1024, 256), (512, 4096, 512), (1024, 8192, 128)]
    out = {}
    for m, k, n in shapes:
        tm = traffic_model(m, k, n)
        out[f"{m}x{k}x{n}"] = tm
        if verbose:
            print(f"GEMM {m}x{k}x{n}:")
            for name, row in tm.items():
                print(f"  {name:12s} {row['bytes']/1e6:8.2f} MB "
                      f"{row['flops_per_byte']:8.1f} FLOP/byte")
            xr = tm['float_gemm']['bytes'] / tm['xnor_packed']['bytes']
            print(f"  -> xnor moves {xr:.1f}x fewer bytes (paper's win on TPU)")

    chain = fused_chain_traffic()
    out["fused_chain"] = chain
    conv_chain = direct_conv_chain_traffic()
    out["direct_conv_chain"] = conv_chain
    if verbose:
        print("fused packed chain (CIFAR BNN, batch 64) — boundary bytes:")
        for name, row in chain.items():
            if name == "total":
                continue
            print(f"  {name:6s} unfused {row['unfused_bytes']/1e6:8.2f} MB "
                  f"fused {row['fused_bytes']/1e6:7.2f} MB "
                  f"({row['bytes_ratio']:.1f}x, 1 fewer launch)")
        print(f"  total  {chain['total']['unfused_bytes']/1e6:8.2f} MB -> "
              f"{chain['total']['fused_bytes']/1e6:.2f} MB "
              f"({chain['total']['bytes_ratio']:.1f}x fewer inter-layer bytes)")
        print("direct vs im2col conv (CIFAR BNN, batch 64) — per-layer "
              "HBM bytes:")
        for name, row in conv_chain.items():
            if name == "total":
                continue
            print(f"  {name:6s} im2col {row['im2col_fused_bytes']/1e6:8.2f} MB "
                  f"direct {row['direct_bytes']/1e6:7.2f} MB "
                  f"({row['bytes_ratio']:.1f}x — patch matrix "
                  f"{row['patch_matrix_bytes']/1e6:.2f} MB skipped)")
        t = conv_chain["total"]
        print(f"  total  {t['im2col_fused_bytes']/1e6:8.2f} MB -> "
              f"{t['direct_bytes']/1e6:.2f} MB "
              f"({t['bytes_ratio']:.1f}x fewer conv-layer bytes)")

    # interpret-mode correctness-scale timing (NOT a TPU perf claim)
    rng = np.random.default_rng(0)
    m, k, n = 128, 512, 128
    w = jnp.asarray(np.sign(rng.normal(size=(m, k))) + 0.0)
    x = jnp.asarray(np.sign(rng.normal(size=(k, n))) + 0.0)
    wp = bitops.pack_bits(w, axis=1)
    xp = bitops.pack_bits(x, axis=0)

    t0 = time.time()
    ref = bitops.xnor_popcount_matmul(wp, xp, k).block_until_ready()
    t_xla = time.time() - t0
    t0 = time.time()
    got = kops.xnor_gemm(wp, xp, k).block_until_ready()
    t_pallas = time.time() - t0
    assert bool(jnp.all(ref == got))
    out["interpret_timing"] = {"xla_fallback_s": t_xla,
                               "pallas_interpret_s": t_pallas}
    if verbose:
        print(f"xnor {m}x{k}x{n}: xla-fallback {t_xla:.3f}s, "
              f"pallas-interpret {t_pallas:.3f}s (correctness-scale only)")
    return out


# ---------------------------------------------------------------------------
# Tile sweep + VMEM-per-step model (DESIGN.md §6) -> BENCH_autotune.json
# ---------------------------------------------------------------------------

# Legacy fixed tiling every kernel hard-coded before the autotuner.
OLD_DEFAULT = {"block_m": 128, "block_n": 128, "block_kw": 16}


def vmem_step_report() -> dict:
    """Per-grid-step VMEM bytes, broadcast vs loop accumulator, at the
    legacy default tiles — the backend-independent half of the claim."""
    rows = {}
    for name, fused in [("xnor_gemm", False), ("fused_xnor_gemm", True)]:
        old = autotune.gemm_step_vmem(128, 128, 16, fused=fused,
                                      accum="broadcast")
        new = autotune.gemm_step_vmem(128, 128, 16, fused=fused,
                                      accum="loop")
        rows[name] = {
            "default_blocks": [128, 128, 16],
            "broadcast_bytes": old,
            "loop_bytes": new,
            "reduction": old / new,
        }
    # Direct conv, CIFAR BNN worst cases: conv1 (Hp=Wp=34, CW=4) and
    # conv5 (Hp=Wp=10, CW=16 -> KW=144, the big filter row).
    for name, (hp, cw, ow) in [
        ("fused_direct_conv[conv1]", (34, 4, 32)),
        ("fused_direct_conv[conv5]", (10, 16, 8)),
    ]:
        old = autotune.conv_step_vmem(hp, hp, cw, 128, 3, 3, ow,
                                      fused=True, accum="broadcast")
        new = autotune.conv_step_vmem(hp, hp, cw, 128, 3, 3, ow,
                                      fused=True, accum="loop")
        rows[name] = {
            "default_blocks": [128],
            "broadcast_bytes": old,
            "loop_bytes": new,
            "reduction": old / new,
        }
    return rows


def tile_sweep(
    shapes=((256, 2048, 256),), repeats: int = 8, verbose: bool = True
) -> dict:
    """Broadcast-vs-loop wall clock at the legacy tiles, then the
    autotuner's candidate sweep. Interpret-mode timings (compiled by
    XLA on CPU) — relative ordering is the signal, not TPU perf."""
    out = {}
    for m, k, n in shapes:
        kw = -(-k // 32)
        key = jax.random.PRNGKey(m + k + n)
        wp = autotune.rand_packed(jax.random.fold_in(key, 0), (m, kw))
        xp = autotune.rand_packed(jax.random.fold_in(key, 1), (kw, n))
        a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
        b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
        per = {}
        for name, fused in [("xnor_gemm", False), ("fused_xnor_gemm", True)]:
            fn = kops.fused_xnor_gemm if fused else kops.xnor_gemm
            extra = (a, b) if fused else ()
            t_broadcast = autotune.time_call(
                lambda: fn(wp, xp, k, *extra, accum="broadcast",
                           **OLD_DEFAULT),
                repeats,
            )
            t_loop = autotune.time_call(
                lambda: fn(wp, xp, k, *extra, accum="loop", **OLD_DEFAULT),
                repeats,
            )
            timings: dict = {}
            best = autotune.tune(
                fn, (m, k, n), fused=fused, repeats=repeats, cache=False,
                kernel=name, timings=timings,
            )
            t_best = min(timings.values())
            per[name] = {
                "old_default_blocks": [128, 128, 16],
                "broadcast_s": t_broadcast,
                "loop_s": t_loop,
                "loop_vs_broadcast_speedup": t_broadcast / t_loop,
                "tuned_blocks": [best.block_m, best.block_n, best.block_kw],
                "tuned_s": t_best,
                "tuned_vs_broadcast_speedup": t_broadcast / t_best,
                "candidates": [
                    {
                        "blocks": [c.block_m, c.block_n, c.block_kw],
                        "wall_s": t,
                    }
                    for c, t in timings.items()
                ],
            }
            if verbose:
                print(
                    f"{name} {m}x{k}x{n}: broadcast {t_broadcast:.3f}s -> "
                    f"loop {t_loop:.3f}s "
                    f"({t_broadcast / t_loop:.2f}x) -> tuned "
                    f"{best.block_m}/{best.block_n}/{best.block_kw} "
                    f"{t_best:.3f}s ({t_broadcast / t_best:.2f}x)"
                )
        out[f"{m}x{k}x{n}"] = per
    return out


def run_tile_sweep(verbose: bool = True, write: bool = True) -> dict:
    vmem = vmem_step_report()
    result = {
        "vmem_per_step": vmem,
        "vmem_reduction_min": min(r["reduction"] for r in vmem.values()),
        "tile_sweep": tile_sweep(verbose=verbose),
        "note": (
            "CPU interpret-mode wall clocks (relative ordering only, not "
            "TPU perf). vmem_per_step is the shape-derived model "
            "(kernels/autotune.py): per-grid-step VMEM bytes with the "
            "legacy [bm, bkw, bn] broadcast intermediate vs the "
            "fori-loop accumulator, at the old default tiles."
        ),
    }
    if verbose:
        for name, row in vmem.items():
            print(f"vmem/step {name:28s} {row['broadcast_bytes']/1024:8.0f} "
                  f"KiB -> {row['loop_bytes']/1024:6.0f} KiB "
                  f"({row['reduction']:.1f}x)")
    if write:
        write_bench(BENCH_AUTOTUNE_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--tile-sweep", action="store_true",
        help="run the block-size sweep and write BENCH_autotune.json",
    )
    args = parser.parse_args()
    if args.tile_sweep:
        run_tile_sweep()
    else:
        run()
