"""Kernel-level benchmark: arithmetic intensity + HBM traffic of the
three binary-GEMM engines (paper §3.2 adapted to TPU, DESIGN.md §2).

No TPU here, so the numbers that matter are *structural*: bytes moved
per output element and per-engine FLOP/byte, computed from shapes —
plus interpret-mode wall times at validation scale for completeness.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.kernels import ops as kops


def traffic_model(m: int, k: int, n: int) -> dict:
    """Bytes/HBM per GEMM for each engine (weights resident in HBM)."""
    f32 = 4
    rows = {
        # float GEMM: w[m,k] f32 + x[k,n] f32 + out f32
        "float_gemm": (m * k + k * n + m * n) * f32,
        # paper xnor: packed w [m,k/32] i32 + packed x [k/32,n] i32 + out i32
        "xnor_packed": (m * (k // 32) + (k // 32) * n) * 4 + m * n * 4,
        # unpack-MXU: packed w + bf16 x + f32 out
        "unpack_mxu": m * (k // 32) * 4 + k * n * 2 + m * n * 4,
    }
    flops = 2 * m * k * n
    return {
        name: {"bytes": b, "flops_per_byte": flops / b}
        for name, b in rows.items()
    }


def run(verbose: bool = True) -> dict:
    shapes = [(256, 1024, 256), (512, 4096, 512), (1024, 8192, 128)]
    out = {}
    for m, k, n in shapes:
        tm = traffic_model(m, k, n)
        out[f"{m}x{k}x{n}"] = tm
        if verbose:
            print(f"GEMM {m}x{k}x{n}:")
            for name, row in tm.items():
                print(f"  {name:12s} {row['bytes']/1e6:8.2f} MB "
                      f"{row['flops_per_byte']:8.1f} FLOP/byte")
            xr = tm['float_gemm']['bytes'] / tm['xnor_packed']['bytes']
            print(f"  -> xnor moves {xr:.1f}x fewer bytes (paper's win on TPU)")

    # interpret-mode correctness-scale timing (NOT a TPU perf claim)
    rng = np.random.default_rng(0)
    m, k, n = 128, 512, 128
    w = jnp.asarray(np.sign(rng.normal(size=(m, k))) + 0.0)
    x = jnp.asarray(np.sign(rng.normal(size=(k, n))) + 0.0)
    wp = bitops.pack_bits(w, axis=1)
    xp = bitops.pack_bits(x, axis=0)

    t0 = time.time()
    ref = bitops.xnor_popcount_matmul(wp, xp, k).block_until_ready()
    t_xla = time.time() - t0
    t0 = time.time()
    got = kops.xnor_gemm(wp, xp, k).block_until_ready()
    t_pallas = time.time() - t0
    assert bool(jnp.all(ref == got))
    out["interpret_timing"] = {"xla_fallback_s": t_xla,
                               "pallas_interpret_s": t_pallas}
    if verbose:
        print(f"xnor {m}x{k}x{n}: xla-fallback {t_xla:.3f}s, "
              f"pallas-interpret {t_pallas:.3f}s (correctness-scale only)")
    return out


if __name__ == "__main__":
    run()
