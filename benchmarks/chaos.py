"""Chaos benchmark: open-loop serving traffic through a seeded fault
schedule (DESIGN.md §11). Writes BENCH_chaos.json at the repo root.

Three sections, all on the continuous scheduler:

1. **Baseline** — a deterministic open-loop arrival schedule (the
   serving benchmark's convention: latency from INTENDED arrival,
   self-calibrated load) served fault-free on the primary
   ``megakernel_xla`` engine.
2. **Chaos** — the IDENTICAL schedule under a seeded `FaultPlan`:
   ~10% of dispatches fault (raise / NaN logits / latency spike),
   plus two pinned consecutive raises that force the `FallbackPolicy`
   to demote ``megakernel_xla -> xla`` deterministically. Gates:
   * **zero lost** — every submitted rid resolves (completed, expired,
     or failed with a result); nothing is stranded.
   * **bounded p99** — chaos p99 <= ``P99_INFLATION x
     max(baseline p99, one service wall)``; graceful degradation, not
     collapse.
   * **failover bit-identical** — after the forced demotion, a probe
     request's logits equal the PRIMARY engine's exact-shape forward
     bit-for-bit (the repo's bedrock invariant makes failover
     logit-exact).
3. **Mesh shrink** — an 8-device sharded continuous engine takes a
   pinned `DeviceLost` mid-traffic: it must shrink to the largest
   surviving power-of-two mesh (8 -> 4), re-warm the extent ladder at
   the new device multiple, re-dispatch the in-flight batch, lose
   nothing, stay bit-identical, and add ZERO compiles in steady state
   after the re-warm. Self-nulls (with the reason recorded) when
   fewer than 8 devices are available.

``--check`` (the CI gate, per ROADMAP Tending) exits nonzero if any
non-null gate fails. ``--smoke`` shortens the traffic window.

  PYTHONPATH=src python -m benchmarks.chaos [--smoke] [--check]
"""

from __future__ import annotations

import os

SIM_DEVICES = 8

# Must precede the first jax backend touch; this module is an entry
# point, so import time is early enough. A count already in XLA_FLAGS
# (e.g. the CI leg's exported environment) wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={SIM_DEVICES}"
    ).strip()

import argparse  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks._util import bench_path, time_fn, write_bench  # noqa: E402
from repro.core.bnn import (  # noqa: E402
    bnn_apply_fused,
    bnn_apply_megakernel,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.serve import (  # noqa: E402
    ContinuousServingEngine,
    DeadlineExceeded,
    FallbackPolicy,
    FaultPlan,
    FaultSpec,
    RequestFailed,
    RetryPolicy,
    percentile,
)

BENCH_PATH = bench_path("chaos")

MAX_ROWS = 8          # per-dispatch row budget -> extent classes 1/2/4/8
MAX_IMAGES = 4        # request sizes ~ U{1..4}
UTILIZATION = 0.5     # offered load as a fraction of extent-8 capacity
FAULT_RATE = 0.10     # random fault probability per dispatch
P99_INFLATION = 8.0   # chaos p99 bound, x max(baseline p99, fallback wall)
PRIMARY = "megakernel_xla"
FALLBACK = "xla"      # SERVE_FALLBACKS[PRIMARY][0] — the demotion target


def _arrival_schedule(seed, rate, duration_s, max_images):
    """Deterministic open-loop schedule (serving.py convention)."""
    rng = np.random.default_rng(seed)
    interval = 1.0 / rate
    out, t = [], 0.0
    while t < duration_s:
        out.append((t, int(rng.integers(1, max_images + 1))))
        t += interval
    return out


def _drive(eng, schedule, requests, *, deadline_s=None):
    """Replay ``schedule`` on the real clock; classify every resolution.

    Latency (successes only) runs from each request's INTENDED arrival
    — the open-loop convention benchmarks/serving.py established."""
    pend: dict[int, float] = {}
    out = {"completed": 0, "expired": 0, "failed": 0, "latencies": []}

    t0 = time.monotonic()

    def settle(rids):
        now = time.monotonic() - t0
        for rid in rids:
            res = eng.take(rid)
            t_arr = pend.pop(rid, None)
            if isinstance(res, DeadlineExceeded):
                out["expired"] += 1
            elif isinstance(res, RequestFailed):
                out["failed"] += 1
            elif res is not None:
                out["completed"] += 1
                if t_arr is not None:
                    out["latencies"].append(now - t_arr)

    i = 0
    while i < len(schedule):
        now = time.monotonic() - t0
        while i < len(schedule) and now >= schedule[i][0]:
            rid = eng.submit(requests[i], deadline_s=deadline_s)
            pend[rid] = schedule[i][0]
            i += 1
        settle(eng.step())
        if i < len(schedule):
            time.sleep(min(0.001, max(0.0, schedule[i][0]
                                      - (time.monotonic() - t0))))
    settle(eng.drain())
    out["wall_s"] = time.monotonic() - t0
    out["lost"] = len(pend)  # rids that never resolved — must be 0
    out["p99_s"] = percentile(out["latencies"], 99)
    out["p50_s"] = percentile(out["latencies"], 50)
    return out


def _summarize(run, snap):
    return {
        "submitted": snap["requests"]["submitted"],
        "completed": run["completed"],
        "expired": run["expired"],
        "failed": run["failed"],
        "lost": run["lost"],
        "wall_s": run["wall_s"],
        "open_loop_latency_s": {"p50": run["p50_s"], "p99": run["p99_s"]},
        "dispatch": snap["dispatch"],
        "mesh": snap["mesh"],
        "degraded": snap["degraded"],
    }


def chaos_run(mega, fused, *, smoke, seed, verbose=True):
    """Baseline vs chaos on the identical open-loop schedule."""
    # Calibrate BOTH service walls: the primary engine's and the
    # fallback rung's.  Offered load targets a fraction of the
    # DEGRADED engine's capacity — a fleet that arms failover
    # provisions for the fallback's throughput, otherwise a demotion
    # just trades a crash for an unbounded queue.  Rate, deadline,
    # backoff and the p99 floor all derive from the measured walls so
    # the operating point survives machine-speed differences.
    x8 = jax.random.normal(jax.random.PRNGKey(seed), (MAX_ROWS, 32, 32, 3))
    fn_p = bnn_serve_fn(engine=PRIMARY, ragged=True)
    t8, _ = time_fn(lambda: fn_p(mega, x8), repeats=3)
    t8 = max(t8, 1e-4)
    fn_f = bnn_serve_fn(engine=FALLBACK, ragged=True)
    t8_fb, _ = time_fn(lambda: fn_f(fused, x8), repeats=3)
    t8_fb = max(t8_fb, t8)
    mean_imgs = (1 + MAX_IMAGES) / 2
    rate = UTILIZATION * (MAX_ROWS / t8_fb) / mean_imgs
    duration_s = (12 if smoke else 30) * t8_fb
    deadline_s = 25 * t8_fb  # generous: expiry allowed, not engineered
    schedule = _arrival_schedule(seed, rate, duration_s, MAX_IMAGES)
    rng = np.random.default_rng(seed + 2)
    requests = [rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
                for _, n in schedule]
    retry = RetryPolicy(max_attempts=4, backoff_base_s=0.1 * t8_fb,
                        backoff_cap_s=t8_fb, jitter=0.25, seed=seed)
    if verbose:
        print(f"chaos: extent-8 wall {t8*1e3:.1f}ms (primary) / "
              f"{t8_fb*1e3:.1f}ms (fallback) -> rate {rate:.1f} req/s, "
              f"{len(schedule)} requests over {duration_s:.2f}s per "
              f"side, deadline {deadline_s:.2f}s")

    sides = {}
    engines = {}
    for name in ("baseline", "chaos"):
        faults = None
        fallback = None
        if name == "chaos":
            # Random ~10% of dispatches fault; two pinned consecutive
            # raises guarantee the demotion threshold is crossed no
            # matter where the random faults land.
            faults = FaultPlan(
                [FaultSpec("raise", at=5, count=2)],
                rate=FAULT_RATE, kinds=("raise", "nan", "latency"),
                latency_s=1.5 * t8_fb, seed=seed,
            )
            fallback = FallbackPolicy(fused_params=fused, mega_params=mega,
                                      failures_before_demote=2)
        eng = ContinuousServingEngine(
            mega, engine=PRIMARY, max_rows=MAX_ROWS,
            max_wait_s=0.25 * t8, retry=retry, fallback=fallback,
            faults=faults,
        )
        eng.warmup()
        # Hot-standby failover: warm the fallback rung ahead of traffic
        # so a mid-run demotion swaps executables instead of stalling
        # the queue behind fresh XLA compiles.
        eng.prewarm_fallback()
        run = _drive(eng, schedule, requests, deadline_s=deadline_s)
        sides[name] = _summarize(run, eng.snapshot())
        engines[name] = eng
        if name == "chaos":
            sides[name]["faults_fired"] = len(faults.fired)
            sides[name]["fault_kinds"] = {
                k: sum(1 for f in faults.fired if f["kind"] == k)
                for k in ("raise", "nan", "latency")
            }
        if verbose:
            s = sides[name]
            print(f"  {name:9s} completed {s['completed']} expired "
                  f"{s['expired']} failed {s['failed']} lost {s['lost']}"
                  f" | p99 {s['open_loop_latency_s']['p99']*1e3:.0f}ms"
                  f" | retries {s['dispatch']['retries']} fallbacks "
                  f"{s['dispatch']['fallbacks']}")

    # Failover probe: the chaos engine was demoted mid-run; a request
    # served NOW must still be bit-identical to the PRIMARY engine's
    # exact-shape forward.
    eng = engines["chaos"]
    probe = rng.normal(size=(3, 32, 32, 3)).astype(np.float32)
    rid = eng.submit(probe)
    eng.drain()
    got = eng.take(rid)
    want = np.asarray(bnn_apply_megakernel(mega, jnp.asarray(probe),
                                           engine="xla"))
    failover = {
        "occurred": sides["chaos"]["dispatch"]["fallbacks"] >= 1,
        "engine_path": sides["chaos"]["dispatch"]["engine_path"],
        "serving_engine_now": eng.executors.engine,
        "bit_identical_to_primary": bool(
            isinstance(got, np.ndarray) and np.array_equal(got, want)),
    }
    # The bound's floor is the FALLBACK wall: after a demotion the
    # service time is the fallback engine's, and "bounded inflation"
    # means bounded relative to what the degraded engine can do — a
    # stalled or compiling-under-traffic engine still blows past it
    # (the no-hot-standby configuration measured ~4x over this bound).
    p99_bound_s = P99_INFLATION * max(
        sides["baseline"]["open_loop_latency_s"]["p99"], t8_fb)
    return {
        "calibration": {"extent8_wall_s": t8,
                        "fallback_extent8_wall_s": t8_fb,
                        "rate_req_per_s": rate,
                        "duration_s": duration_s, "deadline_s": deadline_s,
                        "utilization_target": UTILIZATION,
                        "fault_rate": FAULT_RATE},
        "baseline": sides["baseline"],
        "chaos": sides["chaos"],
        "failover": failover,
        "p99_bound_s": p99_bound_s,
    }


def shrink_run(fused, *, seed, verbose=True):
    """One pinned device loss under traffic on an 8-device mesh."""
    n_dev = jax.device_count()
    if n_dev < SIM_DEVICES:
        return {
            "verdict": None,
            "note": (f"only {n_dev} jax devices — XLA_FLAGS was consumed "
                     "before this module could force host devices; mesh-"
                     "shrink section skipped (gate passes vacuously)"),
        }
    faults = FaultPlan([FaultSpec("device_loss", at=2, device=5)])
    eng = ContinuousServingEngine(
        fused, engine="xla", max_rows=MAX_ROWS,
        mesh=make_serving_mesh(SIM_DEVICES), faults=faults,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.0, jitter=0.0),
    )
    eng.warmup()
    rng = np.random.default_rng(seed)
    requests = {}
    for _ in range(8):
        x = rng.normal(size=(int(rng.integers(1, MAX_IMAGES + 1)),
                             32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        eng.drain()
    compiles_after_rewarm = eng.snapshot()["executors"]["compiles"]
    # Steady state on the shrunk mesh: more traffic, zero new compiles.
    for _ in range(6):
        x = rng.normal(size=(int(rng.integers(1, MAX_IMAGES + 1)),
                             32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        eng.drain()
    snap = eng.snapshot()
    lost, diverged = 0, 0
    for rid, x in requests.items():
        got = eng.take(rid)
        if got is None or not isinstance(got, np.ndarray):
            lost += 1
            continue
        want = np.asarray(bnn_apply_fused(fused, jnp.asarray(x),
                                          engine="xla"))
        diverged += int(not np.array_equal(got, want))
    result = {
        "devices_before": SIM_DEVICES,
        "devices_after": snap["mesh"]["devices"],
        "shrinks": snap["mesh"]["shrinks"],
        "requests": len(requests),
        "lost_or_failed": lost,
        "diverged": diverged,
        "compiles_after_rewarm": compiles_after_rewarm,
        "compiles_final": snap["executors"]["compiles"],
        "steady_state_recompiles": (snap["executors"]["compiles"]
                                    - compiles_after_rewarm),
        "verdict": bool(
            snap["mesh"]["shrinks"] == 1
            and snap["mesh"]["devices"] == SIM_DEVICES // 2
            and lost == 0 and diverged == 0
            and snap["executors"]["compiles"] == compiles_after_rewarm),
        "note": "one pinned DeviceLost mid-traffic; serves on through "
                "the 8->4 shrink, bit-identical, zero steady-state "
                "recompiles after re-warm",
    }
    if verbose:
        print(f"  shrink    {result['devices_before']}->"
              f"{result['devices_after']} devices | "
              f"{result['requests']} requests, lost {lost}, diverged "
              f"{diverged} | steady-state recompiles "
              f"{result['steady_state_recompiles']} | "
              f"verdict {result['verdict']}")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: shorter traffic window")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any non-null gate fails: "
                         "a lost request, unbounded p99 inflation, "
                         "missing/diverged failover, or a failed "
                         "mesh-shrink section")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    params = init_bnn_params(jax.random.PRNGKey(args.seed))
    fused = pack_bnn_params_fused(params)
    mega = pack_bnn_params_megakernel(params)

    doc = chaos_run(mega, fused, smoke=args.smoke, seed=args.seed)
    doc["mesh_shrink"] = shrink_run(fused, seed=args.seed + 1)

    chaos, base = doc["chaos"], doc["baseline"]
    gates = {
        "zero_lost": base["lost"] == 0 and chaos["lost"] == 0,
        "p99_bounded": (chaos["open_loop_latency_s"]["p99"]
                        <= doc["p99_bound_s"]),
        "failover_occurred": doc["failover"]["occurred"],
        "failover_bit_identical":
            doc["failover"]["bit_identical_to_primary"],
        "mesh_shrink_ok": doc["mesh_shrink"]["verdict"],
    }
    gates["all_ok"] = all(v is not False for v in gates.values())
    doc["verdict"] = gates
    print(f"verdict: {gates}")

    write_bench(BENCH_PATH, {
        "config": {"primary_engine": PRIMARY, "max_rows": MAX_ROWS,
                   "max_images": MAX_IMAGES, "fault_rate": FAULT_RATE,
                   "p99_inflation_bound": P99_INFLATION,
                   "smoke": args.smoke, "seed": args.seed},
        **doc,
    })

    if args.check:
        failed = [k for k, v in gates.items() if v is False]
        if failed:
            print(f"CHECK FAILED: {failed}")
            return 1
        print("CHECK OK" + (" (mesh-shrink gate skipped)"
                            if gates["mesh_shrink_ok"] is None else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
