"""Megakernel (one launch per stage) vs the per-layer fused chain:
launches/forward, inter-layer HBM bytes, residency math, wall clock.
Writes BENCH_megakernel.json at the repo root.

No TPU in this container, so wall clocks are CPU measurements (xla
oracle engines at batch 1/32/128; Pallas-interpret xnor engines at
validation scale) — NOT a TPU perf claim. The backend-independent
claims are structural: launches per forward drop from ~1-per-layer to
~1-per-stage, the intra-stage packed boundaries stop touching HBM
(``megakernel_stage_traffic``), and the whole packed model fits one
core's VMEM with room to spare (``residency_report``).

  PYTHONPATH=src python -m benchmarks.megakernel [--smoke] [--check]

``--check`` is the CI regression gate: exit nonzero if the megakernel
loses to the per-layer fused chain on the interpret xnor path (either
conv_impl) — the launch-fusion win must not regress.
"""

from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import bench_path, time_fn, write_bench
from benchmarks.kernel_microbench import _ceil_div, megakernel_stage_traffic
from repro.core.bnn import (
    CONV_CHANNELS,
    CONV_STAGES,
    FC_SIZES,
    bnn_apply_fused,
    bnn_apply_megakernel,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.kernels import autotune

BENCH_PATH = bench_path("megakernel")


def residency_report() -> dict:
    """DESIGN.md §8's math as data: packed model bytes vs the VMEM
    budget, per launch. Shape-derived, backend-independent."""
    conv_w = {
        f"conv{i}": cout * 9 * _ceil_div(cin, 32) * 4
        for i, (cin, cout) in enumerate(CONV_CHANNELS)
        if i > 0
    }
    fc_w = {
        f"fc{j}": fout * _ceil_div(fin, 32) * 4
        for j, (fin, fout) in enumerate(FC_SIZES)
    }
    interior = list(FC_SIZES[:-1])
    m_max = max(f for _, f in interior)
    kw_max = max(_ceil_div(f, 32) for f, _ in interior)
    per_launch = {
        f"stage{si + 1}": sum(conv_w[f"conv{i}"] for i in stage)
        for si, stage in enumerate(CONV_STAGES)
    }
    per_launch["fc_trunk"] = (
        len(interior) * m_max * kw_max * 4                      # stacked
        + FC_SIZES[-1][1] * _ceil_div(FC_SIZES[-1][0], 32) * 4  # head
    )
    return {
        "packed_model_bytes": sum(conv_w.values()) + sum(fc_w.values()),
        "per_launch_resident_bytes": per_launch,
        "fc_trunk_vmem_model_bytes": autotune.megakernel_vmem(
            len(interior), m_max, kw_max, 128, final_m=FC_SIZES[-1][1]
        ),
        "vmem_budget_bytes": autotune.MEGAKERNEL_VMEM_BUDGET,
        "fits": all(
            b <= autotune.MEGAKERNEL_VMEM_BUDGET
            for b in per_launch.values()
        ),
    }


def run(smoke: bool = False, verbose: bool = True, write: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    params = init_bnn_params(key)
    fused = pack_bnn_params_fused(params)
    mega = pack_bnn_params_megakernel(params)

    # -- wall clock, xla engines (CPU-fast: oracle chains, full batches)
    xla_batches = (1, 8) if smoke else (1, 32, 128)
    walls_xla = {}
    # conv_impl="direct" on the fused side: the strongest per-layer
    # baseline (same direct-conv math as the megakernel's stages, so
    # the xla rows isolate the chain-structure difference).
    fused_fn = jax.jit(
        lambda p, x: bnn_apply_fused(p, x, engine="xla", conv_impl="direct")
    )
    mega_fn = jax.jit(lambda p, x: bnn_apply_megakernel(p, x, engine="xla"))
    for b in xla_batches:
        x = jax.random.normal(jax.random.fold_in(key, b), (b, 32, 32, 3))
        reps = 3 if b <= 32 else 2
        t_f, want = time_fn(fused_fn, fused, x, repeats=reps)
        t_m, got = time_fn(mega_fn, mega, x, repeats=reps)
        walls_xla[int(b)] = {
            "fused_chain_s": t_f,
            "megakernel_s": t_m,
            "speedup": t_f / t_m,
            "bit_identical": bool(jnp.all(got == want)),
        }
        if verbose:
            r = walls_xla[int(b)]
            print(f"xla   b{b:3d}: fused {t_f:.3f}s -> mega {t_m:.3f}s "
                  f"({r['speedup']:.2f}x, bit_identical="
                  f"{r['bit_identical']})")

    # -- wall clock, interpret xnor path (validation scale; the --check
    #    gate reads the LARGEST batch row — the steady-state serving
    #    bucket in full mode — because batch 1 pads every lane tile
    #    identically on both sides and its sub-0.1s walls are
    #    noise-dominated; repeats median out single-run noise)
    walls_xnor = {}
    for bx in (2,) if smoke else (1, 32):
        x = jax.random.normal(jax.random.fold_in(key, 900 + bx),
                              (bx, 32, 32, 3))
        reps = 3
        t_direct, want = time_fn(
            lambda: bnn_apply_fused(fused, x, engine="xnor",
                                    conv_impl="direct"), repeats=reps,
        )
        t_im2col, _ = time_fn(
            lambda: bnn_apply_fused(fused, x, engine="xnor",
                                    conv_impl="im2col"), repeats=reps,
        )
        t_mega, got = time_fn(
            lambda: bnn_apply_megakernel(mega, x, engine="xnor"),
            repeats=reps,
        )
        walls_xnor[int(bx)] = {
            "fused_chain_direct_s": t_direct,
            "fused_chain_im2col_s": t_im2col,
            "megakernel_s": t_mega,
            "speedup_vs_direct": t_direct / t_mega,
            "speedup_vs_im2col": t_im2col / t_mega,
            "bit_identical": bool(jnp.all(got == want)),
        }
        if verbose:
            r = walls_xnor[int(bx)]
            print(f"xnor-interpret b{bx}: fused direct {t_direct:.3f}s / "
                  f"im2col {t_im2col:.3f}s -> mega {t_mega:.3f}s "
                  f"({r['speedup_vs_direct']:.2f}x vs direct, "
                  f"{r['speedup_vs_im2col']:.2f}x vs im2col)")

    # -- joint batch-tile search (full mode): measure the FC-trunk
    #    chain across batch tiles under the weights-resident model and
    #    persist the winner as "bnn_megakernel" in the autotune cache —
    #    later block_n="auto" launches on this shape reuse it.
    tuned = None
    if not smoke:
        from repro.kernels import ops as kops

        stack = mega["fc_stack"]
        k_bits = tuple(f for f, _ in FC_SIZES[:-1])
        n_t = 32
        kw_in = -(-FC_SIZES[0][0] // 32)
        xp = autotune.rand_packed(jax.random.PRNGKey(5), (kw_in, n_t))
        shape = autotune.megakernel_shape(
            *stack["w"].shape, n_t, FC_SIZES[-1][1]
        )

        def fn(bn):
            return kops.megakernel_chain(
                stack["w"], stack["a"], stack["b"], k_bits, xp,
                FC_SIZES[-2][1], final_wp=mega["fc_final"]["w_packed"],
                final_k_bits=FC_SIZES[-1][0], block_n=bn,
            )

        timings: dict = {}
        best = autotune.tune_block_n(
            autotune.MEGAKERNEL_KERNEL, shape, fn,
            candidates=(8, 32, 128), repeats=2, timings=timings,
        )
        tuned = {
            "shape": shape,
            "best_block_n": best,
            "wall_s": {str(bn): t for bn, t in timings.items()},
        }
        if verbose:
            print(f"bnn_megakernel batch-tile sweep at n={n_t}: "
                  + ", ".join(f"bn={bn} {t:.3f}s"
                              for bn, t in timings.items())
                  + f" -> cached bn={best}")

    traffic = megakernel_stage_traffic(32 if smoke else 128)
    result = {
        "tuned_batch_tile": tuned,
        "mode": "smoke" if smoke else "full",
        "launches_per_forward": traffic["launches_per_forward"],
        "interlayer_bytes": traffic,
        "residency": residency_report(),
        "wall_time_s": {"xla": walls_xla, "xnor_interpret": walls_xnor},
        "note": (
            "CPU-only walls: xla rows run the pure-XLA oracle chains "
            "(megakernel semantics, sequential ops — they measure the "
            "math, not the launch fusion), xnor rows the Pallas "
            "interpret kernels where launch/grid overhead is real. The "
            "backend-independent claims are launches_per_forward, "
            "interlayer_bytes (intra-stage boundaries never reach HBM) "
            "and residency (packed model << VMEM)."
        ),
    }
    if verbose:
        lp = traffic["launches_per_forward"]
        t = traffic["total"]
        print(f"launches/forward: unfused {lp['unfused_packed']} -> "
              f"fused chain {lp['fused_chain']} -> megakernel "
              f"{lp['megakernel']}")
        print(f"inter-layer bytes (b{traffic['batch']}): "
              f"{t['fused_chain_bytes']/1e6:.2f} MB -> "
              f"{t['megakernel_bytes']/1e6:.2f} MB "
              f"({t['bytes_ratio']:.1f}x fewer)")
        res = result["residency"]
        print(f"packed model {res['packed_model_bytes']/1e6:.2f} MB; "
              f"largest launch residency "
              f"{max(res['per_launch_resident_bytes'].values())/1e6:.2f} "
              f"MB of {res['vmem_budget_bytes']/1e6:.0f} MB budget "
              f"(fits={res['fits']})")
    if write:
        write_bench(BENCH_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: smaller batches, batch-1 xnor row")
    ap.add_argument(
        "--check", action="store_true",
        help="exit nonzero if the megakernel loses to the per-layer "
             "fused chain on the interpret xnor path",
    )
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    if args.check:
        rows = result["wall_time_s"]["xnor_interpret"]
        big = rows[max(rows)]
        worst = min(big["speedup_vs_direct"], big["speedup_vs_im2col"])
        ok_bits = all(r["bit_identical"] for r in rows.values())
        if worst < 1.0 or not ok_bits:
            print(
                f"FAIL: megakernel must beat the per-layer fused chain "
                f"on the interpret xnor path at batch {max(rows)} and "
                f"stay bit-identical "
                f"(speedup_vs_direct={big['speedup_vs_direct']:.2f}, "
                f"speedup_vs_im2col={big['speedup_vs_im2col']:.2f}, "
                f"bit_identical={ok_bits})",
                file=sys.stderr,
            )
            sys.exit(1)
        print(f"check OK: megakernel {worst:.2f}x >= 1.0 vs the fused "
              f"chain at batch {max(rows)}, bit-identical")
