"""Serving benchmark: batched vs batch-1 throughput on the fused xnor
path, bucket/compile accounting, and the structural serving-traffic
model. Writes BENCH_serving.json at the repo root.

Full mode (default; several minutes — Pallas interpret compiles at
every bucket):

1. **Serving-config sweep** — ``tune_serving_blocks`` picks the ONE
   deployment-wide block config that maximizes throughput at the
   largest measured bucket (persisted in the PR-3 autotune cache).
2. **Per-bucket throughput** under that deployed config, on
   ``engine="xnor"`` (the Pallas fused kernels, interpret mode off-TPU
   — the literal fused xnor path). The headline ratio compares bucket
   >= 32 against batch-1 under the SAME deployed config: that is
   exactly the choice a serving fleet faces (one compiled config,
   dispatch now vs coalesce).
3. **Structural serving bytes** — per-dispatch HBM traffic splits into
   batch-invariant weight reads and per-image activation bytes;
   batching amortizes the former. Shape-derived, backend-independent.
4. **Engine traffic run** (xla engine, CPU-fast) — seeded ragged
   requests through the ServingEngine: bucket hit rates, padding
   overhead, flush reasons, and the steady-state compile invariant
   (compile count == buckets warmed, zero new compiles under traffic).
5. **Scheduler head-to-head** (interpret xnor path, both modes) — one
   deterministic open-loop arrival schedule driven through the bucket
   ladder AND the continuous scheduler (DESIGN.md §9), same engine,
   same traffic. Load and SLO self-calibrate to the machine: offered
   load targets ~60% of the top rung's measured capacity, the SLO is
   1.75x the top-rung service wall — the regime where coalesced rows
   land BETWEEN rungs, so the ladder pads to 32 while the continuous
   scheduler dispatches 16/24-row extents. Reports per-side open-loop
   p99 (latency from INTENDED arrival, not submit — the synchronous
   loop submits late while a dispatch blocks, and that wait is real),
   goodput (within-SLO images/s) and pad-row fraction. ``--check``
   exits nonzero unless the continuous side beats the ladder on BOTH
   p99 and goodput — the CI gate.

``--smoke`` (CI): skips the sweep, uses the xla fallback engine and a
tiny ladder for sections 1-4 and a shorter head-to-head window; still
writes the JSON with the same schema.

  PYTHONPATH=src python -m benchmarks.serving [--smoke] [--check]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_microbench import _ceil_div, fused_chain_traffic
from repro.core.bnn import (
    CONV_CHANNELS,
    FC_SIZES,
    POOL_AFTER,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
)
from repro.kernels import autotune
from repro.serve import (
    ContinuousServingEngine,
    QueueFull,
    ServingEngine,
    percentile,
    tune_serving_blocks,
)
from repro.serve.executor import blocks_key

from benchmarks._util import bench_path, write_bench

BENCH_PATH = bench_path("serving")


# ---------------------------------------------------------------------------
# Structural serving-traffic model (shape-derived, backend-independent)
# ---------------------------------------------------------------------------

def serving_traffic_model(buckets=(1, 8, 32, 128)) -> dict:
    """Per-dispatch HBM bytes of the fused im2col chain at each bucket,
    split into batch-invariant weight bytes W and per-image activation
    bytes A: ``bytes(B) = W + B*A``. Serving at bucket B amortizes W
    over B images; the table reports the per-image amortization ratio
    ``(W + A) / (W/B + A)`` vs batch-1.
    """
    f32 = 4
    # -- W: every byte read once per dispatch regardless of batch.
    w_bytes = 0
    cin0, cout0 = CONV_CHANNELS[0]
    w_bytes += cout0 * 9 * cin0 * f32 + cout0 * f32      # float first conv
    w_bytes += 4 * cout0 * f32                            # its separate BN
    for cin, cout in CONV_CHANNELS[1:]:
        w_bytes += cout * _ceil_div(9 * cin, 32) * 4      # packed filters
        w_bytes += 2 * cout * f32                         # folded (a, b)
    for fin, fout in FC_SIZES[:-1]:
        w_bytes += fout * _ceil_div(fin, 32) * 4 + 2 * fout * f32
    fin_l, fout_l = FC_SIZES[-1]
    w_bytes += fout_l * _ceil_div(fin_l, 32) * 4 + fout_l * f32
    w_bytes += 4 * fout_l * f32                           # unfolded last BN

    # -- A: bytes that scale with every image in the dispatch.
    act = 32 * 32 * 3 * f32                               # input read
    act += 2 * 32 * 32 * cout0 * f32                      # float conv out w+r
    act += 2 * 32 * 32 * _ceil_div(cout0, 32) * 4         # first packed w+r
    # interior packed boundaries (write+read), per image:
    act += fused_chain_traffic(1)["total"]["fused_bytes"]
    # im2col packed patch matrices (write+read), per image:
    hw = 32
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        if i > 0:
            act += 2 * hw * hw * 9 * _ceil_div(cin, 32) * 4
        if i in POOL_AFTER:
            hw //= 2
    act += fout_l * f32                                   # logits write

    per_image_b1 = w_bytes + act
    rows = {
        int(b): {
            "dispatch_bytes": w_bytes + b * act,
            "per_image_bytes": w_bytes / b + act,
            "amortization_ratio_vs_batch1": per_image_b1 / (w_bytes / b + act),
        }
        for b in buckets
    }
    return {
        "weight_bytes": w_bytes,
        "act_bytes_per_image": act,
        "per_bucket": rows,
        "note": (
            "bytes(B) = W + B*A for the fused im2col chain; batching "
            "amortizes the batch-invariant weight reads W. Shape-derived "
            "— no wall clock involved."
        ),
    }


# ---------------------------------------------------------------------------
# Measured throughput
# ---------------------------------------------------------------------------

def measure_bucket_throughput(
    fused_params: dict,
    buckets,
    *,
    engine: str,
    blocks: object,
    key=None,
) -> dict:
    """Steady-state img/s per bucket under one (engine, blocks) config.

    One ``bnn_serve_fn`` serves every bucket (as in the executor cache:
    one jit fn, one executable per shape). Fewer repeats at larger
    buckets keep full-mode wall time bounded.
    """
    key = jax.random.PRNGKey(7) if key is None else key
    fn = bnn_serve_fn(engine=engine, blocks=blocks)
    out = {}
    for b in buckets:
        # interpret-mode timings on a small shared CPU are noisy;
        # spend repeats where a single run is cheapest
        reps = 6 if b == 1 else 3 if b <= 8 else 2 if b <= 32 else 1

        def call(b=b):
            # fresh operand per call: serve_fn donates on accelerators
            x = jax.random.normal(jax.random.fold_in(key, b),
                                  (b, 32, 32, 3))
            return fn(fused_params, x)

        t = autotune.time_call(call, reps)
        out[int(b)] = {"wall_s": t, "img_per_s": b / t}
    return out


def traffic_run(fused_params: dict, *, seed: int = 0) -> dict:
    """Seeded ragged traffic through the ServingEngine (xla engine —
    CPU-fast; the batching/caching machinery is engine-independent).
    Returns the stats snapshot plus the steady-state compile check."""
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4, 8),
                        max_wait_s=0.0)  # max_wait 0: dispatch every poll
    warmed = eng.warmup()
    compiles_after_warmup = eng.stats.executor_compiles
    rng = np.random.default_rng(seed)
    for _ in range(24):
        n = int(rng.integers(1, 9))
        eng.submit(rng.normal(size=(n, 32, 32, 3)).astype(np.float32))
        eng.step()
    eng.drain()
    snap = eng.snapshot()
    return {
        "snapshot": snap,
        "steady_state": {
            "buckets_warmed": warmed,
            "compiles_total": snap["executors"]["compiles"],
            "compiles_under_traffic": (
                snap["executors"]["compiles"] - compiles_after_warmup
            ),
            "compiles_equal_buckets_warmed": (
                snap["executors"]["compiles"] == warmed
            ),
        },
    }


# ---------------------------------------------------------------------------
# Scheduler head-to-head: bucket ladder vs continuous, same traffic
# ---------------------------------------------------------------------------

H2H_MAX_ROWS = 32        # continuous row budget == the ladder's top rung
H2H_BUCKETS = (1, 8, 32)
H2H_MAX_IMAGES = 8       # request sizes ~ U{1..8}, mean 4.5
H2H_UTILIZATION = 0.6    # offered load as a fraction of rung-32 capacity
H2H_SLO_FACTOR = 1.75    # SLO = factor * measured rung-32 service wall


def _arrival_schedule(seed: int, rate: float, duration_s: float,
                      max_images: int) -> list[tuple[float, int]]:
    """Deterministic open-loop schedule: ``(t_arrive, n_images)`` at a
    fixed inter-arrival interval with seeded sizes — both schedulers
    replay the IDENTICAL traffic."""
    rng = np.random.default_rng(seed)
    interval = 1.0 / rate
    out = []
    t = 0.0
    while t < duration_s:
        out.append((t, int(rng.integers(1, max_images + 1))))
        t += interval
    return out


def _drive_open_loop(eng, schedule, requests) -> dict:
    """Replay ``schedule`` through ``eng`` on the real clock.

    Latency is measured from each request's INTENDED arrival time, not
    its submit time: the synchronous dispatch loop submits late while a
    launch blocks, and for the ladder that blocked wait is exactly the
    tail this benchmark exists to expose — crediting it away would rig
    the comparison toward whichever side blocks longer.
    """
    lat = []
    rejected_images = 0
    t_intended: dict[int, float] = {}
    n_images: dict[int, int] = {}

    t0 = time.monotonic()
    i = 0
    while i < len(schedule):
        now = time.monotonic() - t0
        while i < len(schedule) and now >= schedule[i][0]:
            t_arr, _ = schedule[i]
            try:
                rid = eng.submit(requests[i])
                t_intended[rid] = t_arr
                n_images[rid] = requests[i].shape[0]
            except QueueFull:
                rejected_images += requests[i].shape[0]
            i += 1
        for rid in eng.step():
            eng.take(rid)
            lat.append(((time.monotonic() - t0) - t_intended.pop(rid),
                        n_images.pop(rid)))
        if i < len(schedule):
            time.sleep(min(0.001, max(0.0, schedule[i][0]
                                      - (time.monotonic() - t0))))
    for rid in eng.drain():
        eng.take(rid)
        lat.append(((time.monotonic() - t0) - t_intended.pop(rid),
                    n_images.pop(rid)))
    wall = time.monotonic() - t0
    return {"latencies": lat, "wall_s": wall,
            "rejected_images": rejected_images}


def _h2h_side(run: dict, snap: dict, slo_s: float) -> dict:
    lat = [l for l, _ in run["latencies"]]
    within = sum(n for l, n in run["latencies"] if l <= slo_s)
    served = sum(n for _, n in run["latencies"])
    bat = snap["batches"]
    return {
        "scheduler": snap["scheduler"],
        "requests_served": len(lat),
        "images_served": served,
        "images_rejected": run["rejected_images"],
        "open_loop_latency_s": {
            "p50": percentile(lat, 50),
            "p95": percentile(lat, 95),
            "p99": percentile(lat, 99),
            "max": max(lat) if lat else 0.0,
        },
        "goodput_img_per_s": within / run["wall_s"] if run["wall_s"] else 0.0,
        "images_within_slo": within,
        "pad_row_fraction": bat["pad_row_fraction"],
        "dispatch_shapes": bat["per_bucket"],
        "dispatched_rows": bat["dispatched_rows"],
        "real_rows": bat["real_rows"],
    }


def head_to_head(fused_params: dict, *, smoke: bool, seed: int = 11,
                 verbose: bool = True) -> dict:
    """Bucket ladder vs continuous scheduler on the interpret xnor path,
    identical deterministic open-loop traffic, self-calibrated load."""
    engine = "xnor"

    # Calibrate: one rung-32 forward (after a warmup execution) sets the
    # machine's service wall; load and SLO derive from it so the regime
    # — coalesced rows landing between rungs — survives machine-speed
    # differences (a fixed rate would under- or overload a faster or
    # slower container into a different operating point entirely).
    fn = bnn_serve_fn(engine=engine)
    x32 = jax.random.normal(jax.random.PRNGKey(seed), (H2H_MAX_ROWS, 32, 32, 3))
    fn(fused_params, x32).block_until_ready()
    t32 = autotune.time_call(
        lambda: fn(fused_params,
                   jax.random.normal(jax.random.PRNGKey(seed + 1),
                                     (H2H_MAX_ROWS, 32, 32, 3))), 1,
    )
    mean_imgs = (1 + H2H_MAX_IMAGES) / 2
    rate = H2H_UTILIZATION * (H2H_MAX_ROWS / t32) / mean_imgs
    slo_s = H2H_SLO_FACTOR * t32
    # Both sides get the SAME coalescing wait, scaled to the service
    # wall: with a near-zero wait each side fires tiny launches whose
    # fixed per-launch overhead swamps the scheduling signal; a
    # quarter-service wait lets arrivals coalesce into the regime the
    # comparison is about (rows between the 8 and 32 rungs).
    max_wait_s = 0.25 * t32
    # The window must be long enough for queue dynamics to surface:
    # pad-to-rung wastes ~the pad fraction of the ladder's compute, so
    # at this utilization the ladder runs at its capacity edge and its
    # queue (hence p99) grows across cycles, while the continuous side
    # holds steady — a short window would hide exactly that.
    duration_s = (12 if smoke else 20) * t32
    schedule = _arrival_schedule(seed, rate, duration_s, H2H_MAX_IMAGES)
    rng = np.random.default_rng(seed + 2)
    requests = [rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
                for _, n in schedule]
    if verbose:
        print(f"head-to-head: rung-32 wall {t32:.2f}s -> rate "
              f"{rate:.2f} req/s, SLO {slo_s:.2f}s, {len(schedule)} "
              f"requests over {duration_s:.0f}s per side")

    sides = {}
    for name in ("bucket", "continuous"):
        if name == "bucket":
            eng = ServingEngine(fused_params, engine=engine,
                                buckets=H2H_BUCKETS,
                                max_wait_s=max_wait_s)
            eng.stats.slo_s = slo_s
        else:
            eng = ContinuousServingEngine(
                fused_params, engine=engine, max_rows=H2H_MAX_ROWS,
                max_queue_rows=3 * H2H_MAX_ROWS, slo_s=slo_s,
                max_wait_s=max_wait_s,
            )
        eng.warmup()
        run = _drive_open_loop(eng, schedule, requests)
        sides[name] = _h2h_side(run, eng.snapshot(), slo_s)
        if verbose:
            s = sides[name]
            print(f"  {name:10s} p99 {s['open_loop_latency_s']['p99']:.2f}s"
                  f" | goodput {s['goodput_img_per_s']:.1f} img/s"
                  f" | pad rows {s['pad_row_fraction']:.1%}"
                  f" | shapes {s['dispatch_shapes']}")

    b, c = sides["bucket"], sides["continuous"]
    wins = {
        "p99": c["open_loop_latency_s"]["p99"] < b["open_loop_latency_s"]["p99"],
        "goodput": c["goodput_img_per_s"] > b["goodput_img_per_s"],
    }
    wins["both"] = wins["p99"] and wins["goodput"]
    if verbose:
        print(f"  continuous beats bucket: p99={wins['p99']} "
              f"goodput={wins['goodput']}")
    return {
        "engine": engine,
        "calibration": {"rung32_wall_s": t32, "rate_req_per_s": rate,
                        "slo_s": slo_s, "duration_s": duration_s,
                        "max_wait_s": max_wait_s,
                        "utilization_target": H2H_UTILIZATION,
                        "max_images": H2H_MAX_IMAGES},
        "bucket": b,
        "continuous": c,
        "continuous_beats_bucket": wins,
        "note": (
            "Identical deterministic open-loop traffic through both "
            "schedulers on the interpret xnor path. Latency is from "
            "intended arrival (open-loop convention). Load targets "
            f"{H2H_UTILIZATION:.0%} of rung-32 capacity so coalesced "
            "batches land between the 8 and 32 rungs: the ladder pads "
            "them to 32, the continuous scheduler dispatches tile-"
            "padded 16/24-row extents — the pad-row compute it removes "
            "is the p99/goodput margin."
        ),
    }


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run(smoke: bool = False, verbose: bool = True, write: bool = True) -> dict:
    params = init_bnn_params(jax.random.PRNGKey(0))
    fused = pack_bnn_params_fused(params)

    if smoke:
        engine, buckets, big = "xla", (1, 4, 8), 8
        blocks, sweep = "auto", None
        best_single_ratio = None
    else:
        engine, buckets, big = "xnor", (1, 8, 32, 128), 32
        timings: dict = {}
        blocks = tune_serving_blocks(fused, big, engine=engine,
                                     repeats=3, timings=timings)
        # Per-config batch-1 throughput: the batched-vs-batch1 ratio is
        # only meaningful with the config held FIXED across both sides,
        # and near-tied configs at the big bucket can differ 2x at
        # batch-1 — so record the whole (b1, b32, ratio) surface, not
        # just the winner's row.
        sweep = {}
        for c, t in timings.items():
            r1 = measure_bucket_throughput(fused, (1,), engine=engine,
                                           blocks=c)
            sweep[blocks_key(c)] = {
                "batch1_img_per_s": r1[1]["img_per_s"],
                "bucket32_img_per_s": big / t,
                "ratio_32_vs_1": (big / t) / r1[1]["img_per_s"],
            }
        best_single_ratio = max(r["ratio_32_vs_1"] for r in sweep.values())
        if verbose:
            print(f"serving-config sweep at bucket {big}:")
            for k, row in sweep.items():
                print(f"  {k:24s} b1 {row['batch1_img_per_s']:5.2f} "
                      f"b32 {row['bucket32_img_per_s']:6.2f} img/s "
                      f"({row['ratio_32_vs_1']:.2f}x)")
            print(f"  -> deployed config: {blocks_key(blocks)}")

    per_bucket = measure_bucket_throughput(
        fused, buckets, engine=engine, blocks=blocks
    )
    b1 = per_bucket[1]["img_per_s"]
    ratios = {
        b: row["img_per_s"] / b1 for b, row in per_bucket.items() if b != 1
    }
    # The system-level comparison this subsystem exists for: the serving
    # engine (bucketed + batched + serving-tuned blocks) vs the repo's
    # prior dispatch mode — one request at a time with per-shape "auto"
    # blocks and no batching. Both sides measured, same engine.
    naive_b1 = (sweep or {}).get("auto", {}).get("batch1_img_per_s", b1)
    batched_best = max(
        (row["img_per_s"] for b, row in per_bucket.items() if b >= 32),
        default=None,
    )
    engine_vs_naive = (
        batched_best / naive_b1 if batched_best is not None else None
    )
    structural = serving_traffic_model()
    traffic = traffic_run(fused)
    h2h = head_to_head(fused, smoke=smoke, verbose=verbose)

    result = {
        "mode": "smoke" if smoke else "full",
        "engine": engine,
        "deployed_blocks": blocks_key(blocks),
        "serving_config_sweep": sweep,
        "throughput": {
            "per_bucket": per_bucket,
            "batched_vs_batch1": ratios,
            "max_measured_bucket": max(buckets),
            # Three framings of "batched vs batch-1", most to least
            # favorable to batch-1 — all measured, none hidden:
            #   batched_vs_batch1      deployed config held fixed on
            #                          both sides (the fleet's marginal
            #                          choice: dispatch now vs coalesce)
            #   best_single_config...  best ratio any ONE config attains
            #                          (config fixed per row)
            #   engine_vs_naive_batch1 the serving engine at bucket>=32
            #                          vs the repo's PRIOR dispatch mode
            #                          (batch-1, per-shape auto blocks,
            #                          no batching) — what the subsystem
            #                          delivers end to end; note it
            #                          compounds batching with the
            #                          config change, so read it next
            #                          to the same-config rows.
            "best_single_config_ratio_32_vs_1": best_single_ratio,
            "engine_vs_naive_batch1": engine_vs_naive,
            # One verdict per framing (null in smoke mode, where the
            # xnor path and the >=32 buckets are not measured at all —
            # a False here would read as a failed criterion in every CI
            # artifact).
            "meets_3x_at_32": None if smoke else {
                "engine_vs_naive_batch1": bool(engine_vs_naive >= 3.0),
                "best_single_config": bool(best_single_ratio >= 3.0),
                "deployed_config": bool(
                    max((r for b, r in ratios.items() if b >= 32),
                        default=0.0) >= 3.0
                ),
            },
        },
        "structural_serving_bytes": structural,
        "engine_traffic": traffic,
        "head_to_head": h2h,
        "note": (
            "Throughput rows run the fused packed chain via bnn_serve_fn "
            "under ONE deployed block config (full mode: tuned for the "
            "largest-bucket steady state on the Pallas interpret xnor "
            "engine — the fused xnor path as it runs off-TPU; smoke: xla "
            "fallback). The batched-vs-batch1 ratio is the fleet's actual "
            "tradeoff: same compiled config, dispatch alone vs coalesce. "
            "CPU caveat: interpret-mode timings on this 2-core container "
            "are noisy (+-20%), and the per-image marginal cost bounds "
            "the measurable amortization at 1 + fixed/marginal (~3x "
            "here); larger buckets approach it. On accelerator backends "
            "the same fixed work (launch overhead, weight streaming, "
            "lane-padded FC tiles) is what the GPU batching wins of Khan "
            "et al. amortize. structural_serving_bytes is the backend-"
            "independent weight-amortization model; engine_traffic "
            "exercises the bucket ladder/cache on the CPU-fast xla "
            "engine."
        ),
    }
    if verbose:
        for b, row in per_bucket.items():
            extra = f"  ({ratios[b]:.2f}x vs batch-1)" if b != 1 else ""
            print(f"bucket {b:3d}: {row['img_per_s']:6.2f} img/s{extra}")
        if engine_vs_naive is not None:
            print(f"engine (bucket>=32, tuned) vs naive batch-1 (auto, "
                  f"unbatched): {engine_vs_naive:.2f}x")
        ss = traffic["steady_state"]
        print(f"steady state: {ss['buckets_warmed']} buckets warmed, "
              f"{ss['compiles_total']} compiles, "
              f"{ss['compiles_under_traffic']} under traffic")
        bt = traffic["snapshot"]["batches"]
        print(f"traffic: buckets {bt['per_bucket']} | padding "
              f"{bt['padding_overhead']:.1%}")
    if write:
        write_bench(BENCH_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: xla engine, tiny ladder, no sweep")
    ap.add_argument("--check", action="store_true",
                    help="gate: exit nonzero unless the continuous "
                         "scheduler beats the bucket ladder on BOTH "
                         "p99 latency and goodput in the head-to-head")
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    if args.check:
        wins = result["head_to_head"]["continuous_beats_bucket"]
        if not wins["both"]:
            raise SystemExit(
                f"head-to-head gate FAILED: continuous vs bucket "
                f"p99={wins['p99']} goodput={wins['goodput']} "
                f"(both must be True)"
            )
        print("head-to-head gate OK: continuous beats bucket on p99 "
              "and goodput")
