"""Serving benchmark: batched vs batch-1 throughput on the fused xnor
path, bucket/compile accounting, and the structural serving-traffic
model. Writes BENCH_serving.json at the repo root.

Full mode (default; several minutes — Pallas interpret compiles at
every bucket):

1. **Serving-config sweep** — ``tune_serving_blocks`` picks the ONE
   deployment-wide block config that maximizes throughput at the
   largest measured bucket (persisted in the PR-3 autotune cache).
2. **Per-bucket throughput** under that deployed config, on
   ``engine="xnor"`` (the Pallas fused kernels, interpret mode off-TPU
   — the literal fused xnor path). The headline ratio compares bucket
   >= 32 against batch-1 under the SAME deployed config: that is
   exactly the choice a serving fleet faces (one compiled config,
   dispatch now vs coalesce).
3. **Structural serving bytes** — per-dispatch HBM traffic splits into
   batch-invariant weight reads and per-image activation bytes;
   batching amortizes the former. Shape-derived, backend-independent.
4. **Engine traffic run** (xla engine, CPU-fast) — seeded ragged
   requests through the ServingEngine: bucket hit rates, padding
   overhead, flush reasons, and the steady-state compile invariant
   (compile count == buckets warmed, zero new compiles under traffic).

``--smoke`` (CI): skips the sweep, uses the xla fallback engine and a
tiny ladder; still writes the JSON with the same schema.

  PYTHONPATH=src python -m benchmarks.serving [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.kernel_microbench import _ceil_div, fused_chain_traffic
from repro.core.bnn import (
    CONV_CHANNELS,
    FC_SIZES,
    POOL_AFTER,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
)
from repro.kernels import autotune
from repro.serve import ServingEngine, tune_serving_blocks
from repro.serve.executor import blocks_key

from benchmarks._util import bench_path, write_bench

BENCH_PATH = bench_path("serving")


# ---------------------------------------------------------------------------
# Structural serving-traffic model (shape-derived, backend-independent)
# ---------------------------------------------------------------------------

def serving_traffic_model(buckets=(1, 8, 32, 128)) -> dict:
    """Per-dispatch HBM bytes of the fused im2col chain at each bucket,
    split into batch-invariant weight bytes W and per-image activation
    bytes A: ``bytes(B) = W + B*A``. Serving at bucket B amortizes W
    over B images; the table reports the per-image amortization ratio
    ``(W + A) / (W/B + A)`` vs batch-1.
    """
    f32 = 4
    # -- W: every byte read once per dispatch regardless of batch.
    w_bytes = 0
    cin0, cout0 = CONV_CHANNELS[0]
    w_bytes += cout0 * 9 * cin0 * f32 + cout0 * f32      # float first conv
    w_bytes += 4 * cout0 * f32                            # its separate BN
    for cin, cout in CONV_CHANNELS[1:]:
        w_bytes += cout * _ceil_div(9 * cin, 32) * 4      # packed filters
        w_bytes += 2 * cout * f32                         # folded (a, b)
    for fin, fout in FC_SIZES[:-1]:
        w_bytes += fout * _ceil_div(fin, 32) * 4 + 2 * fout * f32
    fin_l, fout_l = FC_SIZES[-1]
    w_bytes += fout_l * _ceil_div(fin_l, 32) * 4 + fout_l * f32
    w_bytes += 4 * fout_l * f32                           # unfolded last BN

    # -- A: bytes that scale with every image in the dispatch.
    act = 32 * 32 * 3 * f32                               # input read
    act += 2 * 32 * 32 * cout0 * f32                      # float conv out w+r
    act += 2 * 32 * 32 * _ceil_div(cout0, 32) * 4         # first packed w+r
    # interior packed boundaries (write+read), per image:
    act += fused_chain_traffic(1)["total"]["fused_bytes"]
    # im2col packed patch matrices (write+read), per image:
    hw = 32
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        if i > 0:
            act += 2 * hw * hw * 9 * _ceil_div(cin, 32) * 4
        if i in POOL_AFTER:
            hw //= 2
    act += fout_l * f32                                   # logits write

    per_image_b1 = w_bytes + act
    rows = {
        int(b): {
            "dispatch_bytes": w_bytes + b * act,
            "per_image_bytes": w_bytes / b + act,
            "amortization_ratio_vs_batch1": per_image_b1 / (w_bytes / b + act),
        }
        for b in buckets
    }
    return {
        "weight_bytes": w_bytes,
        "act_bytes_per_image": act,
        "per_bucket": rows,
        "note": (
            "bytes(B) = W + B*A for the fused im2col chain; batching "
            "amortizes the batch-invariant weight reads W. Shape-derived "
            "— no wall clock involved."
        ),
    }


# ---------------------------------------------------------------------------
# Measured throughput
# ---------------------------------------------------------------------------

def measure_bucket_throughput(
    fused_params: dict,
    buckets,
    *,
    engine: str,
    blocks: object,
    key=None,
) -> dict:
    """Steady-state img/s per bucket under one (engine, blocks) config.

    One ``bnn_serve_fn`` serves every bucket (as in the executor cache:
    one jit fn, one executable per shape). Fewer repeats at larger
    buckets keep full-mode wall time bounded.
    """
    key = jax.random.PRNGKey(7) if key is None else key
    fn = bnn_serve_fn(engine=engine, blocks=blocks)
    out = {}
    for b in buckets:
        # interpret-mode timings on a small shared CPU are noisy;
        # spend repeats where a single run is cheapest
        reps = 6 if b == 1 else 3 if b <= 8 else 2 if b <= 32 else 1

        def call(b=b):
            # fresh operand per call: serve_fn donates on accelerators
            x = jax.random.normal(jax.random.fold_in(key, b),
                                  (b, 32, 32, 3))
            return fn(fused_params, x)

        t = autotune.time_call(call, reps)
        out[int(b)] = {"wall_s": t, "img_per_s": b / t}
    return out


def traffic_run(fused_params: dict, *, seed: int = 0) -> dict:
    """Seeded ragged traffic through the ServingEngine (xla engine —
    CPU-fast; the batching/caching machinery is engine-independent).
    Returns the stats snapshot plus the steady-state compile check."""
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4, 8),
                        max_wait_s=0.0)  # max_wait 0: dispatch every poll
    warmed = eng.warmup()
    compiles_after_warmup = eng.stats.executor_compiles
    rng = np.random.default_rng(seed)
    for _ in range(24):
        n = int(rng.integers(1, 9))
        eng.submit(rng.normal(size=(n, 32, 32, 3)).astype(np.float32))
        eng.step()
    eng.drain()
    snap = eng.snapshot()
    return {
        "snapshot": snap,
        "steady_state": {
            "buckets_warmed": warmed,
            "compiles_total": snap["executors"]["compiles"],
            "compiles_under_traffic": (
                snap["executors"]["compiles"] - compiles_after_warmup
            ),
            "compiles_equal_buckets_warmed": (
                snap["executors"]["compiles"] == warmed
            ),
        },
    }


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run(smoke: bool = False, verbose: bool = True, write: bool = True) -> dict:
    params = init_bnn_params(jax.random.PRNGKey(0))
    fused = pack_bnn_params_fused(params)

    if smoke:
        engine, buckets, big = "xla", (1, 4, 8), 8
        blocks, sweep = "auto", None
        best_single_ratio = None
    else:
        engine, buckets, big = "xnor", (1, 8, 32, 128), 32
        timings: dict = {}
        blocks = tune_serving_blocks(fused, big, engine=engine,
                                     repeats=3, timings=timings)
        # Per-config batch-1 throughput: the batched-vs-batch1 ratio is
        # only meaningful with the config held FIXED across both sides,
        # and near-tied configs at the big bucket can differ 2x at
        # batch-1 — so record the whole (b1, b32, ratio) surface, not
        # just the winner's row.
        sweep = {}
        for c, t in timings.items():
            r1 = measure_bucket_throughput(fused, (1,), engine=engine,
                                           blocks=c)
            sweep[blocks_key(c)] = {
                "batch1_img_per_s": r1[1]["img_per_s"],
                "bucket32_img_per_s": big / t,
                "ratio_32_vs_1": (big / t) / r1[1]["img_per_s"],
            }
        best_single_ratio = max(r["ratio_32_vs_1"] for r in sweep.values())
        if verbose:
            print(f"serving-config sweep at bucket {big}:")
            for k, row in sweep.items():
                print(f"  {k:24s} b1 {row['batch1_img_per_s']:5.2f} "
                      f"b32 {row['bucket32_img_per_s']:6.2f} img/s "
                      f"({row['ratio_32_vs_1']:.2f}x)")
            print(f"  -> deployed config: {blocks_key(blocks)}")

    per_bucket = measure_bucket_throughput(
        fused, buckets, engine=engine, blocks=blocks
    )
    b1 = per_bucket[1]["img_per_s"]
    ratios = {
        b: row["img_per_s"] / b1 for b, row in per_bucket.items() if b != 1
    }
    # The system-level comparison this subsystem exists for: the serving
    # engine (bucketed + batched + serving-tuned blocks) vs the repo's
    # prior dispatch mode — one request at a time with per-shape "auto"
    # blocks and no batching. Both sides measured, same engine.
    naive_b1 = (sweep or {}).get("auto", {}).get("batch1_img_per_s", b1)
    batched_best = max(
        (row["img_per_s"] for b, row in per_bucket.items() if b >= 32),
        default=None,
    )
    engine_vs_naive = (
        batched_best / naive_b1 if batched_best is not None else None
    )
    structural = serving_traffic_model()
    traffic = traffic_run(fused)

    result = {
        "mode": "smoke" if smoke else "full",
        "engine": engine,
        "deployed_blocks": blocks_key(blocks),
        "serving_config_sweep": sweep,
        "throughput": {
            "per_bucket": per_bucket,
            "batched_vs_batch1": ratios,
            "max_measured_bucket": max(buckets),
            # Three framings of "batched vs batch-1", most to least
            # favorable to batch-1 — all measured, none hidden:
            #   batched_vs_batch1      deployed config held fixed on
            #                          both sides (the fleet's marginal
            #                          choice: dispatch now vs coalesce)
            #   best_single_config...  best ratio any ONE config attains
            #                          (config fixed per row)
            #   engine_vs_naive_batch1 the serving engine at bucket>=32
            #                          vs the repo's PRIOR dispatch mode
            #                          (batch-1, per-shape auto blocks,
            #                          no batching) — what the subsystem
            #                          delivers end to end; note it
            #                          compounds batching with the
            #                          config change, so read it next
            #                          to the same-config rows.
            "best_single_config_ratio_32_vs_1": best_single_ratio,
            "engine_vs_naive_batch1": engine_vs_naive,
            # One verdict per framing (null in smoke mode, where the
            # xnor path and the >=32 buckets are not measured at all —
            # a False here would read as a failed criterion in every CI
            # artifact).
            "meets_3x_at_32": None if smoke else {
                "engine_vs_naive_batch1": bool(engine_vs_naive >= 3.0),
                "best_single_config": bool(best_single_ratio >= 3.0),
                "deployed_config": bool(
                    max((r for b, r in ratios.items() if b >= 32),
                        default=0.0) >= 3.0
                ),
            },
        },
        "structural_serving_bytes": structural,
        "engine_traffic": traffic,
        "note": (
            "Throughput rows run the fused packed chain via bnn_serve_fn "
            "under ONE deployed block config (full mode: tuned for the "
            "largest-bucket steady state on the Pallas interpret xnor "
            "engine — the fused xnor path as it runs off-TPU; smoke: xla "
            "fallback). The batched-vs-batch1 ratio is the fleet's actual "
            "tradeoff: same compiled config, dispatch alone vs coalesce. "
            "CPU caveat: interpret-mode timings on this 2-core container "
            "are noisy (+-20%), and the per-image marginal cost bounds "
            "the measurable amortization at 1 + fixed/marginal (~3x "
            "here); larger buckets approach it. On accelerator backends "
            "the same fixed work (launch overhead, weight streaming, "
            "lane-padded FC tiles) is what the GPU batching wins of Khan "
            "et al. amortize. structural_serving_bytes is the backend-"
            "independent weight-amortization model; engine_traffic "
            "exercises the bucket ladder/cache on the CPU-fast xla "
            "engine."
        ),
    }
    if verbose:
        for b, row in per_bucket.items():
            extra = f"  ({ratios[b]:.2f}x vs batch-1)" if b != 1 else ""
            print(f"bucket {b:3d}: {row['img_per_s']:6.2f} img/s{extra}")
        if engine_vs_naive is not None:
            print(f"engine (bucket>=32, tuned) vs naive batch-1 (auto, "
                  f"unbatched): {engine_vs_naive:.2f}x")
        ss = traffic["steady_state"]
        print(f"steady state: {ss['buckets_warmed']} buckets warmed, "
              f"{ss['compiles_total']} compiles, "
              f"{ss['compiles_under_traffic']} under traffic")
        bt = traffic["snapshot"]["batches"]
        print(f"traffic: buckets {bt['per_bucket']} | padding "
              f"{bt['padding_overhead']:.1%}")
    if write:
        write_bench(BENCH_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: xla engine, tiny ladder, no sweep")
    args = ap.parse_args()
    run(smoke=args.smoke)
