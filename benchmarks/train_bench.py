"""Training benchmark: the train half of the train-to-serve loop
(ISSUE 9, DESIGN.md §12). Writes BENCH_train.json at the repo root.

Three sections:

1. **step_time** — median wall time of one jitted STE train step
   (FAKE_QUANT forward, batch BN, straight-through backward, AdamW with
   latent clip) at the benchmark batch size, plus the compile time.
2. **learning** — a short deterministic CPU training run
   (``train_bnn``): first-vs-last train loss, held-out eval loss and
   accuracy on the float-boundary forward (bit-identical to packed
   serving, so this IS serving accuracy). Gates:
   * **loss drops >= 30%** from the first train step to the mean of the
     final quarter of steps;
   * **eval accuracy above chance** by a wide margin
     (>= ``ACC_GATE`` vs 0.10 chance on 10 classes).
3. **dp_compressions** — one jitted shard_map data-parallel step per
   grad-compression mode (fp32 / EF-int8 / 1-bit EF-sign-SGD) on a
   2-device mesh: median step time and the per-mode train loss after a
   fixed number of steps, so a compression regression shows up as a
   loss gap, not just a crash. Self-nulls when fewer than 2 devices
   are available.

``--check`` (the CI gate, per ROADMAP Tending) exits nonzero if any
non-null gate fails. ``--smoke`` shrinks steps/batch for CI wall-clock.

  PYTHONPATH=src python -m benchmarks.train_bench [--smoke] [--check]
"""

from __future__ import annotations

import os

SIM_DEVICES = 2

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={SIM_DEVICES}"
    ).strip()

import argparse  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from benchmarks._util import bench_path, time_fn, write_bench  # noqa: E402
from repro.core.bnn import init_bnn_params  # noqa: E402
from repro.data.pipeline import (  # noqa: E402
    DataConfig,
    synthetic_cifar_batches,
)
from repro.train.bnn_trainer import (  # noqa: E402
    DP_COMPRESSIONS,
    BNNTrainerConfig,
    _BNNTask,
    bnn_clip_predicate,
    init_dp_error_feedback,
    make_dp_train_step,
    train_bnn,
)
from repro.train.step import init_opt_state, make_train_step  # noqa: E402

LOSS_DROP_GATE = 0.30    # final-quarter mean train loss vs first step
ACC_GATE = 0.30          # held-out accuracy; chance is 0.10


def bench_step_time(cfg: BNNTrainerConfig) -> dict:
    task = _BNNTask(cfg.model_config())
    params = init_bnn_params(jax.random.PRNGKey(cfg.seed))
    opt = init_opt_state(params)
    batch = next(iter(synthetic_cifar_batches(
        DataConfig(global_batch=cfg.batch, seed=cfg.data_seed))))
    feed = {"images": batch["images"], "labels": batch["labels"]}
    step = jax.jit(make_train_step(task, cfg.train_config(),
                                   clip_predicate=bnn_clip_predicate))
    t0 = time.perf_counter()
    out = step(params, opt, feed)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    sec, _ = time_fn(step, params, opt, feed, repeats=3)
    return {"batch": cfg.batch, "compile_s": compile_s,
            "step_time_s": sec,
            "images_per_s": cfg.batch / sec}


def bench_learning(cfg: BNNTrainerConfig) -> dict:
    res = train_bnn(cfg)
    losses = res.history["loss"]
    tail = losses[-max(1, len(losses) // 4):]
    drop = 1.0 - float(np.mean(tail)) / losses[0]
    return {
        "steps": cfg.steps,
        "batch": cfg.batch,
        "first_loss": losses[0],
        "tail_mean_loss": float(np.mean(tail)),
        "loss_drop": drop,
        "eval_loss": res.eval_loss,
        "eval_acc": res.eval_acc,
        "first_step_lr_scale": res.history["lr_scale"][0],
        "gates": {
            "loss_drops": drop >= LOSS_DROP_GATE,
            "above_chance_acc": res.eval_acc >= ACC_GATE,
            "first_step_live": res.history["lr_scale"][0] > 0.0,
        },
    }


def bench_dp(cfg: BNNTrainerConfig, steps: int) -> dict | None:
    if jax.device_count() < 2:
        return None
    n_dev = 2
    task = _BNNTask(cfg.model_config())
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("data",))
    data = list(
        b for _, b in zip(range(steps), synthetic_cifar_batches(
            DataConfig(global_batch=cfg.batch, seed=cfg.data_seed)))
    )
    out = {}
    for comp in DP_COMPRESSIONS:
        step = jax.jit(make_dp_train_step(
            task, cfg.train_config(), mesh, grad_compression=comp,
            clip_predicate=bnn_clip_predicate,
        ))
        params = init_bnn_params(jax.random.PRNGKey(cfg.seed))
        opt = init_opt_state(params)
        err = init_dp_error_feedback(params, n_dev)
        feed0 = {k: data[0][k] for k in ("images", "labels")}
        sec, _ = time_fn(step, params, opt, err, feed0, repeats=3)
        loss = None
        for b in data:
            feed = {k: b[k] for k in ("images", "labels")}
            params, opt, err, metrics = step(params, opt, err, feed)
            loss = float(metrics["loss"])
        out[comp] = {"step_time_s": sec, "final_loss": loss}
    base = out["none"]["final_loss"]
    out["gates"] = {
        # compressed runs must not blow up relative to fp32: same ballpark
        # loss after the same steps (EF makes this tight in practice)
        f"{c}_tracks_fp32": out[c]["final_loss"] <= max(2.0 * base,
                                                        base + 0.5)
        for c in ("int8", "signsgd")
    }
    return out


def collect_gates(doc: dict) -> dict:
    gates = dict(doc["learning"]["gates"])
    if doc["dp_compressions"] is not None:
        gates.update(doc["dp_compressions"]["gates"])
    return gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short run for CI wall-clock")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any gate fails")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = BNNTrainerConfig(steps=24, batch=32, lr=3e-3,
                               warmup_steps=2, eval_batches=2)
        dp_steps = 4
    else:
        cfg = BNNTrainerConfig(steps=40, batch=32, lr=3e-3,
                               warmup_steps=5, eval_batches=4)
        dp_steps = 8

    doc = {
        "step_time": bench_step_time(cfg),
        "learning": bench_learning(cfg),
        "dp_compressions": bench_dp(
            BNNTrainerConfig(steps=dp_steps, batch=16, warmup_steps=2),
            dp_steps,
        ),
    }
    write_bench(bench_path("train"), doc)
    gates = collect_gates(doc)
    for name, ok in gates.items():
        print(f"gate {name}: {'PASS' if ok else 'FAIL'}")
    if args.check and not all(gates.values()):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
