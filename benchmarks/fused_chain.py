"""Unfused vs fused packed BNN forward: wall time + structural bytes.

No TPU in this container, so the wall-clock numbers are CPU/interpret
measurements at validation scale (NOT a TPU perf claim); the structural
inter-layer traffic model is shape-derived and backend-independent
(DESIGN.md §4). Writes BENCH_fused.json at the repo root to seed the
perf trajectory across PRs.

  PYTHONPATH=src python -m benchmarks.fused_chain
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import bench_path, time_fn, write_bench
from benchmarks.kernel_microbench import fused_chain_traffic
from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    bnn_apply_fused,
    init_bnn_params,
    pack_bnn_params,
    pack_bnn_params_fused,
)

BENCH_PATH = bench_path("fused")


def run(batch: int = 8, verbose: bool = True, write: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    params = init_bnn_params(key)
    images = jax.random.normal(jax.random.fold_in(key, 1), (batch, 32, 32, 3))
    packed = pack_bnn_params(params)
    fused = pack_bnn_params_fused(params)

    cfg = BNNConfig(mode=QuantMode.PACKED, engine="xla")
    t_unfused, want = time_fn(
        jax.jit(lambda p, x: bnn_apply(p, x, cfg)), packed, images
    )
    t_fused, got = time_fn(
        jax.jit(lambda p, x: bnn_apply_fused(p, x, engine="xla")),
        fused, images,
    )
    bit_identical = bool(jnp.all(got == want))

    # Pallas interpret engine at tiny scale (interpreter is python-speed;
    # this validates the fused kernel path end to end, not TPU perf).
    small = images[:2]
    t_unfused_xnor, w2 = time_fn(
        lambda: bnn_apply(
            packed, small, BNNConfig(mode=QuantMode.PACKED, engine="xnor")
        ),
        repeats=1,
    )
    t_fused_xnor, g2 = time_fn(
        lambda: bnn_apply_fused(fused, small, engine="xnor"), repeats=1
    )
    bit_identical_xnor = bool(jnp.all(g2 == w2))

    chain = fused_chain_traffic(batch)
    result = {
        "batch": batch,
        "wall_time_s": {
            "unfused_packed_xla": t_unfused,
            "fused_packed_xla": t_fused,
            "speedup_xla": t_unfused / t_fused,
            "unfused_packed_xnor_interpret_b2": t_unfused_xnor,
            "fused_packed_xnor_interpret_b2": t_fused_xnor,
            "speedup_xnor_interpret": t_unfused_xnor / t_fused_xnor,
        },
        "logits_bit_identical": {
            "xla": bit_identical, "xnor": bit_identical_xnor
        },
        "interlayer_bytes": {
            "unfused": chain["total"]["unfused_bytes"],
            "fused": chain["total"]["fused_bytes"],
            "ratio": chain["total"]["bytes_ratio"],
        },
        "launches_per_binary_layer": {"unfused": 2, "fused": 1},
        "note": (
            "CPU-only numbers. The xla rows are NOT engine-matched: the "
            "unfused 'xla' engine lowers to unpack+float-dot (fast on "
            "CPU) while the fused fallback keeps the popcount GEMM; the "
            "xnor rows compare the same popcount engine fused vs "
            "unfused. The backend-independent claim is interlayer_bytes."
        ),
    }
    if verbose:
        wt = result["wall_time_s"]
        print(f"unfused packed (xla)  b{batch}: {wt['unfused_packed_xla']:.3f}s")
        print(f"fused packed   (xla)  b{batch}: {wt['fused_packed_xla']:.3f}s "
              f"({wt['speedup_xla']:.2f}x)")
        print(f"unfused packed (xnor-interpret) b2: "
              f"{wt['unfused_packed_xnor_interpret_b2']:.3f}s")
        print(f"fused packed   (xnor-interpret) b2: "
              f"{wt['fused_packed_xnor_interpret_b2']:.3f}s "
              f"({wt['speedup_xnor_interpret']:.2f}x)")
        print(f"logits bit-identical: {result['logits_bit_identical']}")
        ib = result["interlayer_bytes"]
        print(f"inter-layer bytes: {ib['unfused']/1e6:.1f} MB -> "
              f"{ib['fused']/1e6:.1f} MB ({ib['ratio']:.1f}x fewer)")
    if write:
        write_bench(BENCH_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    run()
