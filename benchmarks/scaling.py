"""Scaling benchmark: data-parallel throughput of the packed-BNN
serving engines across 1/2/4/8 simulated mesh devices (DESIGN.md §10).
Writes BENCH_scaling.json at the repo root.

What it measures, per engine x device count:

1. **Sharded forward wall** — ``bnn_serve_fn(mesh=make_serving_mesh(d))``
   (weights replicated, batch sharded over the 1-D ``data`` axis) on a
   fixed global batch, median-of-k via the shared ``_util.time_fn``
   protocol; throughput, speedup vs the 1-device dispatch and parallel
   efficiency (speedup / d) are derived.
2. **Bit identity** — the sharded logits at every device count are
   compared bit-for-bit against single-device dispatch (the §10
   contract; the test matrix asserts it, the benchmark records it).
3. **Structural replication cost** — the packed model's per-device
   bytes (replication is ~1.75 MB/device — XNOR-Net's 32x footprint
   win is what makes the collective-free deployment shape affordable)
   and per-device shard rows at each mesh size.

Devices are SIMULATED host devices: the module forces
``--xla_force_host_platform_device_count=8`` into ``XLA_FLAGS`` before
importing jax (a pre-set count in the environment wins). Wall-clock
scaling therefore measures real data parallelism only when the host
has cores to back the simulated devices: on a single-core host the
speedup verdict is recorded as ``null`` (with the reason) instead of a
meaningless number — the ``--check`` gate then passes vacuously, and
bit identity (which is core-count independent) is still enforced.

``--check`` (the CI gate, per ROADMAP Tending): exits nonzero if any
sharded run diverges from single-device logits, or if the interpret
path's best 4-device speedup lands under ``--min-speedup`` (default
1.5x) on a multi-core host.

  PYTHONPATH=src python -m benchmarks.scaling [--smoke] [--check]
"""

from __future__ import annotations

import os

SIM_DEVICES = 8

# Must precede the first jax backend touch; this module is an entry
# point, so import time is early enough. A count already in XLA_FLAGS
# (e.g. the CI leg's exported environment) wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={SIM_DEVICES}"
    ).strip()

import argparse  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks._util import bench_path, time_fn, write_bench  # noqa: E402
from repro.core.bnn import (  # noqa: E402
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.launch.mesh import make_serving_mesh  # noqa: E402

BENCH_PATH = bench_path("scaling")

DEVICE_COUNTS = (1, 2, 4, 8)
# The engines a scaled-out deployment actually flips between: the
# per-layer fused chain and the megakernel, each with its Pallas
# (interpret off-TPU) and pure-XLA lowering.
FULL_ENGINES = ("xla", "xnor", "megakernel_xla", "megakernel")
SMOKE_ENGINES = ("xla", "xnor", "megakernel")
INTERPRET_ENGINES = ("xnor", "megakernel")  # the gated path


def host_cores() -> int:
    """Cores actually available to this process — the physical ceiling
    on simulated-device parallelism."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def packed_model_bytes(packed: dict) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(packed)))


def measure_engine(engine: str, packed: dict, images, *,
                   repeats: int) -> dict:
    """Wall/throughput at every device count + bit-identity vs 1-dev."""
    out: dict = {}
    want = None
    for d in DEVICE_COUNTS:
        mesh = make_serving_mesh(d) if d > 1 else None
        fn = bnn_serve_fn(engine=engine, mesh=mesh)
        wall, logits = time_fn(fn, packed, images, repeats=repeats)
        logits = np.asarray(logits)
        if want is None:
            want = logits
        row = {
            "wall_s": wall,
            "images_per_s": images.shape[0] / wall,
            "shard_rows_per_device": images.shape[0] // d,
            "bit_identical_to_1dev": bool(np.array_equal(logits, want)),
        }
        if d > 1:
            row["speedup_vs_1dev"] = out["1"]["wall_s"] / wall
            row["efficiency"] = row["speedup_vs_1dev"] / d
        out[str(d)] = row
        print(f"  {engine:>15} d={d}: {wall*1e3:8.1f} ms  "
              f"{row['images_per_s']:7.1f} img/s"
              + (f"  speedup {row['speedup_vs_1dev']:.2f}x" if d > 1
                 else "")
              + ("" if row["bit_identical_to_1dev"]
                 else "  LOGITS DIVERGED"))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller batch, fewer repeats, skip "
                         "the slowest engine leg")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on logits divergence, or (on a "
                         "multi-core host) if the interpret path's "
                         "best 4-device speedup is under --min-speedup")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="gate: required best interpret-path speedup "
                         "at --gate-devices vs 1 device")
    ap.add_argument("--gate-devices", type=int, default=4,
                    choices=DEVICE_COUNTS)
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default 64, smoke 16; must "
                         "divide every device count)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    batch = args.batch or (16 if args.smoke else 64)
    if batch % max(DEVICE_COUNTS):
        raise SystemExit(f"--batch {batch} must divide "
                         f"{max(DEVICE_COUNTS)} devices")
    repeats = 2 if args.smoke else 3
    engines = SMOKE_ENGINES if args.smoke else FULL_ENGINES
    cores = host_cores()

    n_dev = jax.device_count()
    if n_dev < max(DEVICE_COUNTS):
        raise SystemExit(
            f"only {n_dev} jax devices — XLA_FLAGS was consumed before "
            "this module could force host devices; unset the existing "
            "xla_force_host_platform_device_count or run standalone"
        )
    print(f"scaling: {n_dev} simulated devices on {cores} host core(s), "
          f"batch {batch}, engines {engines}")

    params = init_bnn_params(jax.random.PRNGKey(args.seed))
    fused = pack_bnn_params_fused(params)
    mega = pack_bnn_params_megakernel(params)
    rng = np.random.default_rng(args.seed)
    images = jnp.asarray(
        rng.normal(size=(batch, 32, 32, 3)).astype(np.float32))

    scaling = {}
    for engine in engines:
        packed = mega if engine.startswith("megakernel") else fused
        scaling[engine] = measure_engine(engine, packed, images,
                                         repeats=repeats)

    identical = {
        e: all(r["bit_identical_to_1dev"] for r in rows.values())
        for e, rows in scaling.items()
    }

    # ---- verdict ---------------------------------------------------------
    gate_d = str(args.gate_devices)
    gated = [e for e in INTERPRET_ENGINES if e in scaling]
    speedups = {e: scaling[e][gate_d]["speedup_vs_1dev"] for e in gated}
    best_engine = max(speedups, key=speedups.get)
    parallel_host = cores >= 2
    if parallel_host:
        scaling_ok = speedups[best_engine] >= args.min_speedup
        note = (f"best interpret-path speedup at {gate_d} devices: "
                f"{speedups[best_engine]:.2f}x ({best_engine}); "
                f"gate >= {args.min_speedup}x")
    else:
        scaling_ok = None
        note = (f"single-core host ({cores} core available): simulated "
                "devices cannot run concurrently, wall-clock speedup "
                "is unmeasurable here — speedup gate skipped (bit "
                "identity still enforced); run on a multi-core host "
                "for the real verdict")
    verdict = {
        "bit_identical_all": all(identical.values()),
        "gate_devices": args.gate_devices,
        "min_speedup": args.min_speedup,
        "interpret_speedups_at_gate": speedups,
        "gate_engine": best_engine,
        "host_cores": cores,
        "scaling_ok": scaling_ok,
        "note": note,
    }
    print(f"verdict: {note}")

    write_bench(BENCH_PATH, {
        "config": {
            "batch": batch,
            "device_counts": list(DEVICE_COUNTS),
            "engines": list(engines),
            "simulated_devices": n_dev,
            "host_cores": cores,
            "repeats": repeats,
            "smoke": args.smoke,
        },
        "replication": {
            "packed_model_bytes_per_device": packed_model_bytes(fused),
            "megakernel_model_bytes_per_device": packed_model_bytes(mega),
            "collectives_in_forward": 0,
        },
        "scaling": scaling,
        "bit_identity": identical,
        "verdict": verdict,
    })

    if args.check:
        if not verdict["bit_identical_all"]:
            diverged = [e for e, ok in identical.items() if not ok]
            print(f"CHECK FAILED: sharded logits diverged for {diverged}")
            return 1
        if scaling_ok is False:
            print(f"CHECK FAILED: {note}")
            return 1
        print("CHECK OK" if scaling_ok else "CHECK OK (speedup gate "
              "skipped on single-core host)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
