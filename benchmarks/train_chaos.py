"""Training-chaos benchmark: a scripted fault plan against fault-free
controls at equal total steps (DESIGN.md §13). Writes
BENCH_train_chaos.json at the repo root.

Four resilient runs share one process (and therefore one jitted-step
cache — replays and controls never retrace):

1. **control**   — 8-device sign-SGD DP run, no faults. Its per-save
   param fingerprints are ground truth for every fixed-8-device gate.
2. **chaosA**    — the IDENTICAL run under a scripted plan: a simulated
   preemption, a torn checkpoint (MANIFEST deleted mid-write — which
   *amplifies* the next rollback past it), and a NaN batch. No device
   loss, so the device trajectory matches control's.
3. **controlB**  — device loss only: one host dies at a pinned step,
   8 -> 4 elastic shrink. This is the control for the shrink scenario:
   a device-count change alters the all-reduce summation order, so the
   fault-free 8-device run is NOT the right bit-identity reference —
   the run with the same device trajectory is.
4. **chaosB**    — chaosA's full plan PLUS the device loss. Must land
   bit-identical to controlB.

Gates (``--check`` exits nonzero on any failure):

* **zero_runs_lost**       — every run finishes all steps with finite
  params and a full loss history.
* **bit_identical_A/B**    — final params bit-for-bit equal to the
  matching control at equal total steps. Transient faults + the
  stateless (seed, step) data stream mean recovery replays exactly the
  clean updates; any drift is a resume bug.
* **sentinel_catches_all_nan** — 100% of injected NaN-batch steps
  appear in the sentinel's trip events.
* **ef_mass_conserved**    — the 8 -> 4 error-feedback fold reports
  relative mass delta <= 1e-5 in both shrink runs.
* **bounded_recompute**    — replayed steps <= checkpoint cadence x
  fired fault count, per run.
* **resume_points_match**  — every restore's param fingerprint equals
  the matching control's fingerprint at that checkpoint step.

  PYTHONPATH=src python -m benchmarks.train_chaos [--smoke] [--check]
"""

from __future__ import annotations

import os

SIM_DEVICES = 8

# Must precede the first jax backend touch; this module is an entry
# point, so import time is early enough. A count already in XLA_FLAGS
# (e.g. the CI leg's exported environment) wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={SIM_DEVICES}"
    ).strip()

import argparse  # noqa: E402
import shutil  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks._util import bench_path, write_bench  # noqa: E402
from repro.train.bnn_trainer import BNNTrainerConfig  # noqa: E402
from repro.train.resilience import (  # noqa: E402
    TrainFaultPlan,
    TrainFaultSpec,
    train_bnn_resilient,
)

EF_RTOL = 1e-5


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))


def _run(name: str, cfg_base: BNNTrainerConfig, root: str,
         plan: TrainFaultPlan | None):
    cfg = BNNTrainerConfig(
        **{**cfg_base.__dict__, "checkpoint_dir": os.path.join(root, name)}
    )
    result = train_bnn_resilient(
        cfg, faults=plan, n_devices=SIM_DEVICES, grad_compression="signsgd"
    )
    fired = len(plan.fired) if plan is not None else 0
    return {
        "name": name,
        "result": result,
        "fired": fired,
        "steps": cfg.steps,
        "cadence": cfg.checkpoint_every,
    }


def _summary(run) -> dict:
    r = run["result"]
    return {
        "steps": run["steps"],
        "faults_fired": run["fired"],
        "events": [e["kind"] for e in r.events],
        "recomputed_steps": r.recomputed_steps,
        "restore_points": r.restore_points,
        "device_trajectory": r.device_trajectory,
        "final_n_devices": r.n_devices,
        "final_loss": r.history["loss"][-1] if r.history["loss"] else None,
        "history_len": len(r.history["loss"]),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (16 steps, batch 16)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any gate fails")
    args = ap.parse_args(argv)

    if args.smoke:
        steps, batch, cadence = 12, 16, 3
        preempt_at, torn_at, nan_at, loss_at = 4, 6, 7, 10
    else:
        steps, batch, cadence = 24, 16, 6
        preempt_at, torn_at, nan_at, loss_at = 8, 12, 14, 20

    cfg_base = BNNTrainerConfig(
        steps=steps, batch=batch, checkpoint_every=cadence,
        eval_batches=0, checkpoint_dir=None,
    )
    chaos_specs = [
        TrainFaultSpec("preempt", at=preempt_at),
        TrainFaultSpec("torn_ckpt", at=torn_at, flavor="torn"),
        TrainFaultSpec("nan_batch", at=nan_at),
    ]
    loss_spec = TrainFaultSpec("device_loss", at=loss_at, host=5)

    root = tempfile.mkdtemp(prefix="train_chaos_")
    try:
        control = _run("control", cfg_base, root, None)
        plan_a = TrainFaultPlan(chaos_specs)
        chaos_a = _run("chaosA", cfg_base, root, plan_a)
        plan_cb = TrainFaultPlan([loss_spec])
        control_b = _run("controlB", cfg_base, root, plan_cb)
        plan_b = TrainFaultPlan(chaos_specs + [loss_spec])
        chaos_b = _run("chaosB", cfg_base, root, plan_b)
    finally:
        shutil.rmtree(root, ignore_errors=True)

    runs = [control, chaos_a, control_b, chaos_b]

    zero_runs_lost = all(
        len(r["result"].history["loss"]) == r["steps"]
        and _finite(r["result"].params)
        for r in runs
    )
    bit_identical_a = _trees_equal(control["result"].params,
                                   chaos_a["result"].params)
    bit_identical_b = _trees_equal(control_b["result"].params,
                                   chaos_b["result"].params)

    nan_steps = set(plan_a.steps_of("nan_batch"))
    caught = {
        e["step"] for r in (chaos_a, chaos_b)
        for e in r["result"].events if e["kind"] == "sentinel_nan"
    }
    sentinel_catches_all_nan = nan_steps <= caught

    folds = [
        e for r in (control_b, chaos_b)
        for e in r["result"].events if e["kind"] == "ef_folded"
    ]
    ef_mass_conserved = (
        len(folds) == 2
        and all(f["max_rel_delta"] <= EF_RTOL for f in folds)
        and all(f["n_old"] == 8 and f["n_new"] == 4 for f in folds)
    )

    bounded_recompute = all(
        r["result"].recomputed_steps <= r["cadence"] * max(r["fired"], 1)
        for r in runs
    )

    def _resumes_ok(chaos, ctrl) -> bool:
        fps = ctrl["result"].fingerprints
        return all(
            p["step"] in fps and p["params_sha"] == fps[p["step"]]
            for p in chaos["result"].restore_points
        )

    resume_points_match = (
        _resumes_ok(chaos_a, control) and _resumes_ok(chaos_b, control_b)
    )

    gates = {
        "zero_runs_lost": bool(zero_runs_lost),
        "bit_identical_A": bool(bit_identical_a),
        "bit_identical_B": bool(bit_identical_b),
        "sentinel_catches_all_nan": bool(sentinel_catches_all_nan),
        "ef_mass_conserved": bool(ef_mass_conserved),
        "bounded_recompute": bool(bounded_recompute),
        "resume_points_match": bool(resume_points_match),
    }
    gates["all_ok"] = all(gates.values())

    doc = {
        "config": {
            "smoke": bool(args.smoke), "steps": steps, "batch": batch,
            "checkpoint_every": cadence, "n_devices": SIM_DEVICES,
            "grad_compression": "signsgd", "ef_rtol": EF_RTOL,
            "fault_plan": {
                "preempt_at": preempt_at, "torn_ckpt_at": torn_at,
                "nan_batch_at": nan_at, "device_loss_at": loss_at,
                "device_loss_host": 5,
            },
        },
        "runs": {r["name"]: _summary(r) for r in runs},
        "ef_folds": folds,
        "gates": gates,
    }
    write_bench(bench_path("train_chaos"), doc)

    for name, ok in gates.items():
        print(f"  {name:28s} {'PASS' if ok else 'FAIL'}")
    for r in runs:
        res = r["result"]
        print(f"  {r['name']:10s} faults={r['fired']} "
              f"recomputed={res.recomputed_steps} "
              f"n_dev={res.n_devices} "
              f"final_loss={res.history['loss'][-1]:.4f}")
    if args.check and not gates["all_ok"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
