"""Shared benchmark plumbing: one timing protocol, one JSON schema.

Every BENCH writer uses the same three pieces so the perf trajectory is
comparable across PRs:

* :func:`time_fn` — warmup (compile) call, then MEDIAN of ``repeats``
  timed calls. Median, not mean: interpret-mode wall clocks on a small
  shared CPU see GC pauses and noisy neighbors, and a single outlier
  must not be able to flip a CI ``--check`` gate.
* :func:`stamp` — the environment fingerprint (jax version, backend,
  device kind) recorded into every BENCH file, mirroring the autotune
  cache's staleness stamps: a number is only comparable to another
  number measured on the same stack.
* :func:`write_bench` — wraps the payload as ``{"meta": stamp + schema
  version, **payload}`` and writes it at the repo root.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

import jax

BENCH_SCHEMA_VERSION = 1
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def bench_path(name: str) -> pathlib.Path:
    """Repo-root path for ``BENCH_<name>.json``."""
    return REPO_ROOT / f"BENCH_{name}.json"


def stamp() -> dict:
    """Environment fingerprint for a BENCH file's ``meta`` block."""
    try:
        device = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no devices at all
        device = "unknown"
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device": device,
    }


def time_fn(fn: Callable, *args, repeats: int = 3):
    """Median wall time of ``fn(*args)`` over ``repeats`` after one
    warmup (compile) call. Returns ``(seconds, last_output)``."""
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def write_bench(path: pathlib.Path, payload: dict, *,
                verbose: bool = True) -> dict:
    """Prepend the ``meta`` stamp, write ``path``, return the full doc."""
    doc = {"meta": stamp(), **payload}
    path.write_text(json.dumps(doc, indent=2) + "\n")
    if verbose:
        print(f"wrote {path}")
    return doc


__all__ = [
    "BENCH_SCHEMA_VERSION",
    "REPO_ROOT",
    "bench_path",
    "stamp",
    "time_fn",
    "write_bench",
]
