"""Direct packed-window conv vs the im2col fused chain: wall time,
bit-identity, and per-layer HBM bytes.

No TPU in this container, so wall-clock numbers are CPU/interpret
measurements at validation scale (NOT a TPU perf claim); the per-layer
traffic model is shape-derived and backend-independent (DESIGN.md §5):
the direct kernel never writes the ``[N*OH*OW, kH*kW*CW]`` packed patch
matrix to HBM, which the im2col path writes AND reads back per layer.
Writes BENCH_direct_conv.json at the repo root.

  PYTHONPATH=src python -m benchmarks.direct_conv [--check]

``--check`` turns the measurement into a regression gate: exit nonzero
if the direct path is slower than im2col on the fused xnor
(Pallas-interpret) chain — the ``speedup_xnor_interpret: 0.81``
regression of the old broadcast-formulation kernels must not return.
"""

from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import bench_path, time_fn, write_bench
from benchmarks.kernel_microbench import direct_conv_chain_traffic
from repro.core.bnn import (
    bnn_apply_fused,
    init_bnn_params,
    pack_bnn_params_fused,
)

BENCH_PATH = bench_path("direct_conv")


def run(batch: int = 8, verbose: bool = True, write: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    params = init_bnn_params(key)
    images = jax.random.normal(jax.random.fold_in(key, 1), (batch, 32, 32, 3))
    fused = pack_bnn_params_fused(params)

    t_im2col, want = time_fn(
        jax.jit(lambda p, x: bnn_apply_fused(p, x, engine="xla",
                                             conv_impl="im2col")),
        fused, images,
    )
    t_direct, got = time_fn(
        jax.jit(lambda p, x: bnn_apply_fused(p, x, engine="xla",
                                             conv_impl="direct")),
        fused, images,
    )
    bit_identical = bool(jnp.all(got == want))

    # Pallas interpret engine at tiny scale (interpreter is python-speed;
    # this validates the direct kernel path end to end, not TPU perf).
    # repeats=3: --check gates CI on the ratio of these two numbers, so
    # a single-shot measurement's noise (GC pause, noisy neighbor) must
    # not be able to flip it.
    small = images[:2]
    t_im2col_xnor, w2 = time_fn(
        lambda: bnn_apply_fused(fused, small, engine="xnor",
                                conv_impl="im2col"),
        repeats=3,
    )
    t_direct_xnor, g2 = time_fn(
        lambda: bnn_apply_fused(fused, small, engine="xnor",
                                conv_impl="direct"),
        repeats=3,
    )
    bit_identical_xnor = bool(jnp.all(g2 == w2))

    chain = direct_conv_chain_traffic(batch)
    result = {
        "batch": batch,
        "wall_time_s": {
            "im2col_fused_xla": t_im2col,
            "direct_fused_xla": t_direct,
            "speedup_xla": t_im2col / t_direct,
            "im2col_fused_xnor_interpret_b2": t_im2col_xnor,
            "direct_fused_xnor_interpret_b2": t_direct_xnor,
            "speedup_xnor_interpret": t_im2col_xnor / t_direct_xnor,
        },
        "logits_bit_identical": {
            "xla": bit_identical, "xnor": bit_identical_xnor
        },
        "traffic_model": {
            name: (
                row if name == "total" else {
                    "im2col_fused_bytes": row["im2col_fused_bytes"],
                    "direct_bytes": row["direct_bytes"],
                    "patch_matrix_bytes": row["patch_matrix_bytes"],
                    "bytes_ratio": row["bytes_ratio"],
                }
            )
            for name, row in chain.items()
        },
        "note": (
            "CPU-only numbers; wall times are XLA-fallback (full batch) "
            "and Pallas-interpret (b2) measurements, not TPU perf. The "
            "backend-independent claim is traffic_model: per conv layer "
            "the direct path skips the packed patch-matrix write+read."
        ),
    }
    if verbose:
        wt = result["wall_time_s"]
        print(f"im2col fused (xla) b{batch}: {wt['im2col_fused_xla']:.3f}s")
        print(f"direct fused (xla) b{batch}: {wt['direct_fused_xla']:.3f}s "
              f"({wt['speedup_xla']:.2f}x)")
        print(f"im2col fused (xnor-interpret) b2: "
              f"{wt['im2col_fused_xnor_interpret_b2']:.3f}s")
        print(f"direct fused (xnor-interpret) b2: "
              f"{wt['direct_fused_xnor_interpret_b2']:.3f}s "
              f"({wt['speedup_xnor_interpret']:.2f}x)")
        print(f"logits bit-identical: {result['logits_bit_identical']}")
        t = chain["total"]
        print(f"conv-layer HBM bytes: {t['im2col_fused_bytes']/1e6:.1f} MB "
              f"(im2col) -> {t['direct_bytes']/1e6:.1f} MB (direct) "
              f"({t['bytes_ratio']:.1f}x fewer)")
    if write:
        write_bench(BENCH_PATH, result, verbose=verbose)
    return result


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--check", action="store_true",
        help="exit nonzero if direct is slower than im2col on the fused "
             "xnor (interpret) path",
    )
    parser.add_argument("--batch", type=int, default=8)
    args = parser.parse_args()
    result = run(batch=args.batch)
    if args.check:
        speedup = result["wall_time_s"]["speedup_xnor_interpret"]
        if speedup < 1.0:
            print(
                f"FAIL: direct conv slower than im2col on the fused xnor "
                f"path (speedup_xnor_interpret={speedup:.2f} < 1.0)",
                file=sys.stderr,
            )
            sys.exit(1)
        print(f"check OK: speedup_xnor_interpret={speedup:.2f} >= 1.0")
