"""Benchmark harness entry point: one benchmark per paper table/figure
plus the roofline report.

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations


def main() -> None:
    print("=" * 72)
    print("Table 2 analogue — BNN CIFAR-10 inference, three kernel modes")
    print("=" * 72)
    from benchmarks import table2_bnn

    table2_bnn.run()

    print()
    print("=" * 72)
    print("Kernel microbench — binary-GEMM engines, traffic model (paper §3.2)")
    print("=" * 72)
    from benchmarks import kernel_microbench

    kernel_microbench.run()

    print()
    print("=" * 72)
    print("Fused packed chain — unfused vs fused forward (BENCH_fused.json)")
    print("=" * 72)
    from benchmarks import fused_chain

    fused_chain.run()

    print()
    print("=" * 72)
    print("Direct conv — im2col vs packed-window (BENCH_direct_conv.json)")
    print("=" * 72)
    from benchmarks import direct_conv

    direct_conv.run()

    print()
    print("=" * 72)
    print("Roofline table — (arch x shape x mesh) from the dry-run")
    print("=" * 72)
    from benchmarks import roofline_table

    roofline_table.run()


if __name__ == "__main__":
    main()
