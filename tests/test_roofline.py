"""Roofline accounting: the trip-count-aware HLO walker against
known-FLOP programs, collective detection, and the Roofline math."""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.roofline import analysis, hlo_cost


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    w = jnp.zeros((256, 256), jnp.float32)

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=11)
        return y.sum()

    c = hlo_cost.analyze(_compiled(f, jnp.ones((32, 256))).as_text())
    assert c.flops == 11 * 2 * 32 * 256 * 256


def test_nested_scan_flops_multiply():
    w = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None

        y, _ = lax.scan(outer, x, None, length=5)
        return y.sum()

    c = hlo_cost.analyze(_compiled(f, jnp.ones((16, 128))).as_text())
    assert c.flops == 15 * 2 * 16 * 128 * 128


def test_dus_into_stacked_buffer_counts_slice_not_buffer():
    # scan stacking writes [100, 64, 64] but per-step traffic is a slice
    def f(x):
        def body(c, _):
            c = c * 1.0001
            return c, c
        _, ys = lax.scan(body, x, None, length=100)
        return ys

    c = hlo_cost.analyze(_compiled(f, jnp.ones((64, 64))).as_text())
    # full-buffer accounting would be ~100 * 2 * 1.6MB = 330MB; slice-
    # wise is ~100 * (couple of 16KB tiles + loop-carry copies) ~ 13MB
    assert c.bytes < 20e6


def test_vmem_fusible_scope_classified_separately():
    def f(x):
        with jax.named_scope("vmem_fusible"):
            s = x @ x.T              # the "scores"
            p = jax.nn.softmax(s, -1)
        return (p @ x).sum()

    c = hlo_cost.analyze(_compiled(f, jnp.ones((128, 64))).as_text())
    assert c.fusible_bytes > 0


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops_per_chip=197e12,         # exactly 1s of compute
        hlo_bytes_per_chip=819e9 * 2,      # 2s of memory
        collective_bytes_per_chip=50e9 * 0.5,
        collective_breakdown={},
        model_flops=197e12 * 256 * 0.5,
    )
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 2.0)
    assert np.isclose(r.collective_s, 0.5)
    assert r.bottleneck == "memory"
    assert np.isclose(r.step_time_s, 2.0)
    assert np.isclose(r.mfu, 0.25)
    assert np.isclose(r.useful_flops_fraction, 0.5)


def test_count_params_moe_active_fraction():
    tree = {
        "layers": {
            "moe": {"up_proj": {"w": jnp.zeros((64, 16, 8))},
                    "router": {"w": jnp.zeros((64, 8))}},
            "attn": {"q_proj": {"w": jnp.zeros((8, 8))}},
        }
    }
    total = analysis.count_params(tree)
    active = analysis.count_params(tree, active_moe_fraction=2 / 64)
    assert total == 64 * 16 * 8 + 64 * 8 + 64
    assert active == 64 * 16 * 8 * (2 / 64) + 64 * 8 + 64


def test_model_flops_for_kinds():
    from repro.configs.base import ShapeConfig

    class C:  # minimal cfg stand-in
        pass

    train = ShapeConfig("t", 1024, 8, "train")
    dec = ShapeConfig("d", 1024, 8, "decode")
    assert analysis.model_flops_for(C(), train, 1e9, 1e9) == 6e9 * 8 * 1024
    assert analysis.model_flops_for(C(), dec, 1e9, 1e9) == 2e9 * 8


def test_collective_bytes_regex():
    txt = """
ENTRY %main (a: f32[16]) -> f32[16] {
  %ar = f32[4,8]{1,0} all-reduce(%a), to_apply=%add
  ROOT %r = f32[16]{0} copy(%ar)
}
"""
    out = analysis.collective_bytes(txt)
    assert out["all-reduce"] == 4 * 8 * 4 * 2.0  # ring multiplier
