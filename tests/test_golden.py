"""Golden-logits regression: the PACKED CIFAR-BNN logits for a fixed
seed are pinned in tests/golden/bnn_logits.json (float32 hex — exact),
so a kernel refactor that silently changes numerics fails tier-1
immediately instead of shipping.

The fixture is EXACT by design. Two legitimate reasons it can move:

* an intentional numerics change — regenerate with
  ``PYTHONPATH=src python scripts/gen_golden_logits.py`` and commit the
  diff (reviewers see exactly which logits moved);
* a jax/XLA upgrade that re-associates the float first-conv / final-BN
  math — the same ulp-level caveat as
  ``test_bnn_fused_matches_packed_with_trained_stats``. If only a
  handful of entries drift by <= 1e-4 right after a jax bump, that is
  toolchain noise, not a kernel bug: regenerate and note the version.
"""

import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    bnn_apply_fused,
    init_bnn_params,
    pack_bnn_params,
    pack_bnn_params_fused,
)

FIXTURE = pathlib.Path(__file__).parent / "golden" / "bnn_logits.json"


@pytest.fixture(scope="module")
def golden():
    data = json.loads(FIXTURE.read_text())
    logits = np.array(
        [[float.fromhex(v) for v in row] for row in data["logits_hex"]],
        np.float32,
    )
    assert list(logits.shape) == data["shape"]
    return data, logits


@pytest.fixture(scope="module")
def seeded():
    data = json.loads(FIXTURE.read_text())
    params = init_bnn_params(jax.random.PRNGKey(data["param_seed"]))
    images = jax.random.normal(
        jax.random.PRNGKey(data["image_seed"]),
        tuple(data["shape"][:1]) + (32, 32, 3),
    )
    return params, images


def test_packed_logits_match_golden(golden, seeded):
    _, want = golden
    params, images = seeded
    got = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)


def test_fused_pipeline_matches_golden(golden, seeded):
    """The fused packed pipeline is pinned to the SAME fixture — the
    bit-identity chain (fused == unfused PACKED) grounds out in one
    committed artifact rather than only in relative tests."""
    _, want = golden
    params, images = seeded
    got = bnn_apply_fused(pack_bnn_params_fused(params), images,
                          engine="xla")
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the conftest's 8 forced host devices")
def test_golden_invariant_to_device_count(golden, seeded):
    """ISSUE 7: the fixture is invariant to the serving mesh size — the
    whole session already runs under 8 forced host devices (conftest),
    and here the SAME pinned logits must come out of the mesh-sharded
    dispatch path at every mesh size that divides the fixture batch (2
    and 4 exact), plus the 8-device mesh through the ragged executor's
    bit-neutral pad-and-slice path (4 real rows padded to extent 8).
    Bit-identity holding is exactly why no fixture regen is needed."""
    from repro.core.bnn import bnn_serve_fn
    from repro.launch.mesh import make_serving_mesh
    from repro.serve import RaggedExecutorCache

    _, want = golden
    params, images = seeded
    fused = pack_bnn_params_fused(params)
    for n_dev in (2, 4):  # divide the 4-row fixture batch exactly
        fn = bnn_serve_fn(engine="xla", mesh=make_serving_mesh(n_dev))
        got = np.asarray(fn(fused, images), np.float32)
        np.testing.assert_array_equal(got, want)
    cache = RaggedExecutorCache(fused, engine="xla",
                                mesh=make_serving_mesh(8))
    got = np.asarray(cache.run(np.asarray(images)), np.float32)
    np.testing.assert_array_equal(got, want)


def test_golden_fixture_is_exact_hex(golden):
    """Guard the fixture format itself: hex floats must round-trip and
    carry the ±1-dot structure (integer-valued dots scaled by the BN
    affine make most entries near-integers — a wholesale format break
    shows up as NaNs/garbage here)."""
    data, logits = golden
    assert np.isfinite(logits).all()
    rt = [[float.fromhex(float(v).hex()) for v in row] for row in logits]
    np.testing.assert_array_equal(np.asarray(rt, np.float32), logits)
