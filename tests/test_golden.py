"""Golden-logits regression: the PACKED CIFAR-BNN logits of the
committed TRAINED checkpoint are pinned in tests/golden/bnn_logits.json
(float32 hex — exact), so a kernel refactor that silently changes
numerics fails tier-1 immediately instead of shipping.

Since the train-to-serve loop closed, the fixture is generated from
tests/golden/bnn_trained_ckpt.npz — a sign-form checkpoint
(core.bnn.save_binary_checkpoint) produced by a real STE training run
(examples/bnn_cifar.py). Regressing the logits a TRAINED model serves
is the point: a random init exercises the same kernels but not the
same stakes.

The fixture is EXACT by design. Two legitimate reasons it can move:

* an intentional numerics change — regenerate with
  ``PYTHONPATH=src python scripts/gen_golden_logits.py`` and commit the
  diff (reviewers see exactly which logits moved);
* a jax/XLA upgrade that re-associates the float first-conv / final-BN
  math — the same ulp-level caveat as
  ``test_bnn_fused_matches_packed_with_trained_stats``. If only a
  handful of entries drift by <= 1e-4 right after a jax bump, that is
  toolchain noise, not a kernel bug: regenerate and note the version.
"""

import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BINARY_CKPT_FORMAT,
    BNNConfig,
    bnn_apply,
    bnn_apply_fused,
    bnn_eval_logits,
    load_binary_checkpoint,
    pack_bnn_params,
    pack_bnn_params_fused,
)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
FIXTURE = GOLDEN_DIR / "bnn_logits.json"


@pytest.fixture(scope="module")
def golden():
    data = json.loads(FIXTURE.read_text())
    logits = np.array(
        [[float.fromhex(v) for v in row] for row in data["logits_hex"]],
        np.float32,
    )
    assert list(logits.shape) == data["shape"]
    return data, logits


@pytest.fixture(scope="module")
def seeded(golden):
    data, _ = golden
    assert "checkpoint" in data, (
        "fixture must be generated from the trained checkpoint "
        "(scripts/gen_golden_logits.py without --random-init)"
    )
    ckpt = FIXTURE.parent.parent.parent / data["checkpoint"]
    params = load_binary_checkpoint(ckpt)
    images = jax.random.normal(
        jax.random.PRNGKey(data["image_seed"]),
        tuple(data["shape"][:1]) + (32, 32, 3),
    )
    return params, images


def test_checkpoint_format_tag():
    with np.load(GOLDEN_DIR / "bnn_trained_ckpt.npz") as z:
        assert str(z["format"]) == BINARY_CKPT_FORMAT


def test_checkpoint_latents_are_sign_form(seeded):
    """The committed checkpoint stores 1 bit/weight; loading must
    reconstruct exact ±1.0 latents (sign(sign(w)) == sign(w) is what
    makes the forward bit-identical to the float run that produced
    it)."""
    params, _ = seeded
    for group in ("conv", "fc"):
        for layer in params[group]:
            w = np.asarray(layer["w"])
            assert set(np.unique(w)) <= {-1.0, 1.0}


def test_packed_logits_match_golden(golden, seeded):
    _, want = golden
    params, images = seeded
    got = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)


def test_float_boundary_matches_golden(golden, seeded):
    """The FAKE_QUANT eval forward — the reference the training loop
    optimizes — pins to the SAME fixture as the packed engines: this is
    the train-to-serve contract (DESIGN.md §12) grounded in a committed
    artifact."""
    _, want = golden
    params, images = seeded
    got = bnn_eval_logits(params, images)
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)


def test_fused_pipeline_matches_golden(golden, seeded):
    """The fused packed pipeline is pinned to the SAME fixture — the
    bit-identity chain (fused == unfused PACKED) grounds out in one
    committed artifact rather than only in relative tests."""
    _, want = golden
    params, images = seeded
    got = bnn_apply_fused(pack_bnn_params_fused(params), images,
                          engine="xla")
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the conftest's 8 forced host devices")
def test_golden_invariant_to_device_count(golden, seeded):
    """ISSUE 7: serving is invariant to the mesh size — the whole
    session already runs under 8 forced host devices (conftest), and
    the jitted single-device forward, the mesh-sharded dispatch at
    every mesh size that divides the fixture batch (2 and 4), and the
    8-device mesh through the ragged executor's bit-neutral
    pad-and-slice path (4 real rows padded to extent 8) must all agree
    BIT-IDENTICALLY with each other.

    Against the (eager-computed) fixture the jitted paths are pinned to
    <= 1 ulp instead: with a TRAINED checkpoint the final BN affine has
    b != 0, and XLA's jit-time FMA contraction of ``a*dot + b`` rounds
    once where the eager path rounds twice. Deterministic per build —
    the old random-init fixture masked it only because its folded
    b == 0 makes the FMA exact."""
    from repro.core.bnn import bnn_serve_fn
    from repro.launch.mesh import make_serving_mesh
    from repro.serve import RaggedExecutorCache

    _, want = golden
    params, images = seeded
    fused = pack_bnn_params_fused(params)
    base = np.asarray(bnn_serve_fn(engine="xla")(fused, images),
                      np.float32)
    np.testing.assert_allclose(base, want, rtol=0, atol=2.4e-7)
    for n_dev in (2, 4):  # divide the 4-row fixture batch exactly
        fn = bnn_serve_fn(engine="xla", mesh=make_serving_mesh(n_dev))
        got = np.asarray(fn(fused, images), np.float32)
        np.testing.assert_array_equal(got, base)
    cache = RaggedExecutorCache(fused, engine="xla",
                                mesh=make_serving_mesh(8))
    got = np.asarray(cache.run(np.asarray(images)), np.float32)
    np.testing.assert_array_equal(got, base)


def test_golden_fixture_is_exact_hex(golden):
    """Guard the fixture format itself: hex floats must round-trip and
    carry the ±1-dot structure (integer-valued dots scaled by the BN
    affine make most entries near-integers — a wholesale format break
    shows up as NaNs/garbage here)."""
    data, logits = golden
    assert np.isfinite(logits).all()
    rt = [[float.fromhex(float(v).hex()) for v in row] for row in logits]
    np.testing.assert_array_equal(np.asarray(rt, np.float32), logits)
