"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED same-family config and runs one
train step + one prefill+decode step on CPU, asserting output shapes
and finiteness. The FULL configs are exercised via the dry-run only.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    ASSIGNED,
    get_config,
    serve_policy,
    smoke_config,
    train_policy,
)
from repro.models.model_factory import build_model
from repro.train.step import TrainConfig, init_opt_state, make_train_step

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    b = {"tokens": toks, "labels": toks}
    if cfg.input_kind == "embeddings":
        b["input_embeds"] = jax.random.normal(
            key, (BATCH, SEQ, cfg.d_model), jnp.float32)
        if cfg.family != "encdec":
            del b["tokens"]
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 24 or arch == "seamless-m4t-large-v2"
    assert cfg.d_model % 16 == 0
    assert cfg.padded_vocab % 16 == 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, train_policy())
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    batch = _batch_for(cfg, key)
    params, opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert metrics["loss"].shape == ()
    # a second step must also be finite (optimizer state advanced)
    params, opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_packed_serving_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, serve_policy())
    key = jax.random.PRNGKey(0)
    params = model.pack(model.init(key))
    state = model.init_state(BATCH, SEQ + 4, dtype=jnp.float32)
    batch = _batch_for(cfg, key)
    batch.pop("labels")
    logits, state = jax.jit(model.prefill)(params, state, batch)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, state = jax.jit(model.decode_step)(params, state, {"tokens": tok})
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2)), arch


@pytest.mark.parametrize("arch", ["smollm-360m", "moonshot-v1-16b-a3b",
                                  "xlstm-1.3b"])
def test_decode_matches_parallel_forward(arch):
    """Prefill+decode must agree with the full parallel forward — the
    KV-cache/recurrent-state path is numerically consistent.

    MoE needs capacity high enough that no token drops: GShard dropping
    depends on how many tokens contend per expert, which legitimately
    differs between a 16-token forward and a 1-token decode."""
    import dataclasses

    from repro.models import transformer as tf

    cfg = smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    policy = train_policy()
    key = jax.random.PRNGKey(1)
    params = tf.init_lm_params(key, cfg)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size, jnp.int32)

    full_logits, _, _ = tf.lm_forward(params, cfg, policy, tokens=toks)

    state = tf.init_state(cfg, 1, 16, dtype=jnp.float32)
    _, state = tf.prefill(params, cfg, policy, state=state,
                          tokens=toks[:, :15])
    step_logits, _ = tf.decode_step(params, cfg, policy, state=state,
                                    tokens=toks[:, 15:16])
    import numpy as np
    np.testing.assert_allclose(
        step_logits, full_logits[:, -1, : cfg.vocab_size],
        atol=2e-3, rtol=2e-3,
    )
