"""Flash-attention Pallas kernel vs the pure-jnp oracle.

Shape/dtype sweep + causal/non-causal, interpret mode on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def ref_attention(q, k, v, *, causal):
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("bh,sq,skv,dh", [
    (2, 128, 128, 64),
    (1, 256, 256, 128),
    (3, 128, 256, 32),     # cross/kv-longer (non-causal only)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(bh, sq, skv, dh, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (bh, sq, dh), dtype)
    k = jax.random.normal(kk, (bh, skv, dh), dtype)
    v = jax.random.normal(kv_, (bh, skv, dh), dtype)
    causal = sq == skv
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                          interpret=True)
    ref = ref_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, atol=tol, rtol=tol
    )


def test_flash_causal_first_row_is_v0():
    # position 0 attends only to kv 0
    q = jnp.ones((1, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 32))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32,
                          interpret=True)
    np.testing.assert_allclose(out[0, 0], v[0, 0], atol=1e-5, rtol=1e-5)


def test_flash_block_shape_invariance():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 256, 64))
    k = jax.random.normal(key, (2, 256, 64))
    v = jax.random.normal(key, (2, 256, 64))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_kv=128,
                        interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=256, block_kv=32,
                        interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
