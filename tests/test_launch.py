"""End-to-end launch drivers: train (with checkpoint-resume) and serve."""

import numpy as np

from repro.launch.serve import serve
from repro.launch.train import train


def test_train_learns_and_checkpoints(tmp_path):
    out = train("smollm-360m", smoke=True, steps=12, batch=4, seq=32,
                lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=5,
                log_every=100)
    assert len(out["losses"]) == 12
    assert np.isfinite(out["losses"]).all()

    # resume picks up from the persisted step (10), not from scratch
    out2 = train("smollm-360m", smoke=True, steps=14, batch=4, seq=32,
                 lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=5,
                 log_every=100)
    assert len(out2["losses"]) == 4  # steps 10..13 only


def test_serve_packed_generates():
    r = serve("qwen2.5-3b", smoke=True, batch=2, prompt_len=8, gen=4,
              quantized=True)
    assert r["tokens"].shape == (2, 4)
    assert r["tok_per_s"] > 0
