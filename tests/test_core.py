"""Core library behaviour: bitops semantics, STE gradients, layer modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops
from repro.core.binarize import (
    QuantMode,
    binarize_activations,
    binarize_weights,
    ste_sign,
)
from repro.core.im2col import col2im, filters_to_matrix, im2col
from repro.core.layers import (
    BitLinearConfig,
    bit_conv2d,
    bit_linear,
    init_conv,
    init_linear,
    pack_conv_params,
    pack_linear_params,
)

KEY = jax.random.PRNGKey(0)


# ------------------------------ bitops --------------------------------------

def test_pack_bits_lsb_first():
    # element j*32+b maps to bit b of word j.
    x = -jnp.ones((64,))
    x = x.at[0].set(1.0).at[33].set(1.0)
    words = bitops.pack_bits(x)
    assert int(words[0]) == 1          # bit 0 of word 0
    assert int(words[1]) == 2          # bit 1 of word 1


def test_pack_sign_zero_is_plus_one():
    x = jnp.zeros((32,))
    assert int(bitops.pack_bits(x)[0]) == -1  # all 32 bits set (int32 view)


def test_xnor_popcount_matmul_blocked_equals_unblocked():
    key = KEY
    w = jax.random.normal(jax.random.fold_in(key, 0), (17, 224))
    x = jax.random.normal(jax.random.fold_in(key, 1), (224, 23))
    wp, xp = bitops.pack_bits(w, -1), bitops.pack_bits(x, 0)
    a = bitops.xnor_popcount_matmul(wp, xp, 224, block_kw=2)
    b = bitops.xnor_popcount_matmul(wp, xp, 224, block_kw=64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kw,seed", [(1, 0), (3, 7), (8, 123)])
def test_pack_unpack_identity(kw, seed):
    # (hypothesis sweep of this invariant lives in test_properties.py)
    x = jax.random.normal(jax.random.PRNGKey(seed), (kw * 32, 5))
    signs = jnp.where(x >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(
        np.asarray(bitops.unpack_bits(bitops.pack_bits(x, 0), 0)),
        np.asarray(signs),
    )


def test_packed_matmul_unpack_equals_sign_matmul():
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (48, 96))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (96, 12))
    wp = bitops.pack_bits(w, -1)
    got = bitops.packed_matmul_unpack(wp, x, compute_dtype=jnp.float32)
    want = jnp.where(w >= 0, 1.0, -1.0) @ x
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ------------------------------ binarize ------------------------------------

def test_ste_sign_forward():
    x = jnp.array([-2.0, -0.0, 0.0, 0.5])
    np.testing.assert_array_equal(
        np.asarray(ste_sign(x)), np.array([-1.0, 1.0, 1.0, 1.0])
    )


def test_ste_sign_gradient_htanh_window():
    g = jax.grad(lambda v: ste_sign(v).sum())(
        jnp.array([-2.0, -1.0, -0.5, 0.0, 0.7, 1.0, 3.0])
    )
    np.testing.assert_array_equal(
        np.asarray(g), np.array([0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0])
    )


def test_binarize_weights_scale():
    w = jnp.array([[1.0, -3.0], [0.5, 0.5]])
    wb, alpha = binarize_weights(w, scale_axis=-1)
    np.testing.assert_array_equal(np.asarray(wb), np.array([[1, -1], [1, 1]]))
    np.testing.assert_allclose(np.asarray(alpha).ravel(), [2.0, 0.5])


def test_binarize_activations_values():
    x = jnp.array([-5.0, -0.2, 0.0, 0.3, 9.0])
    np.testing.assert_array_equal(
        np.asarray(binarize_activations(x)), np.array([-1, -1, 1, 1, 1])
    )


# ------------------------------ im2col --------------------------------------

def test_im2col_matches_lax_conv():
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 9, 11, 5))
    w = jax.random.normal(jax.random.fold_in(KEY, 5), (7, 3, 3, 5))
    patches, (oh, ow) = im2col(x, 3, 3, stride=2, pad=1)
    y = col2im(patches @ filters_to_matrix(w).T, oh, ow)
    want = jax.lax.conv_general_dilated(
        x, jnp.transpose(w, (1, 2, 3, 0)), (2, 2), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


# ------------------------------ layers --------------------------------------

ENGINES = ["xnor", "unpack", "xla"]


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("in_f", [256, 100])
def test_bit_linear_packed_equals_fake_quant(engine, in_f):
    p = init_linear(jax.random.fold_in(KEY, 6), in_f, 64)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (9, in_f))
    want = bit_linear(p, x, BitLinearConfig(mode=QuantMode.FAKE_QUANT))
    got = bit_linear(
        pack_linear_params(p), x,
        BitLinearConfig(mode=QuantMode.PACKED, engine=engine),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("engine", ENGINES)
def test_bit_conv2d_packed_equals_fake_quant(engine):
    p = init_conv(jax.random.fold_in(KEY, 8), 3, 3, 16, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 8, 8, 16))
    want = bit_conv2d(p, x, BitLinearConfig(mode=QuantMode.FAKE_QUANT), pad=1)
    got = bit_conv2d(
        pack_conv_params(p), x,
        BitLinearConfig(mode=QuantMode.PACKED, engine=engine),
        pad=1, kh=3, kw=3,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_bit_linear_weight_only_mode():
    """binarize_acts=False: real activations vs ±1 weights (LM serving)."""
    p = init_linear(jax.random.fold_in(KEY, 10), 128, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 11), (4, 128))
    want = x @ jnp.where(p["w"] >= 0, 1.0, -1.0).T + p["b"]
    got = bit_linear(
        pack_linear_params(p), x,
        BitLinearConfig(mode=QuantMode.PACKED, engine="xla", binarize_acts=False),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_bit_linear_scale_factor():
    p = init_linear(jax.random.fold_in(KEY, 12), 64, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 13), (3, 64))
    want = bit_linear(
        p, x, BitLinearConfig(mode=QuantMode.FAKE_QUANT, use_scale=True)
    )
    got = bit_linear(
        pack_linear_params(p, use_scale=True), x,
        BitLinearConfig(mode=QuantMode.PACKED, engine="xla", use_scale=True),
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_fake_quant_is_trainable():
    """Loss decreases under STE on a realizable ±1 regression — the BNN
    training recipe (latent fp weights, binary forward) actually learns."""
    p = init_linear(jax.random.fold_in(KEY, 14), 32, 4)
    x = jax.random.normal(jax.random.fold_in(KEY, 15), (64, 32))
    w_true = jnp.where(
        jax.random.normal(jax.random.fold_in(KEY, 16), (4, 32)) >= 0, 1.0, -1.0
    )
    y = x @ w_true.T
    cfg = BitLinearConfig(
        mode=QuantMode.FAKE_QUANT, binarize_acts=False, use_scale=True
    )

    def loss(params):
        return jnp.mean((bit_linear(params, x, cfg) - y) ** 2)

    l0 = loss(p)
    for _ in range(150):
        g = jax.grad(loss)(p)
        p = jax.tree.map(lambda a, b: a - 0.02 * b, p, g)
    assert loss(p) < l0 * 0.7
