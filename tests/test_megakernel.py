"""Megakernel (DESIGN.md §8): chain/conv-stage kernels bit-exact vs
their XLA oracles and the per-layer fused pipeline, stacked-padding
conventions, block-config invariance, and BNN-level logits parity
across engine x conv_impl x blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops
from repro.core.layers import pack_conv_aligned, stack_chain_layers
from repro.kernels import ops as kops
from repro.kernels.autotune import BlockConfig

KEY = jax.random.PRNGKey(7)


def _rand_fused_layer(key, m, k):
    """One fused-layer param dict {w_packed, a, b} with ragged-K packing
    (weight pad bits -1, the pack_linear_params convention)."""
    kw = -(-k // 32)
    w = jax.random.normal(key, (m, k))
    wpad = jnp.pad(w, ((0, 0), (0, kw * 32 - k)), constant_values=-1.0)
    return {
        "w_packed": bitops.pack_bits(wpad, axis=-1),
        "a": jax.random.normal(jax.random.fold_in(key, 1), (m,)),
        "b": jax.random.normal(jax.random.fold_in(key, 2), (m,)),
    }


def _rand_packed_acts(key, k, n):
    """Packed [ceil(k/32), N] activations with +1 K-pad bits."""
    x = jax.random.normal(key, (k, n))
    xpad = jnp.pad(x, ((0, -k % 32), (0, 0)), constant_values=1.0)
    return bitops.pack_bits(xpad, axis=0)


def _chain_fixture(dims=(70, 50, 40, 33), n=8):
    """Ragged chain: per-layer params, stacked operands, packed input."""
    layers, k_bits = [], []
    for i in range(len(dims) - 1):
        k, m = dims[i], dims[i + 1]
        layers.append(_rand_fused_layer(jax.random.fold_in(KEY, 10 + i), m, k))
        k_bits.append(k)
    stack = stack_chain_layers(layers)
    xp = _rand_packed_acts(jax.random.fold_in(KEY, 99), dims[0], n)
    return layers, stack, tuple(k_bits), xp, dims


def _seq_fused_reference(layers, k_bits, xp):
    """The per-layer reference: sequential fused_xnor_layer calls."""
    act = xp
    for p, k in zip(layers, k_bits):
        act = bitops.fused_xnor_layer(
            p["w_packed"], act[: p["w_packed"].shape[1]], k, p["a"], p["b"]
        )
    return act


# ---------------------------------------------------------------------------
# Chain kernel
# ---------------------------------------------------------------------------

def test_chain_matches_per_layer_fused_ragged():
    """One-launch chain == sequential fused layers, bit for bit, on a
    fully ragged chain (no dim is a multiple of 32)."""
    layers, stack, k_bits, xp, dims = _chain_fixture()
    want = _seq_fused_reference(layers, k_bits, xp)
    got = kops.megakernel_chain(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1]
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chain_oracle_matches_kernel():
    layers, stack, k_bits, xp, dims = _chain_fixture()
    want = bitops.megakernel_chain_xla(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1]
    )
    got = kops.megakernel_chain(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1]
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chain_final_gemm_matches_packed_head():
    """The in-launch epilogue-free head == a standalone xnor GEMM on
    the chain output (the ragged 10-class CIFAR head shape)."""
    layers, stack, k_bits, xp, dims = _chain_fixture()
    final_k = dims[-1]
    fin = _rand_fused_layer(jax.random.fold_in(KEY, 77), 10, final_k)
    chain_out = _seq_fused_reference(layers, k_bits, xp)
    want = bitops.xnor_popcount_matmul(
        fin["w_packed"], chain_out[: fin["w_packed"].shape[1]], final_k
    )
    for engine_out in (
        kops.megakernel_chain(
            stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
            final_wp=fin["w_packed"], final_k_bits=final_k,
        ),
        bitops.megakernel_chain_xla(
            stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
            final_wp=fin["w_packed"], final_k_bits=final_k,
        ),
    ):
        np.testing.assert_array_equal(np.asarray(engine_out),
                                      np.asarray(want))


def test_chain_block_config_invariance():
    """block_n / word_group are pure performance knobs: every tiling
    (including ragged word groups and batch splits) is bit-identical."""
    layers, stack, k_bits, xp, dims = _chain_fixture(n=16)
    want = _seq_fused_reference(layers, k_bits, xp)
    for bn, wg in [(8, 3), (16, 1), (128, 16)]:
        got = kops.megakernel_chain(
            stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
            block_n=bn, word_group=wg,
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want),
            err_msg=f"block_n={bn} word_group={wg}",
        )


def test_stacked_padding_conventions():
    """stack_chain_layers emits the exact pad values the chain kernel's
    neutrality argument relies on: zero weight rows/words, a=0, b=+1."""
    layers, stack, k_bits, _, dims = _chain_fixture()
    l = len(layers)
    m_max = stack["w"].shape[1]
    for i, p in enumerate(layers):
        m, kw = p["w_packed"].shape
        np.testing.assert_array_equal(
            np.asarray(stack["w"][i, :m, :kw]), np.asarray(p["w_packed"])
        )
        assert not np.asarray(stack["w"][i, m:]).any()
        assert not np.asarray(stack["w"][i, :, kw:]).any()
        assert not np.asarray(stack["a"][i, m:]).any()
        np.testing.assert_array_equal(
            np.asarray(stack["b"][i, m:]), np.ones(m_max - m, np.float32)
        )


# ---------------------------------------------------------------------------
# Ragged / masked-tail batch path (DESIGN.md §9)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 3, 8, 13])
def test_chain_ragged_tile_matches_oracle(n):
    """ragged_tile dispatch (tile-padded extent, block_n clamped to a
    single exact tile) is bit-identical to the exact-N oracle at every
    batch size around the tile seams, head included."""
    layers, stack, k_bits, _, dims = _chain_fixture(n=n)
    xp = _rand_packed_acts(jax.random.fold_in(KEY, 200 + n), dims[0], n)
    final_k = dims[-1]
    fin = _rand_fused_layer(jax.random.fold_in(KEY, 77), 10, final_k)
    want = bitops.megakernel_chain_xla(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
        final_wp=fin["w_packed"], final_k_bits=final_k,
    )
    got = kops.megakernel_chain(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
        final_wp=fin["w_packed"], final_k_bits=final_k,
        ragged_tile=kops.RAGGED_TILE_N,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chain_masked_tail_grid_matches_ragged_oracle():
    """Multi-tile masked tail: force block_n below the tile-padded
    extent so the tail grid step hangs past n_real, and assert the RAW
    launch (pad columns included) against megakernel_chain_ragged_xla —
    real columns exact, overhang columns zeroed in-kernel."""
    from repro.kernels import megakernel as mega_kernel
    from repro.kernels.popcount import PACK_BITS

    layers, stack, k_bits, _, dims = _chain_fixture()
    n, tile, block_n = 37, 8, 16      # n_tile 40 > block_n: tail masks
    xp = _rand_packed_acts(jax.random.fold_in(KEY, 300), dims[0], n)
    n_pad = -(-n // block_n) * block_n
    l, m_max, kw_max = stack["w"].shape
    word_group = 1
    kw_act = max(kw_max, m_max // PACK_BITS)
    xp_pad = jnp.pad(xp, ((0, kw_act - xp.shape[0]), (0, n_pad - n)),
                     constant_values=-1)
    kw_true = [-(-k // PACK_BITS) for k in k_bits]
    got = mega_kernel.megakernel_chain(
        stack["w"], stack["a"], stack["b"],
        jnp.asarray(k_bits, jnp.int32)[:, None],
        jnp.asarray(kw_true, jnp.int32)[:, None],
        xp_pad, None, jnp.full((1, 1), n, jnp.int32),
        block_n=block_n, word_group=word_group,
        interpret=True,
    )
    want = bitops.megakernel_chain_ragged_xla(
        stack["w"], stack["a"], stack["b"], k_bits, xp_pad[:, :n_pad],
        dims[-1], n,
    )
    rows = -(-dims[-1] // PACK_BITS)
    np.testing.assert_array_equal(np.asarray(got[:rows]),
                                  np.asarray(want[:rows]))
    # the overhang columns really are pinned to zero
    assert not np.asarray(got[:rows, n:]).any()


def test_ragged_oracle_zeroes_pad_columns():
    layers, stack, k_bits, xp, dims = _chain_fixture(n=8)
    out = bitops.megakernel_chain_ragged_xla(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1], 5,
    )
    exact = bitops.megakernel_chain_xla(
        stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
    )
    np.testing.assert_array_equal(np.asarray(out[:, :5]),
                                  np.asarray(exact[:, :5]))
    assert not np.asarray(out[:, 5:]).any()


# ---------------------------------------------------------------------------
# Conv-stage kernel
# ---------------------------------------------------------------------------

def _conv_stage_fixture(chans=(40, 50, 70), hw=8, n=2):
    """Ragged-channel two-conv stage: per-layer aligned packed filters,
    affines, channel-packed input map."""
    weights, a, b, k_bits = [], [], [], []
    for l in range(len(chans) - 1):
        cin, cout = chans[l], chans[l + 1]
        wkey = jax.random.fold_in(KEY, 30 + l)
        p = pack_conv_aligned(
            {"w": jax.random.normal(wkey, (cout, 3, 3, cin))}
        )
        weights.append(p["w_packed"])
        a.append(jax.random.normal(jax.random.fold_in(wkey, 1), (cout,)))
        b.append(jax.random.normal(jax.random.fold_in(wkey, 2), (cout,)))
        k_bits.append(3 * 3 * cin)
    x = jax.random.normal(jax.random.fold_in(KEY, 40), (n, hw, hw, chans[0]))
    xp = bitops.pack_channels(jnp.clip(x, -1, 1))
    return tuple(weights), tuple(a), tuple(b), tuple(k_bits), xp


@pytest.mark.parametrize("pool", [True, False])
def test_conv_stage_matches_per_layer_oracle(pool):
    """One-launch conv stage == chained direct_conv_oracle (+ OR-pool),
    bit for bit, with ragged channel counts at every boundary."""
    weights, a, b, k_bits, xp = _conv_stage_fixture()
    want = bitops.conv_stage_xla(xp, weights, a, b, k_bits, pool=pool)
    got = kops.megakernel_conv_stage(xp, weights, a, b, k_bits, pool=pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_stage_single_layer():
    """A one-conv stage (the CIFAR net's first pool stage) matches the
    standalone fused direct conv + packed pool."""
    weights, a, b, k_bits, xp = _conv_stage_fixture(chans=(40, 50))
    per_layer = kops.fused_direct_conv(
        weights[0], xp, k_bits[0], a[0], b[0], kh=3, kw=3, stride=1, pad=1
    )
    want = bitops.maxpool2_packed(per_layer)
    got = kops.megakernel_conv_stage(xp, weights, a, b, k_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_stage_word_group_invariance():
    weights, a, b, k_bits, xp = _conv_stage_fixture(n=1)
    want = kops.megakernel_conv_stage(xp, weights, a, b, k_bits)
    for wg in (1, 3, 64):
        got = kops.megakernel_conv_stage(
            xp, weights, a, b, k_bits, word_group=wg
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=f"word_group={wg}"
        )


# ---------------------------------------------------------------------------
# BNN-level: logits parity across engine x conv_impl x blocks
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bnn_setup():
    from repro.core.bnn import (
        init_bnn_params,
        pack_bnn_params_fused,
        pack_bnn_params_megakernel,
    )

    params = init_bnn_params(jax.random.PRNGKey(42))
    images = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 32, 32, 3))
    return (
        pack_bnn_params_fused(params),
        pack_bnn_params_megakernel(params),
        images,
    )


def test_bnn_megakernel_matches_fused_all_combos(bnn_setup):
    """Acceptance invariant (ISSUE 5): megakernel logits bit-identical
    to bnn_apply_fused for every fused engine x conv_impl (and both
    megakernel engines) — the ragged 10-class head included."""
    from repro.core.bnn import bnn_apply_fused, bnn_apply_megakernel

    fused, mega, images = bnn_setup
    want = bnn_apply_fused(fused, images, engine="xla")
    got = bnn_apply_megakernel(mega, images, engine="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # interpret-mode Pallas engines at tiny scale
    small = images[:2]
    want_small = np.asarray(want[:2])
    got_xnor = bnn_apply_megakernel(mega, small, engine="xnor")
    np.testing.assert_array_equal(np.asarray(got_xnor), want_small)
    for engine in ("xla", "xnor"):
        for conv_impl in ("im2col", "direct"):
            ref = bnn_apply_fused(fused, small, engine=engine,
                                  conv_impl=conv_impl)
            np.testing.assert_array_equal(
                np.asarray(ref), want_small,
                err_msg=f"fused {engine}/{conv_impl} drifted",
            )


def test_bnn_megakernel_block_config_invariance(bnn_setup):
    from repro.core.bnn import bnn_apply_megakernel

    fused, mega, images = bnn_setup
    small = images[:2]
    want = np.asarray(bnn_apply_megakernel(mega, small, engine="xla"))
    for blocks in (
        "auto",
        BlockConfig(block_n=128, word_group=4),
        BlockConfig(block_n=256, word_group=32),
    ):
        got = bnn_apply_megakernel(mega, small, engine="xnor",
                                   blocks=blocks)
        np.testing.assert_array_equal(
            np.asarray(got), want, err_msg=f"blocks={blocks}"
        )


def test_pack_bnn_params_megakernel_structure(bnn_setup):
    """The megakernel pack pre-stacks the FC trunk at pack time and
    keeps the fused per-layer conv params (true shapes, tap-aligned)."""
    from repro.core.bnn import FC_SIZES

    fused, mega, _ = bnn_setup
    l = len(FC_SIZES) - 1
    m_max = max(f for _, f in FC_SIZES[:-1])
    kw_max = max(-(-f // 32) for f, _ in FC_SIZES[:-1])
    assert mega["fc_stack"]["w"].shape == (l, m_max, kw_max)
    assert mega["fc_stack"]["a"].shape == (l, m_max)
    assert set(mega["fc_final"]) >= {"w_packed", "b"}
    for pf, pm in zip(fused["conv"][1:], mega["conv"][1:]):
        np.testing.assert_array_equal(
            np.asarray(pf["w_packed"]), np.asarray(pm["w_packed"])
        )


def test_megakernel_vmem_model_and_resolution():
    """The weights-resident VMEM model admits the CIFAR FC trunk and
    the resolver clamps the batch tile to the padded batch."""
    from repro.kernels import autotune

    assert autotune.megakernel_vmem(2, 1024, 256, 128, final_m=16) \
        <= autotune.MEGAKERNEL_VMEM_BUDGET
    bn, wg = autotune.resolve_megakernel_block_n(
        2, 1024, 256, 4, "auto", "auto", final_m=16
    )
    assert bn == 128 and wg >= 1  # clamped to round_up(4, 128)
    bn, _ = autotune.resolve_megakernel_block_n(
        2, 1024, 256, 4, 512, 8, final_m=16
    )
    assert bn == 128  # explicit request clamped too


def test_megakernel_tune_block_n_caches(tmp_path, monkeypatch):
    """tune_block_n persists a bnn_megakernel entry the resolver then
    serves (same staleness-stamped cache as every other kernel)."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    layers, stack, k_bits, xp, dims = _chain_fixture(n=256)
    shape = autotune.megakernel_shape(*stack["w"].shape, 256)

    def fn(bn):
        return kops.megakernel_chain(
            stack["w"], stack["a"], stack["b"], k_bits, xp, dims[-1],
            block_n=bn,
        )

    timings = {}
    best = autotune.tune_block_n(
        autotune.MEGAKERNEL_KERNEL, shape, fn, candidates=(64, 256),
        repeats=1, timings=timings,
    )
    assert best in (64, 256) and set(timings) == {64, 256}
    l, m_max, kw_max = stack["w"].shape
    bn, _ = autotune.resolve_megakernel_block_n(
        l, m_max, kw_max, 256, "auto", 8
    )
    assert bn == best
