"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Every kernel sweeps shapes (aligned, unaligned, tiny, rectangular) and
is asserted allclose/bit-exact against ``repro.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitops
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand_pm1(key, shape):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


SHAPES = [
    (128, 256, 128),   # tile-aligned
    (96, 320, 200),    # M/N unaligned
    (128, 96, 128),    # KW < block_kw after packing (96/32 = 3 words)
    (1, 32, 1),        # minimal
    (257, 544, 130),   # everything unaligned
    (64, 1024, 512),   # deep K
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_xnor_gemm_matches_float_truth(m, k, n):
    key = jax.random.PRNGKey(m * 7 + k * 3 + n)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    truth = ref.binary_matmul_ref(wb, xb)
    wp = bitops.pack_bits(wb, axis=-1)
    xp = bitops.pack_bits(xb, axis=0)
    out = ops.xnor_gemm(wp, xp, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(truth))


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_xnor_gemm_matches_ref_oracle(m, k, n):
    key = jax.random.PRNGKey(m + k + n)
    wp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 0), (m, k)), axis=-1)
    xp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 1), (k, n)), axis=0)
    out = ops.xnor_gemm(wp, xp, k, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.xnor_gemm_ref(wp, xp, k))
    )


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_unpack_gemm_matches_oracle(m, k, n):
    key = jax.random.PRNGKey(m ^ k ^ n)
    w = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    x = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    wp = bitops.pack_bits(w, axis=-1)
    out = ops.unpack_gemm(wp, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.unpack_gemm_ref(wp, x)),
        rtol=1e-5, atol=1e-4,
    )


@pytest.mark.parametrize(
    "k,n", [(32, 128), (64, 100), (256, 1), (1024, 333), (32, 129)]
)
def test_pack_kernel_matches_ref(k, n):
    x = jax.random.normal(jax.random.PRNGKey(k + n), (k, n))
    out = ops.pack_rows(x, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.pack_ref(x, axis=0))
    )


@pytest.mark.parametrize("bm,bn,bkw", [(128, 128, 16), (256, 128, 8), (128, 256, 32)])
def test_xnor_gemm_block_shape_invariance(bm, bn, bkw):
    """Result must not depend on the chosen tiling."""
    key = jax.random.PRNGKey(9)
    m, k, n = 160, 640, 96
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    wp, xp = bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0)
    out = ops.xnor_gemm(
        wp, xp, k, block_m=bm, block_n=bn, block_kw=bkw, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.binary_matmul_ref(wb, xb))
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_unpack_gemm_dtypes(dtype):
    key = jax.random.PRNGKey(11)
    w = _rand_pm1(jax.random.fold_in(key, 0), (64, 128))
    x = jax.random.normal(jax.random.fold_in(key, 1), (128, 64)).astype(dtype)
    wp = bitops.pack_bits(w, axis=-1)
    out = ops.unpack_gemm(wp, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.unpack_gemm_ref(wp, x.astype(jnp.float32))),
        rtol=2e-2, atol=2e-1,
    )


# --------------------------- property-based ---------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    kw=st.integers(1, 12),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_xnor_gemm_property(m, kw, n, seed):
    """For random packed operands of any shape, the kernel equals the
    exact ±1 dot product (invariant: 2*popcount(xnor) - K)."""
    k = kw * 32
    key = jax.random.PRNGKey(seed)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    out = ops.xnor_gemm(
        bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0), k, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.binary_matmul_ref(wb, xb))
    )


@settings(max_examples=25, deadline=None)
@given(
    kw=st.integers(1, 16),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip_property(kw, n, seed):
    k = kw * 32
    x = _rand_pm1(jax.random.PRNGKey(seed), (k, n))
    packed = bitops.pack_bits(x, axis=0)
    rt = bitops.unpack_bits(packed, axis=0)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(x))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    kw=st.integers(1, 8),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_engines_agree_property(m, kw, n, seed):
    """xnor and unpack engines compute the same binary contraction."""
    k = kw * 32
    key = jax.random.PRNGKey(seed)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    wp = bitops.pack_bits(wb, -1)
    a = ops.xnor_gemm(wp, bitops.pack_bits(xb, 0), k, interpret=True)
    b = ops.unpack_gemm(wp, xb, interpret=True)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b))
