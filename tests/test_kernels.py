"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Every kernel sweeps shapes (aligned, unaligned, tiny, rectangular) and
is asserted allclose/bit-exact against ``repro.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, layers
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand_pm1(key, shape):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


SHAPES = [
    (128, 256, 128),   # tile-aligned
    (96, 320, 200),    # M/N unaligned
    (128, 96, 128),    # KW < block_kw after packing (96/32 = 3 words)
    (1, 32, 1),        # minimal
    (257, 544, 130),   # everything unaligned
    (64, 1024, 512),   # deep K
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_xnor_gemm_matches_float_truth(m, k, n):
    key = jax.random.PRNGKey(m * 7 + k * 3 + n)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    truth = ref.binary_matmul_ref(wb, xb)
    wp = bitops.pack_bits(wb, axis=-1)
    xp = bitops.pack_bits(xb, axis=0)
    out = ops.xnor_gemm(wp, xp, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(truth))


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_xnor_gemm_matches_ref_oracle(m, k, n):
    key = jax.random.PRNGKey(m + k + n)
    wp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 0), (m, k)), axis=-1)
    xp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 1), (k, n)), axis=0)
    out = ops.xnor_gemm(wp, xp, k, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.xnor_gemm_ref(wp, xp, k))
    )


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_unpack_gemm_matches_oracle(m, k, n):
    key = jax.random.PRNGKey(m ^ k ^ n)
    w = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    x = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    wp = bitops.pack_bits(w, axis=-1)
    out = ops.unpack_gemm(wp, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.unpack_gemm_ref(wp, x)),
        rtol=1e-5, atol=1e-4,
    )


@pytest.mark.parametrize(
    "k,n", [(32, 128), (64, 100), (256, 1), (1024, 333), (32, 129)]
)
def test_pack_kernel_matches_ref(k, n):
    x = jax.random.normal(jax.random.PRNGKey(k + n), (k, n))
    out = ops.pack_rows(x, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.pack_ref(x, axis=0))
    )


@pytest.mark.parametrize("bm,bn,bkw", [(128, 128, 16), (256, 128, 8), (128, 256, 32)])
def test_xnor_gemm_block_shape_invariance(bm, bn, bkw):
    """Result must not depend on the chosen tiling."""
    key = jax.random.PRNGKey(9)
    m, k, n = 160, 640, 96
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    wp, xp = bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0)
    out = ops.xnor_gemm(
        wp, xp, k, block_m=bm, block_n=bn, block_kw=bkw, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.binary_matmul_ref(wb, xb))
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_unpack_gemm_dtypes(dtype):
    key = jax.random.PRNGKey(11)
    w = _rand_pm1(jax.random.fold_in(key, 0), (64, 128))
    x = jax.random.normal(jax.random.fold_in(key, 1), (128, 64)).astype(dtype)
    wp = bitops.pack_bits(w, axis=-1)
    out = ops.unpack_gemm(wp, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.unpack_gemm_ref(wp, x.astype(jnp.float32))),
        rtol=2e-2, atol=2e-1,
    )


# --------------------------- fused layer kernel -----------------------------

FUSED_SHAPES = [
    (128, 256, 128),   # tile-aligned
    (96, 320, 200),    # M/N not tile-aligned (M still a whole 3 words)
    (10, 64, 7),       # tiny, M << 32
    (257, 544, 130),   # everything unaligned
]


@pytest.mark.parametrize("m,k,n", FUSED_SHAPES)
def test_fused_xnor_gemm_matches_float_truth(m, k, n):
    key = jax.random.PRNGKey(m * 5 + k * 11 + n)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    wp = bitops.pack_bits(wb, axis=-1)
    xp = bitops.pack_bits(xb, axis=0)
    out = ops.fused_xnor_gemm(wp, xp, k, a, b, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.fused_layer_ref(wb, xb, a, b))
    )


@pytest.mark.parametrize("m,k,n", FUSED_SHAPES)
def test_fused_xnor_gemm_matches_xla_oracle(m, k, n):
    """Pallas fused kernel vs the pure-XLA fused_xnor_layer oracle —
    bit-exact (same int32 dot, same float op order in the epilogue)."""
    key = jax.random.PRNGKey(m + 2 * k + 3 * n)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    wp = bitops.pack_bits(wb, axis=-1)
    xp = bitops.pack_bits(xb, axis=0)
    got = ops.fused_xnor_gemm(wp, xp, k, a, b, interpret=True)
    want = bitops.fused_xnor_layer(wp, xp, k, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_xnor_gemm_odd_k_bitpad_convention():
    """k_orig % 32 != 0: weight pad bits -1, activation pad bits +1
    (xnor-neutral), k_bits = true K — no post-hoc correction needed."""
    m, k_orig, n = 48, 100, 33
    key = jax.random.PRNGKey(7)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k_orig))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k_orig, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    pad = -k_orig % 32
    wp = bitops.pack_bits(
        jnp.pad(wb, ((0, 0), (0, pad)), constant_values=-1.0), axis=-1
    )
    xp = bitops.pack_bits(
        jnp.pad(xb, ((0, pad), (0, 0)), constant_values=1.0), axis=0
    )
    want = ref.fused_layer_ref(wb, xb, a, b)
    got = ops.fused_xnor_gemm(wp, xp, k_orig, a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    oracle = bitops.fused_xnor_layer(wp, xp, k_orig, a, b)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(want))


def test_fused_xnor_gemm_block_shape_invariance():
    key = jax.random.PRNGKey(13)
    m, k, n = 160, 640, 96
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    wp, xp = bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0)
    want = ref.fused_layer_ref(wb, xb, a, b)
    for bm, bn, bkw in [(128, 128, 16), (256, 128, 8), (32, 256, 32)]:
        out = ops.fused_xnor_gemm(
            wp, xp, k, a, b,
            block_m=bm, block_n=bn, block_kw=bkw, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_fused_output_feeds_next_layer():
    """The packed output of a fused layer (incl. +1 pad bits past M) is
    directly consumable by the next layer's packed weights — a two-layer
    odd-width chain matches plain float math end to end."""
    b_sz, d0, d1, d2 = 5, 70, 50, 9   # every width odd / non-mult-of-32
    key = jax.random.PRNGKey(99)
    x = jax.random.normal(jax.random.fold_in(key, 0), (b_sz, d0))
    w1 = _rand_pm1(jax.random.fold_in(key, 1), (d1, d0))
    w2 = _rand_pm1(jax.random.fold_in(key, 2), (d2, d1))
    a1 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (d1,))) + 0.1
    b1 = jax.random.normal(jax.random.fold_in(key, 4), (d1,))
    a2 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 5), (d2,))) + 0.1
    b2 = jax.random.normal(jax.random.fold_in(key, 6), (d2,))

    # float reference: sign(x) -> dot -> affine -> sign -> dot -> affine
    xb = jnp.where(x >= 0, 1.0, -1.0)
    z1 = a1[None, :] * (xb @ w1.T) + b1[None, :]
    want_bits = ref.fused_layer_ref(
        w2, jnp.where(z1 >= 0, 1.0, -1.0).T, a2, b2
    )  # [ceil(d2/32), b_sz]

    def pack_w(w):
        pad = -w.shape[1] % 32
        return bitops.pack_bits(
            jnp.pad(w, ((0, 0), (0, pad)), constant_values=-1.0), axis=-1
        )

    pad0 = -d0 % 32
    xp = bitops.pack_bits(
        jnp.pad(xb, ((0, 0), (0, pad0)), constant_values=1.0), axis=-1
    ).T  # [KW0, B]
    for engine in ["xla", "xnor"]:
        if engine == "xnor":
            h = ops.fused_xnor_gemm(pack_w(w1), xp, d0, a1, b1, interpret=True)
            out = ops.fused_xnor_gemm(pack_w(w2), h, d1, a2, b2, interpret=True)
        else:
            h = bitops.fused_xnor_layer(pack_w(w1), xp, d0, a1, b1)
            out = bitops.fused_xnor_layer(pack_w(w2), h, d1, a2, b2)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want_bits))


# ------------------------- direct conv kernel -------------------------------

# (n, h, w, c, d, kh, kw, stride, pad) — sweeps strided, padded, ragged
# C (tail-word masking) and ragged D (packed-output tail) geometries.
CONV_SHAPES = [
    (2, 8, 8, 32, 16, 3, 3, 1, 1),     # aligned C, the BNN's conv shape
    (1, 6, 7, 64, 33, 3, 3, 2, 1),     # stride 2, ragged D
    (2, 9, 9, 40, 10, 3, 3, 1, 0),     # C % 32 != 0: tail-word masking
    (1, 5, 5, 32, 7, 1, 1, 1, 0),      # 1x1 conv degenerate window
    (2, 10, 10, 48, 20, 5, 5, 2, 2),   # big window, stride 2, ragged C
]


def _rand_conv_case(n, h, w, c, d, kh, kw):
    key = jax.random.PRNGKey(n * 31 + h * 7 + c + d + kh)
    x = _rand_pm1(jax.random.fold_in(key, 0), (n, h, w, c))
    wt = _rand_pm1(jax.random.fold_in(key, 1), (d, kh, kw, c))
    a = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (d,))
    wp = layers.pack_conv_aligned({"w": wt})["w_packed"]
    xp = bitops.pack_channels(x)
    return x, wt, a, b, wp, xp


@pytest.mark.parametrize("n,h,w,c,d,kh,kw,stride,pad", CONV_SHAPES)
def test_direct_conv_matches_float_truth(n, h, w, c, d, kh, kw, stride, pad):
    """Pallas direct conv + XLA oracle vs the ±1 float conv ground truth
    — the window gather, stride walk, all-ones spatial border, and
    C % 32 tail-word masking must all reproduce the im2col semantics."""
    x, wt, _, _, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw)
    k_bits = kh * kw * c
    truth = ref.conv2d_pm1_ref(wt, x, stride=stride, pad=pad)
    got_oracle = bitops.direct_conv_dot(
        wp, xp, k_bits, kh=kh, kw=kw, stride=stride, pad=pad
    )
    np.testing.assert_array_equal(np.asarray(got_oracle), np.asarray(truth))
    got_pallas = ops.direct_conv(
        wp, xp, k_bits, kh=kh, kw=kw, stride=stride, pad=pad, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got_pallas), np.asarray(truth))


@pytest.mark.parametrize("n,h,w,c,d,kh,kw,stride,pad", CONV_SHAPES)
def test_fused_direct_conv_matches_float_truth(n, h, w, c, d, kh, kw, stride,
                                               pad):
    x, wt, a, b, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw)
    k_bits = kh * kw * c
    want = ref.fused_direct_conv_ref(wt, x, a, b, stride=stride, pad=pad)
    got = ops.fused_direct_conv(
        wp, xp, k_bits, a, b, kh=kh, kw=kw, stride=stride, pad=pad,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,h,w,c,d,kh,kw,stride,pad", CONV_SHAPES)
def test_fused_direct_conv_matches_xla_oracle(n, h, w, c, d, kh, kw, stride,
                                              pad):
    """Pallas direct kernel vs bitops.direct_conv_oracle — bit-exact
    (same int32 dot, same float op order in the epilogue)."""
    _, _, a, b, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw)
    k_bits = kh * kw * c
    want = bitops.direct_conv_oracle(
        wp, xp, k_bits, a, b, kh=kh, kw=kw, stride=stride, pad=pad
    )
    got = ops.fused_direct_conv(
        wp, xp, k_bits, a, b, kh=kh, kw=kw, stride=stride, pad=pad,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---- packed im2col edge cases the direct kernel must also honor ----
# (satellite: stride > 1, pad > 0 with all-ones border words, and
#  C % 32 != 0 tail-word masking — asserted for BOTH conv_impls)

@pytest.mark.parametrize("stride,pad,c", [
    (2, 0, 32),    # stride > 1
    (1, 1, 32),    # pad > 0: all-ones border words
    (2, 2, 64),    # both, multi-word C
    (1, 1, 40),    # C % 32 != 0 tail-word masking
    (2, 1, 33),    # everything ragged at once
])
@pytest.mark.parametrize("conv_impl", ["im2col", "direct"])
def test_fused_conv_edge_cases_both_impls(stride, pad, c, conv_impl):
    """fused_bit_conv2d vs the ±1 float conv truth through both conv
    lowerings: the packed im2col path (patch matrix of words, border =
    all-ones words, ragged C handled by tap-aligned weights + tail +1
    activation bits) and the direct packed-window path must compute the
    identical packed output."""
    n, h, w, d, kh, kw = 2, 9, 9, 21, 3, 3
    x, wt, a, b, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw)
    packed = {"w_packed": wp, "a": a, "b": b}
    want = ref.fused_direct_conv_ref(wt, x, a, b, stride=stride, pad=pad)
    for engine in ["xla", "xnor"]:
        got = layers.fused_bit_conv2d(
            packed, xp, kh * kw * c, kh=kh, kw=kw, stride=stride, pad=pad,
            engine=engine, conv_impl=conv_impl,
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want),
            err_msg=f"engine={engine} conv_impl={conv_impl}",
        )


def test_direct_conv_rejects_mismatched_filter_words():
    """Flat-packed filters with ragged C are NOT tap-aligned — the
    direct path must refuse rather than silently misalign words."""
    c, kh, kw, d = 40, 3, 3, 8
    wt = _rand_pm1(jax.random.PRNGKey(0), (d, kh, kw, c))
    flat = layers.pack_conv_params({"w": wt})  # [d, ceil(kh*kw*c/32)]
    xp = bitops.pack_channels(_rand_pm1(jax.random.PRNGKey(1), (1, 6, 6, c)))
    with pytest.raises(ValueError, match="tap-aligned"):
        bitops.direct_conv_dot(
            flat["w_packed"], xp, kh * kw * c, kh=kh, kw=kw
        )


# ---------------- accumulator restructure + autotuned blocks ----------------
# (tentpole: the fori-loop accumulator must match the legacy broadcast
#  formulation bit-for-bit — including ragged K where k_words is not a
#  multiple of the word group — and "auto" blocks must match fixed ones.)

RAGGED_K_CASES = [
    # (m, k, n, word_group): k/32 words deliberately not a multiple of
    # the group so the static tail path runs.
    (64, 352, 96, 8),    # 11 words, group 8 -> 1 full group + 3 tail
    (48, 96, 64, 5),     # 3 words, group 5 -> clamped group, no loop
    (96, 544, 130, 3),   # 17 words, group 3 -> 5 groups + 2 tail
]


@pytest.mark.parametrize("m,k,n,group", RAGGED_K_CASES)
def test_xnor_gemm_loop_matches_broadcast_ragged_k(m, k, n, group):
    key = jax.random.PRNGKey(m + k + n + group)
    wp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 0), (m, k)), -1)
    xp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 1), (k, n)), 0)
    kw = wp.shape[1]
    assert kw % group != 0, "case must exercise the ragged tail"
    want = ops.xnor_gemm(wp, xp, k, block_m=128, block_n=128, block_kw=kw,
                         accum="broadcast", interpret=True)
    got = ops.xnor_gemm(wp, xp, k, block_m=128, block_n=128, block_kw=kw,
                        word_group=group, accum="loop", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n,group", RAGGED_K_CASES)
def test_fused_gemm_loop_matches_broadcast_ragged_k(m, k, n, group):
    key = jax.random.PRNGKey(m * 3 + k + n + group)
    wp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 0), (m, k)), -1)
    xp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 1), (k, n)), 0)
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    kw = wp.shape[1]
    want = ops.fused_xnor_gemm(wp, xp, k, a, b, block_m=64, block_n=128,
                               block_kw=kw, accum="broadcast", interpret=True)
    got = ops.fused_xnor_gemm(wp, xp, k, a, b, block_m=64, block_n=128,
                              block_kw=kw, word_group=group, accum="loop",
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_direct_conv_loop_matches_broadcast_ragged_k():
    """conv5-like geometry: KW = 9*2 = 18 words, word groups 4 and 7
    leave ragged tails."""
    n, h, w, c, d, kh, kw_, stride, pad = 1, 7, 7, 40, 20, 3, 3, 1, 1
    _, _, a, b, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw_)
    k_bits = kh * kw_ * c
    want = ops.fused_direct_conv(
        wp, xp, k_bits, a, b, kh=kh, kw=kw_, stride=stride, pad=pad,
        accum="broadcast", interpret=True,
    )
    for group in (4, 7):
        got = ops.fused_direct_conv(
            wp, xp, k_bits, a, b, kh=kh, kw=kw_, stride=stride, pad=pad,
            word_group=group, accum="loop", interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(96, 320, 200), (257, 544, 130)])
def test_auto_blocks_bit_identical_gemm(m, k, n, tmp_path, monkeypatch):
    """block_*="auto" (heuristic resolution, isolated empty cache) vs
    the legacy fixed 128/128/16 tiles — bit-identical, both GEMMs."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    key = jax.random.PRNGKey(m ^ k ^ n)
    wp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 0), (m, k)), -1)
    xp = bitops.pack_bits(_rand_pm1(jax.random.fold_in(key, 1), (k, n)), 0)
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    want = ops.xnor_gemm(wp, xp, k, block_m=128, block_n=128, block_kw=16,
                         interpret=True)
    got = ops.xnor_gemm(wp, xp, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    want = ops.fused_xnor_gemm(wp, xp, k, a, b, block_m=128, block_n=128,
                               block_kw=16, interpret=True)
    got = ops.fused_xnor_gemm(wp, xp, k, a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_auto_blocks_bit_identical_direct_conv(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    n, h, w, c, d, kh, kw_ = 2, 9, 9, 40, 70, 3, 3
    _, _, a, b, wp, xp = _rand_conv_case(n, h, w, c, d, kh, kw_)
    k_bits = kh * kw_ * c
    want = ops.fused_direct_conv(wp, xp, k_bits, a, b, kh=kh, kw=kw_,
                                 stride=1, pad=1, block_d=32, interpret=True)
    got = ops.fused_direct_conv(wp, xp, k_bits, a, b, kh=kh, kw=kw_,
                                stride=1, pad=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    want = ops.direct_conv(wp, xp, k_bits, kh=kh, kw=kw_, stride=1, pad=1,
                           block_d=32, interpret=True)
    got = ops.direct_conv(wp, xp, k_bits, kh=kh, kw=kw_, stride=1, pad=1,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_oversized_explicit_blocks_clamped_not_asserted():
    """Satellite: a 10-output head with block_m=512 requested must run
    (clamped to the padded extent), not trip the divisibility assert."""
    m, k, n = 10, 64, 7
    key = jax.random.PRNGKey(1)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    wp, xp = bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0)
    out = ops.fused_xnor_gemm(wp, xp, k, a, b, block_m=512, block_n=512,
                              block_kw=64, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.fused_layer_ref(wb, xb, a, b))
    )


# property-based sweeps of these kernels (hypothesis) live in
# tests/test_properties.py behind pytest.importorskip("hypothesis").
