"""Paper-faithful BNN: mode agreement, trainability, packing compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    bnn_loss,
    init_bnn_params,
    pack_bnn_params,
)
from repro.data import DataConfig, synthetic_cifar_batches
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(42)


@pytest.fixture(scope="module")
def params():
    return init_bnn_params(KEY)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.fold_in(KEY, 1), (4, 32, 32, 3))


def test_bnn_forward_shapes_and_finite(params, images):
    logits = bnn_apply(params, images, BNNConfig(mode=QuantMode.FAKE_QUANT))
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("engine", ["xla", "xnor", "unpack"])
def test_bnn_packed_inference_matches_simulation(params, images, engine):
    """The paper's central correctness claim: the packed 1-bit kernel
    computes the same function as the float 'simulation'."""
    want = bnn_apply(params, images, BNNConfig(mode=QuantMode.FAKE_QUANT))
    got = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine=engine),
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-2, rtol=1e-3
    )


def test_bnn_packed_weights_32x_smaller(params):
    packed = pack_bnn_params(params)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    # binarized conv weights only (skip first conv / bn / biases)
    orig = sum(p["w"].size * 4 for p in params["conv"][1:])
    new = sum(p["w_packed"].size * 4 for p in packed["conv"][1:])
    assert orig / new >= 31.0  # 32x modulo K-padding


def test_bnn_float_control_group_runs(params, images):
    logits = bnn_apply(params, images, BNNConfig(mode=QuantMode.FLOAT))
    assert bool(jnp.isfinite(logits).all())


def test_bnn_trains_on_synthetic_cifar(params):
    """Few steps of STE training reduce loss on the learnable synthetic
    class-conditional task."""
    cfg = BNNConfig(mode=QuantMode.FAKE_QUANT)
    data = synthetic_cifar_batches(DataConfig(seed=7, global_batch=16))
    opt_cfg = AdamWConfig(lr=3e-3, latent_clip=True)
    p = params
    opt = adamw_init(p)

    @jax.jit
    def step(p, opt, images, labels):
        (loss, acc), g = jax.value_and_grad(
            lambda q: bnn_loss(q, images, labels, cfg), has_aux=True
        )(p)
        p, opt = adamw_update(g, opt, p, opt_cfg)
        return p, opt, loss

    losses = []
    for i, batch in zip(range(8), data):
        p, opt, loss = step(p, opt, batch["images"], batch["labels"])
        losses.append(float(loss))
    assert np.mean(losses[-2:]) < np.mean(losses[:2])
    # latent clip invariant: binarized weights stay in [-1, 1]
    for cp in p["conv"]:
        assert float(jnp.max(jnp.abs(cp["w"]))) <= 1.0 + 1e-6
