"""Paper-faithful BNN: mode agreement, trainability, packing compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    bnn_apply_fused,
    bnn_loss,
    init_bnn_params,
    pack_bnn_params,
    pack_bnn_params_fused,
)
from repro.data import DataConfig, synthetic_cifar_batches
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(42)


@pytest.fixture(scope="module")
def params():
    return init_bnn_params(KEY)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.fold_in(KEY, 1), (4, 32, 32, 3))


def test_bnn_forward_shapes_and_finite(params, images):
    logits = bnn_apply(params, images, BNNConfig(mode=QuantMode.FAKE_QUANT))
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("engine", ["xla", "xnor", "unpack"])
def test_bnn_packed_inference_matches_simulation(params, images, engine):
    """The paper's central correctness claim: the packed 1-bit kernel
    computes the same function as the float 'simulation'."""
    want = bnn_apply(params, images, BNNConfig(mode=QuantMode.FAKE_QUANT))
    got = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine=engine),
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-2, rtol=1e-3
    )


@pytest.mark.parametrize("engine", ["xla", "xnor"])
def test_bnn_fused_matches_packed_bit_exact(params, images, engine):
    """Tentpole invariant: the fused packed pipeline (packed int32
    activations between binary layers, BN folded into the epilogue)
    produces logits BIT-IDENTICAL to the unfused QuantMode.PACKED path."""
    want = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    got = bnn_apply_fused(pack_bnn_params_fused(params), images, engine=engine)
    assert got.shape == want.shape == (images.shape[0], 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("engine", ["xla", "xnor"])
def test_bnn_fused_direct_conv_matches_im2col(params, images, engine):
    """Direct-conv tentpole invariant: the packed-window conv kernel
    (no im2col patch matrix in HBM) produces logits BIT-IDENTICAL to
    the im2col fused chain on both engines."""
    fused = pack_bnn_params_fused(params)
    imgs = images if engine == "xla" else images[:2]
    want = bnn_apply_fused(fused, imgs, engine=engine, conv_impl="im2col")
    got = bnn_apply_fused(fused, imgs, engine=engine, conv_impl="direct")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bnn_unfused_direct_conv_matches_packed(params, images):
    """conv_impl='direct' on the UNFUSED packed path (float layer
    boundaries, epilogue-free direct kernel) agrees with the im2col
    packed path."""
    packed = pack_bnn_params(params)
    want = bnn_apply(packed, images,
                     BNNConfig(mode=QuantMode.PACKED, engine="xla"))
    got = bnn_apply(
        packed, images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla", conv_impl="direct"),
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-2, rtol=1e-3
    )


def test_bnn_fused_engines_agree(params, images):
    a = bnn_apply_fused(pack_bnn_params_fused(params), images, engine="xla")
    b = bnn_apply_fused(pack_bnn_params_fused(params), images, engine="xnor")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bnn_block_config_invariance(params, images):
    """Acceptance invariant (ISSUE 3): logits are bit-identical across
    every engine x conv_impl x block-config combination — tile choice
    (including word_group, so the fori-loop trip count and ragged tail
    both move) is a pure performance knob."""
    from repro.kernels.autotune import BlockConfig

    fused = pack_bnn_params_fused(params)
    want = bnn_apply_fused(fused, images, engine="xla")
    imgs = images[:1]  # interpret-mode engine at tiny scale
    want_small = bnn_apply_fused(fused, imgs, engine="xla")
    configs = [
        "auto",
        BlockConfig(block_m=64, block_n=128, block_kw=4, word_group=3),
        BlockConfig(block_m=256, block_n=256, block_kw=32, word_group=16),
    ]
    for conv_impl in ["im2col", "direct"]:
        # xla engine ignores blocks but must stay identical under them
        got = bnn_apply_fused(fused, images, engine="xla",
                              conv_impl=conv_impl, blocks=configs[1])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        for blocks in configs:
            got = bnn_apply_fused(fused, imgs, engine="xnor",
                                  conv_impl=conv_impl, blocks=blocks)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want_small),
                err_msg=f"conv_impl={conv_impl} blocks={blocks}",
            )


def test_bnn_fused_boundaries_are_packed(params):
    """The fused pack drops every interior float boundary: interior
    layers carry only (w_packed, a, b) — no float bias / BN dicts."""
    fp = pack_bnn_params_fused(params)
    for layer in fp["conv"][1:] + fp["fc"][:-1]:
        assert set(layer) == {"w_packed", "a", "b"}, set(layer)
        assert layer["w_packed"].dtype == jnp.int32
    assert "b" in fp["fc"][-1]          # last FC keeps its float bias
    assert "gamma" in fp["bn_fc_last"]  # ... and its separate BN


def test_bnn_fused_matches_packed_with_trained_stats(params, images):
    """Parity must also hold with non-trivial BN statistics (the fresh
    init has gamma=1/beta=0/mean=0/var=1, where folded and unfolded BN
    are algebraically identical ops) — perturb every BN param and bias
    so the folded affine actually differs in op order."""
    key = jax.random.PRNGKey(1234)
    p = jax.tree_util.tree_map(lambda x: x, params)  # shallow-ish copy
    def perturb(bn, k):
        return {
            "gamma": bn["gamma"] * (1 + 0.3 * jax.random.normal(jax.random.fold_in(k, 0), bn["gamma"].shape)),
            "beta": 0.2 * jax.random.normal(jax.random.fold_in(k, 1), bn["beta"].shape),
            "mean": 0.5 * jax.random.normal(jax.random.fold_in(k, 2), bn["mean"].shape),
            "var": bn["var"] * jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), bn["var"].shape)),
        }
    p = dict(p)
    p["bn_conv"] = [perturb(bn, jax.random.fold_in(key, i))
                    for i, bn in enumerate(p["bn_conv"])]
    p["bn_fc"] = [perturb(bn, jax.random.fold_in(key, 100 + i))
                  for i, bn in enumerate(p["bn_fc"])]
    want = bnn_apply(
        pack_bnn_params(p), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    got = bnn_apply_fused(pack_bnn_params_fused(p), images, engine="xla")
    # Exact equality holds for this fixed seed. Caveat: the folded and
    # unfolded BN are differently-associated f32 expressions, so a jax/
    # XLA upgrade that re-fuses either one could flip a sign on a
    # pre-activation within ~1 ulp of 0. If this ever fails with a
    # HANDFUL of differing logits (not wholesale divergence), that is
    # ulp-level sign noise, not a folding bug — relax to a small
    # Hamming-distance bound rather than chasing bit parity.
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bnn_packed_weights_32x_smaller(params):
    packed = pack_bnn_params(params)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    # binarized conv weights only (skip first conv / bn / biases)
    orig = sum(p["w"].size * 4 for p in params["conv"][1:])
    new = sum(p["w_packed"].size * 4 for p in packed["conv"][1:])
    assert orig / new >= 31.0  # 32x modulo K-padding


def test_bnn_float_control_group_runs(params, images):
    logits = bnn_apply(params, images, BNNConfig(mode=QuantMode.FLOAT))
    assert bool(jnp.isfinite(logits).all())


def test_bnn_trains_on_synthetic_cifar(params):
    """Few steps of STE training reduce loss on the learnable synthetic
    class-conditional task."""
    cfg = BNNConfig(mode=QuantMode.FAKE_QUANT)
    data = synthetic_cifar_batches(DataConfig(seed=7, global_batch=16))
    opt_cfg = AdamWConfig(lr=3e-3, latent_clip=True)
    p = params
    opt = adamw_init(p)

    @jax.jit
    def step(p, opt, images, labels):
        (loss, acc), g = jax.value_and_grad(
            lambda q: bnn_loss(q, images, labels, cfg), has_aux=True
        )(p)
        p, opt = adamw_update(g, opt, p, opt_cfg)
        return p, opt, loss

    losses = []
    for i, batch in zip(range(8), data):
        p, opt, loss = step(p, opt, batch["images"], batch["labels"])
        losses.append(float(loss))
    assert np.mean(losses[-2:]) < np.mean(losses[:2])
    # latent clip invariant: binarized weights stay in [-1, 1]
    for cp in p["conv"]:
        assert float(jnp.max(jnp.abs(cp["w"]))) <= 1.0 + 1e-6
