"""kernels/autotune.py: cache round-trip + invalidation guard, VMEM
model, heuristic constraints, "auto" resolution, and measured tuning.

Bit-identity of auto/tuned block configs against fixed blocks lives in
tests/test_kernels.py (kernel level) and tests/test_bnn.py (model
level); this file covers the subsystem itself.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitops import PACK_BITS
from repro.kernels import autotune, ops
from repro.kernels.autotune import BlockConfig


@pytest.fixture()
def cache_file(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    return path


# ------------------------------ cache ---------------------------------------

def test_cache_round_trip(cache_file):
    cfg = BlockConfig(block_m=256, block_n=128, block_kw=32, word_group=4)
    shape = {"m": 512, "kw": 128, "n": 512}
    autotune.save_entry("fused_xnor_gemm", shape, cfg, wall_s=0.01)
    assert cache_file.exists()
    got = autotune.load_entry("fused_xnor_gemm", shape)
    assert got == cfg
    # different shape / kernel -> miss, not a wrong hit
    assert autotune.load_entry("fused_xnor_gemm", {**shape, "n": 64}) is None
    assert autotune.load_entry("xnor_gemm", shape) is None


def test_cache_ignores_stale_jax_version(cache_file):
    """The invalidation guard: entries recorded under another jax
    version or device kind must be ignored, never served."""
    cfg = BlockConfig(block_m=64)
    shape = {"m": 128, "kw": 8, "n": 128}
    autotune.save_entry("xnor_gemm", shape, cfg)
    assert autotune.load_entry("xnor_gemm", shape) == cfg

    data = json.loads(cache_file.read_text())
    (key,) = data["entries"]
    data["entries"][key]["jax"] = "0.0.1-stale"
    cache_file.write_text(json.dumps(data))
    assert autotune.load_entry("xnor_gemm", shape) is None

    data["entries"][key]["jax"] = jax.__version__
    data["entries"][key]["device"] = "TPU v9000"
    cache_file.write_text(json.dumps(data))
    assert autotune.load_entry("xnor_gemm", shape) is None


@pytest.mark.parametrize("content", [
    "not json {",                                 # unparseable
    '{"version": 1, "entries": []}',              # entries wrong type
    '{"version": 99, "entries": {}}',             # unknown version
    '[1, 2, 3]',                                  # top level wrong type
])
def test_cache_tolerates_garbage_file(cache_file, content):
    cache_file.write_text(content)
    shape = {"m": 1, "kw": 1, "n": 1}
    assert autotune.load_entry("xnor_gemm", shape) is None
    # ... and "auto" resolution must fall back to heuristics, not crash
    bm, bn, bkw, wg = autotune.resolve_gemm_blocks(
        "xnor_gemm", 128, 16, 128, "auto", "auto", "auto", "auto"
    )
    assert all(isinstance(v, int) for v in (bm, bn, bkw, wg))
    # save over garbage still works
    autotune.save_entry("xnor_gemm", shape, BlockConfig())
    assert autotune.load_entry("xnor_gemm", shape) == BlockConfig()


def test_cache_survives_torn_write(cache_file):
    """Satellite (ISSUE 5): a torn write — a writer killed mid-file, so
    the cache holds a truncated JSON prefix — must be IGNORED, not
    fatal: lookups miss, "auto" resolution falls back to heuristics,
    and the next save repairs the file."""
    cfg = BlockConfig(block_m=64, block_n=128, block_kw=4)
    shape = {"m": 64, "kw": 8, "n": 64}
    autotune.save_entry("xnor_gemm", shape, cfg, wall_s=0.5)
    whole = cache_file.read_text()
    cache_file.write_text(whole[: len(whole) // 2])  # torn mid-write

    assert autotune.load_entry("xnor_gemm", shape) is None
    bm, bn, bkw, wg = autotune.resolve_gemm_blocks(
        "xnor_gemm", 64, 8, 64, "auto", "auto", "auto", "auto"
    )
    assert all(isinstance(v, int) for v in (bm, bn, bkw, wg))
    # save over the torn file repairs it
    autotune.save_entry("xnor_gemm", shape, cfg, wall_s=0.5)
    assert autotune.load_entry("xnor_gemm", shape) == cfg
    json.loads(cache_file.read_text())  # valid JSON again


def test_cache_write_is_atomic_no_stray_temp(cache_file):
    """The atomic-publish path: after a save the directory holds ONLY
    the cache file (unique temp staged then os.replace'd — concurrent
    writers can never interleave into one shared temp), and repeated
    saves keep every prior entry."""
    autotune.save_entry("a", {"m": 1}, BlockConfig(block_m=8))
    autotune.save_entry("b", {"m": 2}, BlockConfig(block_m=16))
    assert sorted(p.name for p in cache_file.parent.iterdir()) == [
        cache_file.name
    ]
    assert autotune.load_entry("a", {"m": 1}) == BlockConfig(block_m=8)
    assert autotune.load_entry("b", {"m": 2}) == BlockConfig(block_m=16)


def test_cache_disabled_by_env(cache_file, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert not autotune.cache_enabled()
    # resolve still works (pure heuristics) without touching the file
    bm, bn, bkw, wg = autotune.resolve_gemm_blocks(
        "xnor_gemm", 128, 16, 128, "auto", "auto", "auto", "auto"
    )
    assert all(isinstance(v, int) for v in (bm, bn, bkw, wg))
    assert not cache_file.exists()


# --------------------------- VMEM model -------------------------------------

def test_vmem_model_loop_vs_broadcast_reduction():
    """The restructure's headline claim: >= 5x per-step VMEM reduction
    at the legacy default tiles, for every xnor kernel."""
    for fused in (False, True):
        old = autotune.gemm_step_vmem(128, 128, 16, fused=fused,
                                      accum="broadcast")
        new = autotune.gemm_step_vmem(128, 128, 16, fused=fused,
                                      accum="loop")
        assert old / new >= 5.0, (fused, old, new)
    # direct conv, CIFAR worst cases
    for hp, cw, ow in [(34, 4, 32), (10, 16, 8)]:
        old = autotune.conv_step_vmem(hp, hp, cw, 128, 3, 3, ow,
                                      accum="broadcast")
        new = autotune.conv_step_vmem(hp, hp, cw, 128, 3, 3, ow,
                                      accum="loop")
        assert old / new >= 5.0, (hp, cw, old, new)


def test_heuristic_blocks_fit_budget_and_alignment():
    for m, k, n, fused in [
        (512, 4096, 512, True), (10, 64, 7, True), (1, 32, 1, False),
        (4096, 32768, 4096, False), (257, 544, 130, True),
    ]:
        kw = -(-k // PACK_BITS)
        cfg = autotune.heuristic_gemm_blocks(m, kw, n, fused=fused)
        assert autotune.gemm_step_vmem(
            cfg.block_m, cfg.block_n, cfg.block_kw, fused=fused
        ) <= autotune.VMEM_BUDGET_BYTES
        if fused:
            assert cfg.block_m % PACK_BITS == 0
        assert cfg.block_kw <= max(kw, 1)


def test_resolve_clamps_blocks_to_tiny_shapes(cache_file):
    """Satellite: explicit oversized blocks are clamped so tiny/ragged
    layers (the 10-output CIFAR head) never trip the kernel asserts."""
    bm, bn, bkw, _ = autotune.resolve_gemm_blocks(
        "fused_xnor_gemm", 10, 2, 7, 128, 256, 16, 8, fused=True
    )
    assert bm == 32 and bn == 128 and bkw == 2
    bd, _ = autotune.resolve_conv_block_d(
        "fused_direct_conv", 10, 6, 6, 1, 3, 3, 4, 128, 8
    )
    assert bd == 32


# ------------------------- measured tuning ----------------------------------

def test_tune_returns_fastest_and_caches(cache_file):
    m, k, n = 64, 256, 64
    candidates = [
        BlockConfig(block_m=64, block_n=128, block_kw=8),
        BlockConfig(block_m=32, block_n=128, block_kw=4),
    ]
    timings = {}
    best = autotune.tune(
        ops.xnor_gemm, (m, k, n), candidates=candidates, repeats=1,
        kernel="xnor_gemm", timings=timings,
    )
    assert best in candidates
    assert set(timings) == set(candidates)
    assert min(timings, key=timings.get) == best
    # winner persisted and reloadable for this jax version + device
    kw = -(-k // PACK_BITS)
    assert autotune.load_entry(
        "xnor_gemm", {"m": m, "kw": kw, "n": n}
    ) == best
    # ... and "auto" resolution now picks it up
    bm, bn, bkw, wg = autotune.resolve_gemm_blocks(
        "xnor_gemm", m, kw, n, "auto", "auto", "auto", "auto"
    )
    assert (bm, bn, bkw, wg) == (
        best.block_m, best.block_n, best.block_kw, best.word_group
    )


def test_tuned_config_bit_identical(cache_file):
    """A tuned/cached config changes speed only: results stay bit-exact
    vs the legacy fixed tiles."""
    m, k, n = 96, 320, 130
    key = jax.random.PRNGKey(0)
    from repro.core import bitops

    wb = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 0),
                                        0.5, (m, k)), 1.0, -1.0)
    xb = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1),
                                        0.5, (k, n)), 1.0, -1.0)
    wp = bitops.pack_bits(wb, axis=-1)
    xp = bitops.pack_bits(xb, axis=0)
    fixed = ops.xnor_gemm(wp, xp, k, block_m=128, block_n=128, block_kw=16,
                          interpret=True)
    autotune.save_entry(
        "xnor_gemm", {"m": m, "kw": wp.shape[1], "n": n},
        BlockConfig(block_m=64, block_n=256, block_kw=4, word_group=3),
    )
    auto = ops.xnor_gemm(wp, xp, k, interpret=True)  # block_*="auto"
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(fixed))


def test_unpack_gemm_auto_blocks_ragged(cache_file):
    """Satellite (ISSUE 5): unpack_gemm now resolves AUTO blocks (the
    last fixed-tile wrapper) and clamps explicit ints, so ragged shapes
    — the 10-output head with K % 32 != 0 — never trip the kernel's
    divisibility asserts, with results identical to the XLA unpack."""
    from repro.core import bitops

    m, k, n = 10, 40, 3
    key = jax.random.PRNGKey(2)
    w = jnp.where(jax.random.bernoulli(key, 0.5, (m, k)), 1.0, -1.0)
    wpad = jnp.pad(w, ((0, 0), (0, -k % PACK_BITS)), constant_values=-1.0)
    wp = bitops.pack_bits(wpad, axis=-1)
    x = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    xz = jnp.pad(x, ((0, -k % PACK_BITS), (0, 0)))  # zero K-pad rows
    want = np.asarray(w @ x)
    got = ops.unpack_gemm(wp, xz, interpret=True)[:, :n]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    # oversized explicit blocks are clamped, not fatal
    got2 = ops.unpack_gemm(wp, xz, block_m=512, block_n=1024, block_kw=64,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(got))
    # the unpack VMEM model is the one consulted: modeled footprint of
    # the heuristic config fits the budget
    cfg = autotune.heuristic_gemm_blocks(m, wp.shape[1], n, unpack=True)
    assert autotune.gemm_step_vmem(
        cfg.block_m, cfg.block_n, cfg.block_kw, unpack=True
    ) <= autotune.VMEM_BUDGET_BYTES


def test_block_kwargs_surface():
    cfg = BlockConfig(block_m=64, block_n=256, block_kw=4, word_group=2)
    assert autotune.block_kwargs("auto") == {}
    assert autotune.block_kwargs(cfg) == {
        "block_m": 64, "block_n": 256, "block_kw": 4, "word_group": 2
    }
    assert autotune.block_kwargs(cfg, conv=True) == {
        "block_d": 64, "word_group": 2
    }
    with pytest.raises(TypeError):
        autotune.block_kwargs({"block_m": 64})
