"""Resilience layer (DESIGN.md §11): deterministic fault injection,
deadlines, bounded retry with backoff, bit-identical engine failover,
and elastic mesh shrink — all under fake clocks so every schedule
replays exactly."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bnn import (
    SERVE_FALLBACKS,
    bnn_apply_fused,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.distributed.fault_tolerance import (
    serving_shrink_plan,
    shrink_serving_mesh,
)
from repro.launch.mesh import make_serving_mesh
from repro.serve import (
    ContinuousServingEngine,
    DeadlineExceeded,
    DeviceLost,
    FallbackPolicy,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    NaNLogits,
    QueueFull,
    RequestFailed,
    RetryPolicy,
    ServeStats,
    ServingEngine,
    is_error,
)

KEY = jax.random.PRNGKey(99)


class FakeClock:
    """Deterministic clock for queue tests: advances only on demand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def fused_params():
    return pack_bnn_params_fused(init_bnn_params(KEY))


@pytest.fixture(scope="module")
def mega_params():
    return pack_bnn_params_megakernel(init_bnn_params(KEY))


@pytest.fixture(scope="module")
def images():
    return np.asarray(
        jax.random.normal(jax.random.fold_in(KEY, 1), (8, 32, 32, 3))
    )


def oracle(fused_params, imgs):
    return np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs)))


# ---------------------------------------------------------------------------
# FaultSpec / FaultPlan — pure policy
# ---------------------------------------------------------------------------

def test_fault_spec_matching_window_and_wildcards():
    s = FaultSpec("raise", at=3, count=2)
    assert not s.matches(2, 8, "xla")
    assert s.matches(3, 8, "xla")
    assert s.matches(4, 1, "xnor")       # extent/engine are wildcards
    assert not s.matches(5, 8, "xla")
    pinned = FaultSpec("nan", at=0, count=10, extent=8, engine="xla")
    assert pinned.matches(0, 8, "xla")
    assert not pinned.matches(0, 4, "xla")
    assert not pinned.matches(0, 8, "megakernel_xla")
    with pytest.raises(ValueError):
        FaultSpec("segfault")


def test_fault_plan_specs_win_over_random():
    plan = FaultPlan([FaultSpec("raise", at=1)], rate=1.0, seed=0)
    hit = plan.match(1, 8, "xla")
    assert hit is not None and hit.kind == "raise" and hit.at == 1
    # index 0 has no spec but rate=1.0 always fires randomly
    assert plan.match(0, 8, "xla") is not None


def test_fault_plan_random_schedule_is_deterministic():
    """The random layer is a pure function of (seed, index): two plans
    agree index by index, retries cannot reshuffle the schedule, and a
    different seed gives a different schedule."""
    a = FaultPlan(rate=0.3, seed=7)
    b = FaultPlan(rate=0.3, seed=7)
    sched_a = [getattr(a.match(i, 8, "xla"), "kind", None) for i in range(64)]
    # consult b out of order and repeatedly — same answers
    for i in reversed(range(64)):
        b.match(i, 8, "xla")
    sched_b = [getattr(b.match(i, 8, "xla"), "kind", None) for i in range(64)]
    assert sched_a == sched_b
    assert any(k is not None for k in sched_a)
    assert any(k is None for k in sched_a)
    c = FaultPlan(rate=0.3, seed=8)
    sched_c = [getattr(c.match(i, 8, "xla"), "kind", None) for i in range(64)]
    assert sched_a != sched_c
    assert all(k in (None, "raise", "nan", "latency") for k in sched_a)


def test_fault_plan_validates_inputs():
    with pytest.raises(ValueError):
        FaultPlan(rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(rate=0.1, kinds=("raise", "explode"))
    assert FaultPlan(rate=0.0).match(0, 8, "xla") is None


def test_fault_plan_records_fired_schedule():
    plan = FaultPlan([FaultSpec("latency", at=2, latency_s=0.5)])
    spec = plan.match(2, 4, "xla")
    plan.on_fire(2, spec, 4, "xla")
    assert plan.fired == [
        {"index": 2, "kind": "latency", "extent": 4, "engine": "xla"}
    ]


# ---------------------------------------------------------------------------
# RetryPolicy — capped exponential backoff, deterministic jitter
# ---------------------------------------------------------------------------

def test_retry_backoff_capped_exponential_without_jitter():
    p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=0.5, jitter=0.0)
    assert p.delay_s(1, 0) == pytest.approx(0.1)
    assert p.delay_s(2, 1) == pytest.approx(0.2)
    assert p.delay_s(3, 2) == pytest.approx(0.4)
    assert p.delay_s(4, 3) == pytest.approx(0.5)   # capped
    assert p.delay_s(9, 4) == pytest.approx(0.5)


def test_retry_backoff_jitter_is_bounded_and_deterministic():
    p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=1.0, jitter=0.25,
                    seed=3)
    for event in range(32):
        d = p.delay_s(1, event)
        assert 0.075 <= d <= 0.125
        assert d == p.delay_s(1, event)   # same event -> same delay
    assert len({p.delay_s(1, e) for e in range(32)}) > 1
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)


# ---------------------------------------------------------------------------
# FallbackPolicy — the demotion ladder
# ---------------------------------------------------------------------------

def test_fallback_ladder_walks_serve_fallbacks():
    fb = FallbackPolicy(fused_params={"p": 1}, mega_params={"m": 1})
    assert fb.next_engine("megakernel") == "xnor"
    assert fb.next_engine("megakernel_xla") == "xla"
    assert fb.next_engine("xnor") == "xla"
    assert fb.next_engine("xla") is None
    assert SERVE_FALLBACKS["xla"] == ()


def test_fallback_ladder_skips_rungs_without_params():
    fused_only = FallbackPolicy(fused_params={"p": 1})
    assert fused_only.next_engine("megakernel") == "xnor"
    assert fused_only.params_for("xnor") == {"p": 1}
    with pytest.raises(ValueError):
        fused_only.params_for("megakernel")
    mega_only = FallbackPolicy(mega_params={"m": 1})
    # fused rungs unavailable: megakernel has nowhere to go
    assert mega_only.next_engine("megakernel") is None
    with pytest.raises(ValueError):
        FallbackPolicy(failures_before_demote=0)


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_request(fused_params, images):
    clk = FakeClock()
    eng = ServingEngine(fused_params, buckets=(8,), max_wait_s=10.0,
                        clock=clk)
    rid = eng.submit(images[:2], deadline_s=1.0)
    clk.advance(2.0)
    resolved = eng.step()
    assert resolved == [rid]
    res = eng.take(rid)
    assert isinstance(res, DeadlineExceeded) and is_error(res)
    assert res.deadline_s == 1.0 and res.waited_s == pytest.approx(2.0)
    snap = eng.snapshot()
    assert snap["requests"]["expired"] == 1
    assert snap["requests"]["images_expired"] == 2
    # the expired request left the queue: a later drain serves nothing
    assert eng.drain() == []


def test_deadline_enforced_at_dispatch_time(fused_params, images):
    """A request whose deadline passes after batching but before
    dispatch is dropped at the pump, and its batchmate is served
    bit-identically."""
    clk = FakeClock()
    eng = ServingEngine(fused_params, buckets=(2,), max_wait_s=10.0,
                        clock=clk)
    doomed = eng.submit(images[:1], deadline_s=1.0)
    safe = eng.submit(images[1:2])
    batches = eng.batcher.poll()       # full bucket of 2 assembled
    assert len(batches) == 1
    clk.advance(5.0)                   # deadline passes pre-dispatch
    eng._run(batches)
    assert isinstance(eng.take(doomed), DeadlineExceeded)
    np.testing.assert_array_equal(
        eng.take(safe), oracle(fused_params, images[1:2]))
    snap = eng.snapshot()
    assert snap["requests"]["expired"] == 1
    assert snap["requests"]["completed"] == 1


def test_engine_default_deadline_applies_to_every_submit(fused_params,
                                                         images):
    clk = FakeClock()
    eng = ServingEngine(fused_params, buckets=(8,), max_wait_s=10.0,
                        deadline_s=1.0, clock=clk)
    rid = eng.submit(images[:1])               # inherits engine default
    slow = eng.submit(images[1:2], deadline_s=50.0)   # per-request wins
    clk.advance(2.0)
    eng.step()
    assert isinstance(eng.take(rid), DeadlineExceeded)
    eng.drain()
    np.testing.assert_array_equal(
        eng.take(slow), oracle(fused_params, images[1:2]))


def test_cancel_clears_deadline_state(fused_params, images):
    clk = FakeClock()
    eng = ServingEngine(fused_params, buckets=(8,), max_wait_s=10.0,
                        clock=clk)
    rid = eng.submit(images[:1], deadline_s=1.0)
    assert eng.cancel(rid)
    clk.advance(5.0)
    assert eng.step() == []
    assert eng.take(rid) is None       # cancelled, not expired


# ---------------------------------------------------------------------------
# Retry with backoff
# ---------------------------------------------------------------------------

def test_transient_fault_retries_to_bit_identical_success(fused_params,
                                                          images):
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=1.0, jitter=0.0),
        faults=FaultPlan([FaultSpec("raise", at=0)], sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    assert eng.step() == []            # dispatch 0 faults -> backoff
    # backoff has not elapsed: the queue head blocks, nothing dispatches
    assert eng.step() == []
    assert eng.take(rid) is None
    clk.advance(1.5)
    assert eng.step() == [rid]
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images[:2]))
    snap = eng.snapshot()
    assert snap["dispatch"]["retries"] == 1
    assert snap["requests"]["retried"] == 1
    assert snap["requests"]["failed"] == 0
    assert snap["degraded"] is False   # a retry alone is not degraded
    assert eng.faults.fired[0]["kind"] == "raise"


def test_nan_fault_is_retried_not_served(fused_params, images):
    """NaN logits never reach a caller: the guard converts them into a
    retryable failure and the retry serves clean bits."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.0, jitter=0.0),
        faults=FaultPlan([FaultSpec("nan", at=0)], sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    eng.drain()
    out = eng.take(rid)
    assert not is_error(out)
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out, oracle(fused_params, images[:2]))


def test_nan_guard_catches_corrupted_executor(fused_params, images):
    """The guard is always-on, not fault-plan-only: a kernel silently
    producing non-finite logits fails the dispatch."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=1),
    )
    real_run = eng.executors.run
    eng.executors.run = lambda x: np.full((x.shape[0], 10), np.nan,
                                          np.float32)
    rid = eng.submit(images[:2])
    eng.step()
    eng.executors.run = real_run
    res = eng.take(rid)
    assert isinstance(res, RequestFailed)
    assert "NaNLogits" in res.error


def test_retry_exhaustion_fails_requests_and_engine_survives(fused_params,
                                                             images):
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0, jitter=0.0),
        faults=FaultPlan([FaultSpec("raise", at=0, count=2)],
                         sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    eng.drain()
    res = eng.take(rid)
    assert isinstance(res, RequestFailed)
    assert res.attempts == 2 and "InjectedFault" in res.error
    snap = eng.snapshot()
    assert snap["requests"]["failed"] == 1
    assert snap["requests"]["images_failed"] == 2
    # the engine is not poisoned: the next request serves cleanly
    rid2 = eng.submit(images[2:4])
    eng.step()
    eng.drain()
    np.testing.assert_array_equal(
        eng.take(rid2), oracle(fused_params, images[2:4]))


def test_failed_batch_does_not_strand_batchmates(fused_params, images):
    """Regression for the §11 bugfix: one poisoned batch completes its
    own riders as RequestFailed and the NEXT batch in the same pump
    still dispatches — a dispatch exception no longer unwinds the loop
    and strands everything behind it."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=1),
        faults=FaultPlan([FaultSpec("raise", at=0)], sleep=clk.advance),
    )
    poisoned = eng.submit(images[:2])
    healthy = eng.submit(images[2:4])
    resolved = eng.step()              # two full buckets in one poll
    assert set(resolved) == {poisoned, healthy}
    assert isinstance(eng.take(poisoned), RequestFailed)
    np.testing.assert_array_equal(
        eng.take(healthy), oracle(fused_params, images[2:4]))


def test_backoff_preserves_fifo_order(fused_params, images):
    """A batch in backoff blocks the queue head: later batches must not
    overtake it, so completion order among successes stays FIFO."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=1.0, jitter=0.0),
        faults=FaultPlan([FaultSpec("raise", at=0)], sleep=clk.advance),
    )
    first = eng.submit(images[:2])
    eng.step()                         # first batch faults, backs off
    second = eng.submit(images[2:4])
    assert eng.step() == []            # second must wait behind first
    clk.advance(1.5)
    resolved = eng.step()
    assert resolved == [first, second]
    np.testing.assert_array_equal(
        eng.take(first), oracle(fused_params, images[:2]))
    np.testing.assert_array_equal(
        eng.take(second), oracle(fused_params, images[2:4]))


def test_drain_forces_through_backoff(fused_params, images):
    """drain() must leave nothing unresolved even when backoff has not
    elapsed on the fake clock."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=1e9, jitter=0.0),
        faults=FaultPlan([FaultSpec("raise", at=0)], sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    assert eng.step() == []            # blocked behind a huge backoff
    assert eng.drain() == [rid]
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images[:2]))


def test_latency_fault_goes_through_sleep_hook(fused_params, images):
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        faults=FaultPlan([FaultSpec("latency", at=0, latency_s=3.0)],
                         sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    assert clk.t == pytest.approx(3.0)     # slept on the fake clock
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images[:2]))


# ---------------------------------------------------------------------------
# Engine failover
# ---------------------------------------------------------------------------

def test_failover_demotes_and_serves_bit_identical(fused_params,
                                                   mega_params, images):
    """Two consecutive megakernel_xla failures demote to xla; because
    every rung is bit-identical, post-failover logits match the fused
    oracle exactly."""
    clk = FakeClock()
    eng = ServingEngine(
        mega_params, engine="megakernel_xla", buckets=(2,),
        max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=5, backoff_base_s=0.0, jitter=0.0),
        fallback=FallbackPolicy(fused_params=fused_params,
                                mega_params=mega_params,
                                failures_before_demote=2),
        faults=FaultPlan(
            [FaultSpec("raise", at=0, count=2, engine="megakernel_xla")],
            sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    eng.drain()
    assert eng.executors.engine == "xla"
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images[:2]))
    snap = eng.snapshot()
    assert snap["dispatch"]["fallbacks"] == 1
    assert snap["dispatch"]["engine_path"] == ["megakernel_xla->xla"]
    assert snap["degraded"] is True


def test_failover_hot_standby_swaps_without_recompile(fused_params,
                                                      mega_params, images):
    """prewarm_fallback builds the next rung ahead of time; the later
    demotion swaps it in and serving continues with ZERO new compiles."""
    clk = FakeClock()
    eng = ServingEngine(
        mega_params, engine="megakernel_xla", buckets=(2,),
        max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=5, backoff_base_s=0.0, jitter=0.0),
        fallback=FallbackPolicy(fused_params=fused_params,
                                mega_params=mega_params,
                                failures_before_demote=2),
        faults=FaultPlan(
            [FaultSpec("raise", at=0, count=2, engine="megakernel_xla")],
            sleep=clk.advance),
    )
    eng.warmup()
    assert eng.prewarm_fallback() > 0
    standby = eng._standby
    assert standby is not None and standby.engine == "xla"
    compiled_before = len(standby._fns)
    eng.submit(images[:2])
    eng.step()
    eng.drain()                        # dispatch 0,1 fault -> demote
    assert eng.executors is standby    # the hot standby was swapped in
    assert eng._standby is None
    rid = eng.submit(images[2:4])
    eng.step()
    eng.drain()
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images[2:4]))
    assert len(eng.executors._fns) == compiled_before   # no new compiles


def test_ladder_exhausted_engine_fails_requests(fused_params, images):
    """On the bottom rung (xla) with nowhere to demote, a persistent
    fault exhausts retries into RequestFailed — no demotion loop."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, engine="xla", buckets=(2,), max_wait_s=0.0,
        clock=clk,
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.0, jitter=0.0),
        fallback=FallbackPolicy(fused_params=fused_params,
                                failures_before_demote=1),
        faults=FaultPlan([FaultSpec("raise", at=0, count=5)],
                         sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    eng.drain()
    assert isinstance(eng.take(rid), RequestFailed)
    assert eng.executors.engine == "xla"
    assert eng.snapshot()["dispatch"]["fallbacks"] == 0


# ---------------------------------------------------------------------------
# Elastic mesh shrink
# ---------------------------------------------------------------------------

def test_serving_shrink_plan_largest_power_of_two():
    assert serving_shrink_plan(8) == 8
    assert serving_shrink_plan(7) == 4
    assert serving_shrink_plan(4) == 4
    assert serving_shrink_plan(3) == 2
    assert serving_shrink_plan(1) == 1
    assert serving_shrink_plan(0) == 0


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (conftest forces 8 host "
                           "devices before any jax import)")
def test_shrink_serving_mesh_helper():
    mesh = make_serving_mesh(8)
    shrunk = shrink_serving_mesh(mesh, (5,))
    assert shrunk.shape == {"data": 4}      # 7 survivors -> 4
    dead5 = set(np.asarray(shrunk.devices).flat)
    assert np.asarray(mesh.devices).flat[5] not in dead5
    assert shrink_serving_mesh(mesh, (99,)) is None   # invalid index
    one = make_serving_mesh(1)
    assert shrink_serving_mesh(one, (0,)) is None     # nothing survives


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (conftest forces 8 host "
                           "devices before any jax import)")
def test_device_loss_shrinks_mesh_and_redispatches(fused_params, images):
    """A DeviceLost dispatch shrinks 8 -> 4, re-dispatches the in-flight
    batch without charging its retry budget, and steady state on the
    shrunk mesh adds zero compiles after the re-warm."""
    clk = FakeClock()
    eng = ContinuousServingEngine(
        fused_params, engine="xla", max_rows=8, max_wait_s=0.0,
        mesh=make_serving_mesh(8), clock=clk,
        retry=RetryPolicy(max_attempts=1),   # loss must not burn it
        faults=FaultPlan([FaultSpec("device_loss", at=1, device=5)],
                         sleep=clk.advance),
    )
    eng.warmup()
    a = eng.submit(images[:3])
    eng.step()
    eng.drain()                        # dispatch 0 clean
    b = eng.submit(images[3:6])
    eng.step()
    eng.drain()                        # dispatch 1 loses device 5
    np.testing.assert_array_equal(
        eng.take(a), oracle(fused_params, images[:3]))
    np.testing.assert_array_equal(
        eng.take(b), oracle(fused_params, images[3:6]))
    assert eng.executors.devices == 4
    snap = eng.snapshot()
    assert snap["mesh"]["shrinks"] == 1
    assert snap["mesh"]["devices"] == 4
    assert snap["degraded"] is True
    assert snap["requests"]["failed"] == 0
    # extent ladder recomputed at the survivor multiple
    assert all(e % 4 == 0 for e in eng.extents)
    # steady state on the shrunk mesh: zero further compiles
    compiled = len(eng.executors._fns)
    c = eng.submit(images[:5])
    eng.step()
    eng.drain()
    np.testing.assert_array_equal(
        eng.take(c), oracle(fused_params, images[:5]))
    assert len(eng.executors._fns) == compiled


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (conftest forces 8 host "
                           "devices before any jax import)")
def test_heartbeat_timeout_triggers_shrink(fused_params, images):
    """A device that stops beating is treated like a mid-dispatch loss:
    the next step() shrinks the mesh before dispatching."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, engine="xla", buckets=(8,), max_wait_s=0.0,
        mesh=make_serving_mesh(8), heartbeat_timeout_s=10.0, clock=clk,
    )
    assert eng.monitor is not None
    clk.advance(5.0)
    for dev in range(8):
        if dev != 3:
            eng.beat(dev)
    clk.advance(7.0)                   # device 3 silent past timeout
    rid = eng.submit(images)
    eng.step()
    eng.drain()
    assert eng.executors.devices == 4
    assert eng.snapshot()["mesh"]["shrinks"] == 1
    # the monitor was rebuilt for the shrunk mesh
    assert len(eng.monitor.last_beat) == 4
    np.testing.assert_array_equal(
        eng.take(rid), oracle(fused_params, images))


def test_device_loss_without_mesh_is_ordinary_failure(fused_params, images):
    """Unmeshed engine: DeviceLost cannot shrink, so it burns retry
    budget like any other dispatch failure."""
    clk = FakeClock()
    eng = ServingEngine(
        fused_params, buckets=(2,), max_wait_s=0.0, clock=clk,
        retry=RetryPolicy(max_attempts=1),
        faults=FaultPlan([FaultSpec("device_loss", at=0, device=0)],
                         sleep=clk.advance),
    )
    rid = eng.submit(images[:2])
    eng.step()
    res = eng.take(rid)
    assert isinstance(res, RequestFailed) and "DeviceLost" in res.error


# ---------------------------------------------------------------------------
# Admission control backoff hint
# ---------------------------------------------------------------------------

def test_queuefull_hint_falls_back_to_max_wait(fused_params, images):
    clk = FakeClock()
    eng = ContinuousServingEngine(
        fused_params, max_rows=4, max_wait_s=0.25, max_queue_rows=4,
        clock=clk,
    )
    eng.submit(images[:4])
    with pytest.raises(QueueFull) as exc:
        eng.submit(images[4:6])
    # no service observation yet: hint degrades to the coalescing wait
    assert exc.value.retry_after_s == pytest.approx(0.25)
    assert eng.snapshot()["requests"]["rejected"] == 1


def test_queuefull_hint_uses_service_ewma(fused_params, images):
    clk = FakeClock()
    eng = ContinuousServingEngine(
        fused_params, max_rows=4, max_wait_s=0.25, max_queue_rows=4,
        clock=clk,
    )
    eng.batcher.note_service(4, 2.0)       # 0.5 s/row observed
    eng.submit(images[:4])
    with pytest.raises(QueueFull) as exc:
        eng.submit(images[4:6])            # 2 rows past the bound
    assert exc.value.retry_after_s == pytest.approx(
        eng.batcher.est_service_s(2))
    assert exc.value.retry_after_s == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Stats surface
# ---------------------------------------------------------------------------

def test_stats_resilience_counters_and_degraded_flag():
    s = ServeStats()
    snap = s.snapshot()
    assert snap["requests"]["expired"] == 0
    assert snap["requests"]["failed"] == 0
    assert snap["requests"]["retried"] == 0
    assert snap["dispatch"]["retries"] == 0
    assert snap["dispatch"]["fallbacks"] == 0
    assert snap["dispatch"]["engine_path"] == []
    assert snap["mesh"]["shrinks"] == 0
    assert snap["degraded"] is False
    s.on_expire(3)
    s.on_fail(2)
    s.on_retry(4)
    s.on_fallback("megakernel", "xnor")
    s.on_fallback("xnor", "xla")
    s.on_shrink(8, 4)
    snap = s.snapshot()
    assert snap["requests"]["expired"] == 1
    assert snap["requests"]["images_expired"] == 3
    assert snap["requests"]["failed"] == 1
    assert snap["requests"]["images_failed"] == 2
    assert snap["requests"]["retried"] == 4
    assert snap["dispatch"]["retries"] == 1
    assert snap["dispatch"]["fallbacks"] == 2
    assert snap["dispatch"]["engine_path"] == ["megakernel->xnor",
                                               "xnor->xla"]
    assert snap["mesh"]["shrinks"] == 1
    assert snap["mesh"]["devices"] == 4
    assert snap["degraded"] is True
