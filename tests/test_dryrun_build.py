"""Dry-run cell construction (eval_shape only — no 512-device compile;
the full compile sweep runs via launch/dryrun.py and its artifacts are
checked into experiments/dryrun/)."""

import jax
import pytest

from repro.configs import ASSIGNED, SHAPES, cell_applicable, get_config
from repro.launch.dryrun import build_cell


def test_cell_grid_is_40_with_8_skips():
    cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
    skipped = [
        (a, s) for a, s in cells
        if not cell_applicable(get_config(a), SHAPES[s])[0]
    ]
    # 8 pure full-attention archs skip long_500k only
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("moonshot-v1-16b-a3b", "decode_32k"),
    ("seamless-m4t-large-v2", "decode_32k"),
    ("jamba-1.5-large-398b", "long_500k"),
    ("xlstm-1.3b", "prefill_32k"),
])
def test_build_cell_shapes(arch, shape):
    step, args, donate, model_flops, meta = build_cell(arch, shape)
    assert model_flops > 0
    assert len(args) == 3
    # every arg leaf is an abstract stand-in (no allocation)
    for leaf in jax.tree.leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    assert meta["n_active"] <= meta["n_params"]


def test_param_counts_match_config_scale():
    _, args, _, _, meta = build_cell("mistral-large-123b", "train_4k")
    assert 1.1e11 < meta["n_params"] < 1.4e11     # ~123B
    _, _, _, _, meta = build_cell("moonshot-v1-16b-a3b", "train_4k")
    # assigned hyperparams (48L x 64e x d_ff 1408) give 28B total; the
    # "a3b" active count is the one that matches the model card
    assert 2.0e10 < meta["n_params"] < 3.5e10
    assert 2.5e9 < meta["n_active"] < 4.5e9       # ~3B active


def test_decode_cell_uses_packed_params():
    _, (params, state, batch), _, _, _ = build_cell(
        "qwen2.5-3b", "decode_32k")
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    packed = [p for p, _ in leaves
              if any(getattr(k, "key", "") == "w_packed" for k in p)]
    assert packed, "serving cells must carry packed 1-bit weights"
    assert "k" in str(jax.tree_util.tree_structure(state))