"""Chaos-hardened elastic training: train/resilience.py (DESIGN.md §13).

The invariants under test:

* a fault-free resilient run is bit-identical to ``train_bnn`` — the
  wrapper adds monitoring, not math;
* any transient fault (preemption, NaN batch, torn checkpoint, device
  loss) is recovered with the final params bit-identical to the
  uninterrupted run at the same device trajectory — the stateless
  (seed, step) data stream makes every replay exact;
* the sign-SGD error-feedback residuals survive an 8 -> 4 elastic
  shrink with their mass conserved (asserted by the driver itself);
* the loss sentinel classifies NaN/inf and z-score spikes, never lets
  a poisoned loss into its own baseline, and a sticky poison gets its
  batch skipped instead of rolling back forever.

z-score spike detection is unit-tested on a synthetic loss stream:
at the 6-step/batch-8 test scale, training-loss noise (sd ~0.4) swamps
any finite batch poison (~+0.3), so an organic end-to-end z-trip
cannot be made deterministic — the e2e rollback path is exercised via
NaN faults, which share every line past the verdict.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.bnn_trainer import BNNTrainerConfig, train_bnn
from repro.train.resilience import (
    LossSentinel,
    ResilienceConfig,
    TrainFaultPlan,
    TrainFaultSpec,
    fold_error_feedback,
    train_bnn_resilient,
)


def _cfg(tmp, **kw):
    base = dict(steps=6, batch=8, checkpoint_every=2, eval_batches=0,
                checkpoint_dir=str(tmp))
    base.update(kw)
    return BNNTrainerConfig(**base)


def _identical(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))


@pytest.fixture(scope="module")
def baseline():
    """One uninterrupted train_bnn run every recovery test compares to."""
    with tempfile.TemporaryDirectory() as d:
        return train_bnn(_cfg(d))


# ------------------------------ fault plan ------------------------------------


def test_fault_plan_one_shot_consumption():
    plan = TrainFaultPlan([TrainFaultSpec("nan_batch", at=3)])
    assert plan.match(2) is None
    assert plan.match(3) is not None
    assert plan.match(3) is None        # the replay sees the clean step
    assert plan.steps_of("nan_batch") == [3]


def test_fault_plan_sticky_refires():
    plan = TrainFaultPlan([TrainFaultSpec("nan_batch", at=3, sticky=True)])
    assert plan.match(3) is not None
    assert plan.match(3) is not None


def test_fault_plan_torn_only_matches_saves():
    plan = TrainFaultPlan([TrainFaultSpec("torn_ckpt", at=4)])
    assert plan.match(4) is None
    assert plan.match_save(4) is not None


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown train fault kind"):
        TrainFaultSpec("meteor", at=0)


# ------------------------------ loss sentinel ---------------------------------


def test_sentinel_classifies_nan_and_spike():
    s = LossSentinel(window=8, z=3.0, min_history=4)
    for i, loss in enumerate([2.0, 1.9, 1.85, 1.8, 1.75, 1.7]):
        assert s.check(i, loss) is None
    assert s.check(6, float("nan")) == "nan"
    assert s.check(7, 50.0) == "spike"
    assert [e["kind"] for e in s.events] == ["nan", "spike"]


def test_sentinel_poisoned_loss_never_enters_baseline():
    s = LossSentinel(window=8, z=3.0, min_history=4)
    clean = [2.0, 1.9, 1.85, 1.8]
    for i, loss in enumerate(clean):
        s.check(i, loss)
    s.check(4, 1e9)                     # spike must not drag the mean up
    assert s.check(5, 1e9) == "spike"   # ... so the SAME value trips again
    assert list(s._hist) == clean


def test_sentinel_waits_for_min_history():
    s = LossSentinel(window=8, z=3.0, min_history=4)
    assert s.check(0, 100.0) is None    # too little history to judge
    assert s.check(1, 0.1) is None


# ------------------------------ EF folding ------------------------------------


def test_fold_error_feedback_conserves_mass():
    rng = np.random.default_rng(0)
    err = {"w": jnp.asarray(rng.normal(size=(8, 4, 3)).astype(np.float32)),
           "b": jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))}
    folded, report = fold_error_feedback(err, 4)
    assert jax.tree.leaves(folded)[0].shape[0] == 4
    assert report["n_old"] == 8 and report["n_new"] == 4
    assert report["max_rel_delta"] <= 1e-5
    for k in err:
        np.testing.assert_allclose(
            np.asarray(folded[k]).sum(), np.asarray(err[k]).sum(), rtol=1e-5
        )


def test_fold_error_feedback_grow_pads_zeros():
    err = {"w": jnp.ones((2, 3))}
    folded, report = fold_error_feedback(err, 4)
    assert folded["w"].shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(folded["w"][2:]), 0.0)
    assert report["max_rel_delta"] == 0.0


# ------------------------------ resilient driver ------------------------------


def test_resilient_requires_checkpoint_dir():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        train_bnn_resilient(
            BNNTrainerConfig(steps=2, checkpoint_dir=None))


def test_fault_free_resilient_matches_train_bnn(baseline, tmp_path):
    r = train_bnn_resilient(_cfg(tmp_path))
    assert _identical(baseline.params, r.params)
    np.testing.assert_array_equal(baseline.history["loss"],
                                  r.history["loss"])
    assert r.recomputed_steps == 0 and r.events == []


def test_preemption_resumes_bit_identical(baseline, tmp_path):
    plan = TrainFaultPlan([TrainFaultSpec("preempt", at=3)])
    r = train_bnn_resilient(_cfg(tmp_path), faults=plan)
    assert _identical(baseline.params, r.params)
    # restore from the step-2 checkpoint: exactly one step recomputed,
    # bounded by the checkpoint cadence
    assert r.recomputed_steps == 1 <= 2
    assert [e["kind"] for e in r.events] == ["preempt"]
    assert r.restore_points and r.restore_points[0]["step"] == 2


def test_nan_batch_sentinel_rolls_back(baseline, tmp_path):
    plan = TrainFaultPlan([TrainFaultSpec("nan_batch", at=3)])
    r = train_bnn_resilient(_cfg(tmp_path), faults=plan)
    assert _identical(baseline.params, r.params)     # poison discarded
    kinds = [e["kind"] for e in r.events]
    assert kinds == ["nan_batch", "sentinel_nan"]
    assert r.events[1]["step"] == 3
    assert len(r.history["loss"]) == 6               # every step recovered


def test_torn_checkpoint_falls_back_to_fresh_init(baseline, tmp_path):
    # The ONLY checkpoint so far (step 2) is torn; the preemption at
    # step 3 then finds nothing valid and must replay from scratch —
    # still bit-identical, with the full 3 steps recomputed.
    plan = TrainFaultPlan([
        TrainFaultSpec("torn_ckpt", at=2),
        TrainFaultSpec("preempt", at=3),
    ])
    r = train_bnn_resilient(_cfg(tmp_path), faults=plan)
    assert _identical(baseline.params, r.params)
    assert r.recomputed_steps == 3
    assert {"kind": "restored_fresh", "step": 0} in r.events


def test_sticky_nan_poison_skips_the_batch(tmp_path):
    plan = TrainFaultPlan([TrainFaultSpec("nan_batch", at=3, sticky=True)])
    r = train_bnn_resilient(
        _cfg(tmp_path), faults=plan,
        resilience=ResilienceConfig(max_rollbacks_per_step=2),
    )
    assert r.skipped_steps == [3]
    assert _finite(r.params)
    assert "poisoned_window_skipped" in [e["kind"] for e in r.events]
    assert len(r.history["loss"]) == 5               # all but the skip


# ------------------------------ elastic shrink (8 devices) --------------------


needs_8 = pytest.mark.skipif(jax.device_count() < 8,
                             reason="needs 8 (simulated) devices")


@needs_8
def test_device_loss_shrinks_8_to_4_and_folds_ef(tmp_path):
    plan = TrainFaultPlan([TrainFaultSpec("device_loss", at=4, host=6)])
    r = train_bnn_resilient(
        _cfg(tmp_path, batch=16), faults=plan, n_devices=8,
        grad_compression="signsgd",
    )
    assert r.n_devices == 4
    assert r.device_trajectory == [(0, 8), (4, 4)]
    assert jax.tree.leaves(r.err)[0].shape[0] == 4
    kinds = [e["kind"] for e in r.events]
    assert kinds == ["device_loss", "elastic_shrink", "ef_folded"]
    fold = r.events[2]
    assert fold["n_old"] == 8 and fold["n_new"] == 4
    assert fold["max_rel_delta"] <= 1e-5             # mass conserved
    assert _finite(r.params)
    assert len(r.history["loss"]) == 6
    # latent clip invariant survives recovery: binarized latents in [-1, 1]
    for path in ("conv", "fc"):
        for layer in r.params[path]:
            w = np.asarray(layer["w"])
            assert np.all(np.abs(w) <= 1.0 + 1e-6)


@needs_8
def test_straggler_eviction_triggers_shrink(tmp_path):
    # Host 7 reports 10x step times; after `patience` strikes the
    # detector evicts it like a dead worker -> same shrink path.
    plan = TrainFaultPlan(
        [TrainFaultSpec("straggler", at=1, count=4, host=7)])
    r = train_bnn_resilient(
        _cfg(tmp_path, batch=16), faults=plan, n_devices=8,
        grad_compression="signsgd",
        resilience=ResilienceConfig(straggler_patience=3),
    )
    assert r.n_devices == 4
    kinds = [e["kind"] for e in r.events]
    assert "straggler_evicted" in kinds and "elastic_shrink" in kinds
    evict = next(e for e in r.events if e["kind"] == "straggler_evicted")
    assert evict["hosts"] == [7]
    assert _finite(r.params)
