"""mLSTM chunk Pallas kernel vs the model's chunkwise oracle
(models/xlstm.py) — both implement the same stabilized recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm_chunk import mlstm_chunked
from repro.models.xlstm import mlstm_cell


def _inputs(bh, s, dk, dv, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (bh, s, dk))
    k = jax.random.normal(ks[1], (bh, s, dk))
    v = jax.random.normal(ks[2], (bh, s, dv))
    logi = jax.random.normal(ks[3], (bh, s))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (bh, s)) + 1.0)
    return q, k, v, logi, logf


@pytest.mark.parametrize("bh,s,dk,chunk", [
    (2, 128, 64, 32),
    (1, 256, 32, 64),
    (3, 64, 128, 64),
])
def test_mlstm_kernel_matches_oracle(bh, s, dk, chunk):
    q, k, v, logi, logf = _inputs(bh, s, dk, dk)
    # oracle path: mlstm_cell expects [B, S, H, dh]; use H=1 per bh row
    y_ref, st_ref = mlstm_cell(
        q[:, :, None, :] * dk**0.5,  # mlstm_cell scales internally
        k[:, :, None, :], v[:, :, None, :],
        logi[:, :, None], logf[:, :, None],
        None, chunk=chunk,
    )
    y, C, n, m = mlstm_chunked(q, k, v, logi, logf, chunk=chunk,
                               interpret=True)
    np.testing.assert_allclose(
        y, y_ref[:, :, 0, :], atol=2e-5, rtol=2e-5)
    # carried state matches too (prefill -> decode handoff)
    np.testing.assert_allclose(C, st_ref["C"][:, 0], atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(n[:, 0], st_ref["n"][:, 0], atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(m[:, 0, 0], st_ref["m"][:, 0], atol=2e-5,
                               rtol=2e-5)


def test_mlstm_kernel_chunk_invariance():
    q, k, v, logi, logf = _inputs(2, 128, 32, 32, seed=7)
    a, *_ = mlstm_chunked(q, k, v, logi, logf, chunk=32, interpret=True)
    b, *_ = mlstm_chunked(q, k, v, logi, logf, chunk=128, interpret=True)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
