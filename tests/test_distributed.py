"""Distribution substrate: sharding rules, gradient compression,
fault tolerance, elastic meshes, checkpointing."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.distributed import compression, sharding
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    WorkerFailure,
    plan_mesh_for,
    run_with_recovery,
)


def _fake_mesh():
    """An abstract mesh shape for rule checks (1 real device is fine —
    specs are pure metadata)."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1, 1)
    m = Mesh(dev, ("pod", "data", "model"))
    # monkey-patch shape lookups: rules only read mesh.shape
    return m


class _ShapeMesh:
    """Duck-typed mesh exposing only .shape for the rule functions."""

    def __init__(self, **axes):
        self.shape = axes


MESH = _ShapeMesh(pod=2, data=16, model=16)


def _leaf(*shape):
    # The rule functions read only np.shape(leaf); an abstract value
    # keeps frontier-scale cases (the FSDP one is 1.25 TB dense) from
    # actually allocating.
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_column_parallel_weight_spec():
    spec = sharding.param_spec(
        MESH, _path(["layers", 0, "attn", "q_proj", "w"]),
        _leaf(22, 12288, 12288),
    )
    assert spec == P(None, "model", ("pod", "data"))


def test_row_parallel_weight_spec():
    spec = sharding.param_spec(
        MESH, _path(["layers", 0, "ffn", "down_proj", "w"]),
        _leaf(22, 12288, 28672),
    )
    assert spec == P(None, ("pod", "data"), "model")


def test_expert_stack_spec_small_replicates_over_data():
    # moonshot-sized stack (184M elems): E over model, in-dim NOT FSDP'd
    # — FSDP there forces an [E,cap,d] partial-sum all-reduce per layer
    # (§Perf hc7)
    spec = sharding.param_spec(
        MESH, _path(["layers", 0, "moe", "up_proj", "w"]),
        _leaf(48, 64, 1408, 2048),
    )
    assert spec == P(None, "model", None, None)


def test_expert_stack_spec_big_gets_fsdp():
    # arctic-sized stack (4.5e9 elems): too big to replicate over data
    spec = sharding.param_spec(
        MESH, _path(["layers", 0, "moe", "up_proj", "w"]),
        _leaf(35, 128, 4864, 7168),
    )
    assert spec == P(None, "model", None, ("pod", "data"))


def test_indivisible_axis_left_unsharded():
    # 15 heads * 64 = 960 divides 16; but a dim of 17 must not shard
    spec = sharding.param_spec(
        MESH, _path(["q_proj", "w"]), np.zeros((17, 960)))
    assert spec == P(None, ("pod", "data"))


def test_packed_weight_spec_replicated_over_data():
    spec = sharding.param_spec(
        MESH, _path(["ffn", "up_proj", "w_packed"]), np.zeros((2560, 30)))
    assert spec == P("model", None)


def test_kv_cache_spec():
    spec = sharding.state_spec(
        MESH, _path(["kv", "k"]), _leaf(8, 128, 1024, 8, 128))
    assert spec == P(None, ("pod", "data"), "model", None, None)


def test_kv_cache_batch1_seq_sharded():
    spec = sharding.state_spec(
        MESH, _path(["kv", "k"]), np.zeros((8, 1, 2048, 8, 128)))
    assert spec == P(None, None, "model", None, None)


def test_serve_specs_replicate_weights_shard_batch():
    # DESIGN.md §10: the serving mesh contract — packed weights P()
    # on every device, batch axis over "data", collective-free.
    p_spec, x_spec, y_spec = sharding.serve_specs(_ShapeMesh(data=8))
    assert p_spec == P()
    assert x_spec == P("data") and y_spec == P("data")
    # a mesh without a "data" axis degrades to fully replicated
    p_spec, x_spec, y_spec = sharding.serve_specs(_ShapeMesh(model=4))
    assert (p_spec, x_spec, y_spec) == (P(), P(None), P(None))


def test_mesh_devices_counts_all_axes():
    assert sharding.mesh_devices(None) == 1
    assert sharding.mesh_devices(_ShapeMesh(data=8)) == 8
    assert sharding.mesh_devices(MESH) == 2 * 16 * 16


def _path(keys):
    out = []
    for k in keys:
        if isinstance(k, int):
            out.append(jax.tree_util.SequenceKey(k))
        else:
            out.append(jax.tree_util.DictKey(k))
    return tuple(out)


# ------------------------------ compression ----------------------------------


def test_compression_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compression.compress_decompress(g_true, err)
        acc = acc + deq
    # with error feedback the running sum converges to 50*g
    np.testing.assert_allclose(acc / 50, g_true, atol=1e-2)


def test_compression_single_round_is_int8_coarse():
    g = jnp.linspace(-1, 1, 255)
    deq, err = compression.compress_decompress(g, jnp.zeros_like(g))
    assert float(jnp.max(jnp.abs(err))) <= float(jnp.max(jnp.abs(g))) / 127


def test_psum_compressed_in_shard_map():
    if jax.device_count() < 1:
        pytest.skip("no devices")
    from jax.experimental.shard_map import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = jnp.arange(8, dtype=jnp.float32)

    def f(g):
        mean, err = compression.psum_compressed(g, jnp.zeros_like(g), "data")
        return mean

    out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(g)
    np.testing.assert_allclose(out, g, atol=0.05)


def test_signsgd_error_feedback_reduces_bias():
    """EF-sign-SGD (ISSUE 9): one round keeps only 1 bit/coordinate, but
    with error feedback the running sum of decompressed grads converges
    to the true gradient — the residual carries everything the sign
    threw away into later rounds.

    Unlike int8 (whose per-round error is already bounded by half a
    quantization step), a 1-bit code with one SHARED scale makes small
    coordinates oscillate around their true value — so the guarantee is
    the EF one: the time-averaged decompressed gradient converges, and
    keeps improving with more rounds (measured: mean |avg - g| of
    0.041 / 0.013 / 0.004 at 50 / 200 / 800 rounds)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))

    def avg_error(rounds):
        err = jnp.zeros_like(g_true)
        acc = jnp.zeros_like(g_true)
        for _ in range(rounds):
            deq, err = compression.signsgd_compress_decompress(g_true, err)
            acc = acc + deq
        return float(jnp.mean(jnp.abs(acc / rounds - g_true)))

    e50, e800 = avg_error(50), avg_error(800)
    assert e50 < 5e-2
    assert e800 < 1e-2
    assert e800 < e50 / 4  # genuinely converging, not plateaued


def test_signsgd_single_round_is_scaled_sign():
    g = jnp.linspace(-1.0, 1.0, 255)
    deq, err = compression.signsgd_compress_decompress(g, jnp.zeros_like(g))
    scale = float(jnp.mean(jnp.abs(g)))
    np.testing.assert_allclose(
        np.asarray(deq), scale * np.sign(np.where(g == 0, 1.0, g)),
        rtol=1e-6,
    )
    # lossless in the EF sense: deq + err reconstructs g exactly
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               atol=1e-6)


def test_psum_signsgd_in_shard_map():
    from jax.experimental.shard_map import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = jnp.arange(8, dtype=jnp.float32) - 3.5

    def f(g):
        mean, err = compression.psum_signsgd(g, jnp.zeros_like(g), "data")
        return mean, err

    mean, err = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(g)
    # single device: mean == scale * sign(g), and EF reconstructs g
    scale = float(jnp.mean(jnp.abs(g)))
    np.testing.assert_allclose(
        np.asarray(mean), scale * np.where(np.asarray(g) >= 0, 1.0, -1.0),
        rtol=1e-6,
    )
    np.testing.assert_allclose(np.asarray(mean + err), np.asarray(g),
                               atol=1e-6)


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices")
def test_signsgd_convergence_tracks_fp32():
    """Convergence gate (ISSUE 9): plain SGD on a 2-device least-squares
    problem, gradients all-reduced three ways — fp32 pmean, EF-int8, and
    1-bit EF-sign-SGD. Both compressed runs must reach (near) the fp32
    baseline's final loss: error feedback is exactly what makes 1-bit
    gradients usable, and this is the test that would catch losing it."""
    from jax.experimental.shard_map import shard_map

    n_dev, n, d, lr, steps = 2, 64, 8, 0.05, 300
    rng = np.random.default_rng(3)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    x = rng.normal(size=(n_dev, n, d)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n_dev, n)).astype(np.float32)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("data",))

    def run(reduce_fn):
        def shard_step(w, err, xs, ys):
            xs, ys = xs[0], ys[0]          # peel the shard axis
            g = 2.0 * xs.T @ (xs @ w - ys) / xs.shape[0]
            g, new_err = reduce_fn(g, err[0])
            return w - lr * g, new_err[None]

        step = jax.jit(shard_map(
            shard_step, mesh=mesh,
            in_specs=(P(), P("data"), P("data"), P("data")),
            out_specs=(P(), P("data")),
            check_rep=False,
        ))
        w = jnp.zeros((d,))
        err = jnp.zeros((n_dev, d))
        for _ in range(steps):
            w, err = step(w, err, x, y)
        resid = x.reshape(-1, d) @ w - y.reshape(-1)
        return float(jnp.mean(resid**2))

    loss_fp32 = run(lambda g, e: (jax.lax.pmean(g, "data"), e))
    loss_int8 = run(lambda g, e: compression.psum_compressed(g, e, "data"))
    loss_sign = run(lambda g, e: compression.psum_signsgd(g, e, "data"))
    # the problem's noise floor is ~1e-4; every run must solve it
    assert loss_fp32 < 5e-4
    assert loss_int8 < 5 * loss_fp32
    assert loss_sign < 5 * loss_fp32


# ---------------------------- fault tolerance ---------------------------------


def test_heartbeat_detects_dead_host():
    t = [0.0]
    mon = HeartbeatMonitor(num_hosts=3, timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 12.0
    assert mon.dead_hosts() == [2]
    with pytest.raises(WorkerFailure):
        mon.check()


def test_straggler_detector_flags_persistent_outlier():
    det = StragglerDetector(patience=3)
    flagged = []
    for _ in range(6):
        flagged = det.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0, 4: 5.0})
    assert 4 in flagged


def test_straggler_detector_uniform_fleet_never_flags():
    det = StragglerDetector(patience=2)
    for _ in range(20):
        assert det.observe({h: 1.0 for h in range(8)}) == []


def test_straggler_detector_recovery_resets_strikes():
    """A host that recovers before ``patience`` consecutive slow steps
    is never flagged — the strike counter resets on every fast step."""
    det = StragglerDetector(patience=3)
    fleet = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    for _ in range(10):
        assert det.observe({**fleet, 4: 5.0}) == []   # 1 strike
        assert det.observe({**fleet, 4: 1.0}) == []   # recovered: reset
    assert det.strikes[4] == 0


def test_plan_mesh_shrinks_elastically():
    assert plan_mesh_for(512).shape == (2, 16, 16)
    assert plan_mesh_for(256).shape == (16, 16)
    assert plan_mesh_for(240).shape == (15, 16)   # lost a host: data shrinks
    assert plan_mesh_for(8).shape == (8,)


def test_run_with_recovery_restores_after_failure():
    state = {"step": 0, "saved": 0, "failures_left": 1}

    def step_fn(step):
        if step == 3 and state["failures_left"]:
            state["failures_left"] -= 1
            raise WorkerFailure([1])
        state["step"] = step
        return {"step": step}

    def save_fn(step):
        state["saved"] = step

    def restore_fn():
        return state["saved"]

    mon = HeartbeatMonitor(num_hosts=2, timeout=1e9)
    out = run_with_recovery(
        num_steps=6, step_fn=step_fn, save_fn=save_fn,
        restore_fn=restore_fn, monitor=mon, checkpoint_every=2,
    )
    assert out["step"] == 5
    assert state["failures_left"] == 0


def test_run_with_recovery_gives_up_after_max_restarts():
    def step_fn(step):
        raise WorkerFailure([0])

    mon = HeartbeatMonitor(num_hosts=1, timeout=1e9)
    with pytest.raises(WorkerFailure):
        run_with_recovery(
            num_steps=4, step_fn=step_fn, save_fn=lambda s: None,
            restore_fn=lambda: 0, monitor=mon, max_restarts=2,
        )


def test_run_with_recovery_rebuilds_and_stops_monitoring_dead_hosts():
    """On failure the driver calls ``rebuild_fn`` with the dead hosts
    and evicts them from the heartbeat monitor, so a host that died
    once cannot re-trigger WorkerFailure on the next check."""
    state = {"failures_left": 1, "rebuilt_with": None}

    def step_fn(step):
        if step == 1 and state["failures_left"]:
            state["failures_left"] -= 1
            raise WorkerFailure([2, 1])
        return {"step": step}

    mon = HeartbeatMonitor(num_hosts=3, timeout=1e9)
    out = run_with_recovery(
        num_steps=3, step_fn=step_fn, save_fn=lambda s: None,
        restore_fn=lambda: 0, monitor=mon,
        rebuild_fn=lambda hosts: state.update(rebuilt_with=hosts),
    )
    assert out["step"] == 2
    assert state["rebuilt_with"] == [1, 2]   # sorted by WorkerFailure
    assert set(mon.last_beat) == {0}


def test_run_with_recovery_checkpoint_cadence():
    saves = []
    mon = HeartbeatMonitor(num_hosts=1, timeout=1e9)
    run_with_recovery(
        num_steps=10, step_fn=lambda s: {"step": s},
        save_fn=saves.append, restore_fn=lambda: 0, monitor=mon,
        checkpoint_every=3,
    )
    assert saves == [3, 6, 9]


# ------------------------------ checkpointing ---------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3))}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_valid_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_torn_write_is_skipped(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # corrupt step 2 (simulate crash mid-write)
    os.remove(os.path.join(tmp_path, "step_00000002", "MANIFEST.json"))
    assert ckpt.latest_valid_step(str(tmp_path)) == 1


def test_checkpoint_checksum_mismatch_is_skipped(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(str(tmp_path), 1, tree)
    shard = os.path.join(tmp_path, "step_00000001", "shard_00000.npz")
    with open(shard, "ab") as f:
        f.write(b"corruption")
    assert ckpt.latest_valid_step(str(tmp_path)) is None


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        w.save(s, {"x": jnp.full((4,), float(s))})
    w.close()
    assert ckpt.latest_valid_step(str(tmp_path)) == 3
    # retention pruned step 1
    assert not os.path.exists(os.path.join(tmp_path, "step_00000001"))
    out = ckpt.restore(str(tmp_path), 3, {"x": jnp.zeros((4,))})
    np.testing.assert_allclose(out["x"], 3.0)


def test_stray_entries_do_not_crash_latest_valid_step(tmp_path):
    # A stray non-conforming entry in the checkpoint dir (editor
    # leftover, half-renamed staging dir) must be skipped, not crash the
    # recovery path with int("abc").
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(str(tmp_path), 5, tree)
    for stray in ("step_abc", "step_", "step_7.tmp", "notes.txt"):
        p = os.path.join(tmp_path, stray)
        if stray.endswith(".txt"):
            with open(p, "w") as f:
                f.write("stray")
        else:
            os.makedirs(p)
    assert ckpt.latest_valid_step(str(tmp_path)) == 5


def test_stray_entries_do_not_crash_retain(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree)
    os.makedirs(os.path.join(tmp_path, "step_abc"))
    ckpt.retain(str(tmp_path), keep=2)
    assert ckpt.latest_valid_step(str(tmp_path)) == 4
    assert not os.path.exists(os.path.join(tmp_path, "step_00000001"))
    # the stray entry is left alone (retain only manages step dirs)
    assert os.path.exists(os.path.join(tmp_path, "step_abc"))


def test_restore_schema_mismatch_is_actionable(tmp_path):
    ckpt.save(str(tmp_path), 1, {"params": {"w": jnp.ones((2,))}})
    bad_like = {"params": {"w": jnp.zeros((2,)), "extra": jnp.zeros(())}}
    with pytest.raises(ValueError) as ei:
        ckpt.restore(str(tmp_path), 1, bad_like)
    msg = str(ei.value)
    assert "params/extra" in msg        # missing from the checkpoint
    assert "missing" in msg and "unexpected" in msg


def test_async_checkpointer_save_after_close_raises(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path))
    w.save(1, {"x": jnp.zeros((2,))})
    w.close()
    with pytest.raises(RuntimeError, match="after close"):
        w.save(2, {"x": jnp.ones((2,))})
    w.close()  # idempotent
