"""Serving engine: padding neutrality (the bucketing correctness
claim), deterministic micro-batcher behavior under a fake clock,
executor-cache accounting, and end-to-end request/result integrity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bnn import (
    bnn_apply_fused,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
)
from repro.serve import (
    MicroBatcher,
    ServingEngine,
    bucket_for,
    normalize_buckets,
    pad_to_bucket,
)
from repro.serve.executor import ExecutorCache, blocks_key

KEY = jax.random.PRNGKey(99)


class FakeClock:
    """Deterministic clock for queue tests: advances only on demand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def fused_params():
    return pack_bnn_params_fused(init_bnn_params(KEY))


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.fold_in(KEY, 1), (8, 32, 32, 3))


# ---------------------------------------------------------------------------
# Bucket helpers
# ---------------------------------------------------------------------------

def test_bucket_ladder_helpers():
    assert normalize_buckets([32, 1, 8, 8]) == (1, 8, 32)
    assert bucket_for(1, (1, 8, 32)) == 1
    assert bucket_for(2, (1, 8, 32)) == 8
    assert bucket_for(32, (1, 8, 32)) == 32
    with pytest.raises(ValueError):
        bucket_for(33, (1, 8, 32))
    with pytest.raises(ValueError):
        normalize_buckets([])


def test_pad_to_bucket_appends_zero_rows():
    x = np.ones((3, 2, 2, 1), np.float32)
    p = pad_to_bucket(x, 8)
    assert p.shape == (8, 2, 2, 1)
    np.testing.assert_array_equal(p[:3], x)
    assert not p[3:].any()
    assert pad_to_bucket(x, 3) is x  # exact fit: no copy
    with pytest.raises(ValueError):
        pad_to_bucket(x, 2)


# ---------------------------------------------------------------------------
# Padding neutrality — the core correctness claim of shape bucketing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["xla", "xnor"])
@pytest.mark.parametrize("conv_impl", ["im2col", "direct"])
def test_padding_neutral_logits(fused_params, images, engine, conv_impl):
    """For EVERY engine x conv_impl pair: a request padded up to a
    larger bucket yields bit-identical logits on the real rows vs
    exact-shape execution. (The forward is per-sample independent, so
    the zero padding rows cannot perturb the real rows.)"""
    # interpret-mode Pallas is python-speed: keep the xnor pairs tiny
    n, bucket = (1, 2) if engine == "xnor" else (3, 8)
    imgs = np.asarray(images[:n])
    exact = np.asarray(
        bnn_apply_fused(fused_params, jnp.asarray(imgs), engine=engine,
                        conv_impl=conv_impl)
    )
    padded_out = np.asarray(
        bnn_apply_fused(
            fused_params, jnp.asarray(pad_to_bucket(imgs, bucket)),
            engine=engine, conv_impl=conv_impl,
        )
    )
    np.testing.assert_array_equal(padded_out[:n], exact)


def test_padding_rows_do_not_depend_on_real_rows(fused_params, images):
    """Dual check: the real rows' logits are identical no matter WHAT
    shares the batch with them (zeros or other live images)."""
    a = np.asarray(images[:2])
    batch_zeros = pad_to_bucket(a, 4)
    batch_other = np.concatenate([a, np.asarray(images[2:4])], axis=0)
    za = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(batch_zeros)))
    zb = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(batch_other)))
    np.testing.assert_array_equal(za[:2], zb[:2])


# ---------------------------------------------------------------------------
# Micro-batcher under a fake clock
# ---------------------------------------------------------------------------

def _rows(batches):
    """Flatten emitted batches into (rid, request_row) pairs, in order."""
    out = []
    for b in batches:
        for s in b.segments:
            out.extend((s.rid, s.offset + i) for i in range(s.length))
    return out


def test_max_wait_flush_with_fake_clock():
    clk = FakeClock()
    mb = MicroBatcher((1, 4, 8), max_wait_s=0.5, clock=clk)
    mb.submit(np.zeros((2, 1, 1, 1)))
    assert mb.poll() == []                      # young: no flush
    clk.advance(0.49)
    assert mb.poll() == []                      # still inside max_wait
    clk.advance(0.02)
    (batch,) = mb.poll()
    assert batch.reason == "max_wait"
    assert batch.bucket == 4 and batch.rows == 2
    assert mb.pending_rows == 0


def test_full_bucket_flushes_immediately():
    clk = FakeClock()
    mb = MicroBatcher((1, 4), max_wait_s=10.0, clock=clk)
    mb.submit(np.zeros((3, 1, 1, 1)))
    mb.submit(np.zeros((3, 1, 1, 1)))
    (batch,) = mb.poll()                        # 6 rows >= max bucket 4
    assert batch.reason == "full"
    assert batch.bucket == 4 and batch.rows == 4
    assert mb.pending_rows == 2                 # split remainder queued

    clk.advance(11.0)
    (tail,) = mb.poll()
    assert tail.reason == "max_wait" and tail.rows == 2


def test_partial_batch_flush_on_drain():
    clk = FakeClock()
    mb = MicroBatcher((1, 4, 8), max_wait_s=10.0, clock=clk)
    mb.submit(np.zeros((1, 1, 1, 1)))
    mb.submit(np.zeros((2, 1, 1, 1)))
    assert mb.poll() == []                      # young + not full
    (batch,) = mb.drain()
    assert batch.reason == "drain"
    assert batch.bucket == 4 and batch.rows == 3
    assert mb.pending_rows == 0 and mb.drain() == []


def test_fifo_order_and_request_splitting():
    clk = FakeClock()
    mb = MicroBatcher((2, 4), max_wait_s=0.0, clock=clk)
    r0 = mb.submit(np.zeros((3, 1, 1, 1)))
    r1 = mb.submit(np.zeros((3, 1, 1, 1)))
    batches = mb.poll() + mb.drain()
    rows = _rows(batches)
    # every row exactly once, FIFO across and within requests
    assert rows == [(r0, 0), (r0, 1), (r0, 2), (r1, 0), (r1, 1), (r1, 2)]
    # r0 was split across the first full batch and the next one
    assert batches[0].rows == 4 and {s.rid for s in batches[0].segments} == {r0, r1}


def test_submit_rejects_mismatched_row_shape():
    """A bad request must bounce at submit(), not poison the batch its
    rows would have been coalesced into."""
    mb = MicroBatcher((4,), max_wait_s=0.0, clock=FakeClock())
    mb.submit(np.zeros((2, 32, 32, 3), np.float32))
    with pytest.raises(ValueError, match="row shape"):
        mb.submit(np.zeros((1, 28, 28, 3), np.float32))
    with pytest.raises(ValueError):
        mb.submit(np.zeros((0, 32, 32, 3), np.float32))
    (batch,) = mb.drain()                       # queue still healthy
    assert batch.rows == 2


def test_batch_assemble_pads_and_orders():
    clk = FakeClock()
    mb = MicroBatcher((4,), max_wait_s=0.0, clock=clk)
    a = np.arange(2 * 4, dtype=np.float32).reshape(2, 2, 2, 1)
    b = 100 + np.arange(4, dtype=np.float32).reshape(1, 2, 2, 1)
    mb.submit(a)
    mb.submit(b)
    (batch,) = mb.drain()
    x = batch.assemble(mb.requests)
    assert x.shape == (4, 2, 2, 1)
    np.testing.assert_array_equal(x[:2], a)
    np.testing.assert_array_equal(x[2:3], b)
    assert not x[3:].any()                      # zero padding rows


# ---------------------------------------------------------------------------
# Executor cache accounting
# ---------------------------------------------------------------------------

def test_executor_cache_hit_miss_and_compile_counts(fused_params):
    cache = ExecutorCache(fused_params, engine="xla")
    warmed = cache.warmup((1, 4))
    assert warmed == 2
    assert cache.stats.executor_compiles == 2
    assert cache.stats.executor_misses == 2
    # steady state: only hits, no new compiles
    for _ in range(3):
        cache.get(1)
        cache.get(4)
    assert cache.stats.executor_compiles == 2
    assert cache.stats.executor_hits >= 6
    assert cache.size == 2
    # a novel bucket is a miss + one compile
    cache.get(8)
    assert cache.stats.executor_compiles == 3
    assert cache.stats.executor_keys == [
        "1|xla|im2col|auto", "4|xla|im2col|auto", "8|xla|im2col|auto"
    ]


def test_blocks_key_distinguishes_configs():
    from repro.kernels.autotune import BlockConfig

    assert blocks_key("auto") == "auto"
    k1 = blocks_key(BlockConfig(128, 256, 16, 8))
    k2 = blocks_key(BlockConfig(128, 256, 32, 8))
    assert k1 != k2 and "bm128" in k1


def test_serving_tuning_cache_roundtrip(fused_params, tmp_path, monkeypatch):
    """tune_serving_blocks persists its winner in the autotune cache;
    load_serving_blocks serves it back (and falls back to AUTO for
    unknown configurations)."""
    from repro.kernels.autotune import BlockConfig
    from repro.serve import load_serving_blocks, tune_serving_blocks

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    cfg = BlockConfig(block_m=64, block_n=128, block_kw=4, word_group=4)
    timings: dict = {}
    best = tune_serving_blocks(
        fused_params, 1, engine="xla", candidates=[cfg], repeats=1,
        timings=timings,
    )
    assert best == cfg and timings[cfg] > 0
    assert load_serving_blocks("xla", "im2col", 1) == cfg
    # unknown bucket / engine: no entry -> AUTO fallback
    assert load_serving_blocks("xla", "im2col", 64) == "auto"
    assert load_serving_blocks("xnor", "im2col", 1) == "auto"


# ---------------------------------------------------------------------------
# End-to-end engine
# ---------------------------------------------------------------------------

def test_engine_serves_ragged_requests_bit_identical(fused_params, images):
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4, 8),
                        max_wait_s=0.5, clock=clk)
    eng.warmup()
    imgs = np.asarray(images)
    requests = {eng.submit(imgs[:3]): imgs[:3]}
    eng.step()
    requests[eng.submit(imgs[3:4])] = imgs[3:4]
    clk.advance(1.0)                            # age out -> max_wait flush
    eng.step()
    requests[eng.submit(imgs[4:8])] = imgs[4:8]
    eng.drain()

    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x)))
        assert got is not None
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["requests"]["completed"] == 3
    assert snap["requests"]["images_completed"] == 8
    assert snap["batches"]["real_rows"] == 8
    # warmup compiled the whole ladder; traffic added no compiles
    assert snap["executors"]["compiles"] == 3


def test_engine_reassembles_request_larger_than_max_bucket(fused_params,
                                                           images):
    """A request exceeding the largest bucket is split across batches
    and its logits reassembled in request-row order."""
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4),
                        max_wait_s=10.0, clock=clk)
    eng.warmup()
    imgs = np.asarray(images[:6])               # 6 > max bucket 4
    rid = eng.submit(imgs)
    eng.step()                                  # full 4-row batch
    assert eng.take(rid) is None                # tail still pending
    eng.drain()
    got = eng.take(rid)
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, want)


def test_engine_rejects_non_image_rows(fused_params):
    """The engine validates the model's fixed image shape at submit —
    even for the FIRST request (the queue's generic consistency check
    alone would pin itself to whatever arrives first)."""
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=10.0, clock=FakeClock())
    with pytest.raises(ValueError, match="32, 32, 3"):
        eng.submit(np.zeros((2, 16, 16, 3), np.float32))
    rid = eng.submit(np.zeros((1, 32, 32, 3), np.float32))  # still healthy
    eng.drain()
    assert eng.take(rid) is not None


def test_engine_latency_measured_on_injected_clock(fused_params):
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=10.0, clock=clk)
    eng.warmup()
    eng.submit(np.zeros((2, 32, 32, 3), np.float32))
    clk.advance(3.0)
    eng.drain()
    snap = eng.snapshot()
    assert snap["latency_s"]["count"] == 1
    assert snap["latency_s"]["p50"] == pytest.approx(3.0)


def test_serve_fn_matches_apply_fused(fused_params, images):
    fn = bnn_serve_fn(engine="xla")
    got = np.asarray(fn(fused_params, images[:2]))
    want = np.asarray(bnn_apply_fused(fused_params, images[:2]))
    np.testing.assert_array_equal(got, want)


def test_serve_fn_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown serving engine"):
        bnn_serve_fn(engine="warp-drive")


# ---------------------------------------------------------------------------
# Megakernel engine (ISSUE 5): the bucket ladder dispatches
# one-launch-per-stage executors
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mega_params():
    from repro.core.bnn import pack_bnn_params_megakernel

    return pack_bnn_params_megakernel(init_bnn_params(KEY))


@pytest.mark.parametrize("engine", ["megakernel_xla", "megakernel"])
def test_padding_neutral_logits_megakernel(mega_params, fused_params,
                                           images, engine):
    """Bucket padding stays bit-neutral under the megakernel engines,
    and the padded logits still equal the FUSED chain's (the serving
    cache may mix engines across deployments without drift)."""
    from repro.core.bnn import bnn_apply_megakernel

    n, bucket = (1, 2) if engine == "megakernel" else (3, 8)
    imgs = np.asarray(images[:n])
    inner = "xnor" if engine == "megakernel" else "xla"
    exact = np.asarray(
        bnn_apply_megakernel(mega_params, jnp.asarray(imgs), engine=inner)
    )
    padded_out = np.asarray(
        bnn_apply_megakernel(
            mega_params, jnp.asarray(pad_to_bucket(imgs, bucket)),
            engine=inner,
        )
    )
    np.testing.assert_array_equal(padded_out[:n], exact)
    want = np.asarray(
        bnn_apply_fused(fused_params, jnp.asarray(imgs), engine="xla")
    )
    np.testing.assert_array_equal(exact, want)


def test_engine_serves_megakernel_requests_bit_identical(mega_params,
                                                         images):
    """End-to-end ServingEngine on engine="megakernel_xla": ragged
    requests through the bucket ladder come back bit-identical to
    exact-shape megakernel execution, steady state compiles == buckets."""
    from repro.core.bnn import bnn_apply_megakernel

    clk = FakeClock()
    eng = ServingEngine(mega_params, engine="megakernel_xla",
                        buckets=(1, 4), max_wait_s=0.0, clock=clk)
    warmed = eng.warmup()
    imgs = np.asarray(images)
    requests = {}
    for sl in (slice(0, 3), slice(3, 4), slice(4, 8)):
        requests[eng.submit(imgs[sl])] = imgs[sl]
        eng.step()
    eng.drain()
    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(
            bnn_apply_megakernel(mega_params, jnp.asarray(x), engine="xla")
        )
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["executors"]["compiles"] == warmed == 2
