"""Serving engine: padding neutrality (the bucketing correctness
claim), deterministic micro-batcher behavior under a fake clock,
executor-cache accounting, and end-to-end request/result integrity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bnn import (
    bnn_apply_fused,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
)
from repro.serve import (
    ContinuousBatcher,
    ContinuousServingEngine,
    MicroBatcher,
    QueueFull,
    ServingEngine,
    bucket_for,
    default_extents,
    extent_for,
    normalize_buckets,
    pad_to_bucket,
)
from repro.serve.executor import ExecutorCache, blocks_key

KEY = jax.random.PRNGKey(99)


class FakeClock:
    """Deterministic clock for queue tests: advances only on demand."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def fused_params():
    return pack_bnn_params_fused(init_bnn_params(KEY))


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.fold_in(KEY, 1), (8, 32, 32, 3))


# ---------------------------------------------------------------------------
# Bucket helpers
# ---------------------------------------------------------------------------

def test_bucket_ladder_helpers():
    assert normalize_buckets([32, 1, 8, 8]) == (1, 8, 32)
    assert bucket_for(1, (1, 8, 32)) == 1
    assert bucket_for(2, (1, 8, 32)) == 8
    assert bucket_for(32, (1, 8, 32)) == 32
    with pytest.raises(ValueError):
        bucket_for(33, (1, 8, 32))
    with pytest.raises(ValueError):
        normalize_buckets([])


def test_pad_to_bucket_appends_zero_rows():
    x = np.ones((3, 2, 2, 1), np.float32)
    p = pad_to_bucket(x, 8)
    assert p.shape == (8, 2, 2, 1)
    np.testing.assert_array_equal(p[:3], x)
    assert not p[3:].any()
    assert pad_to_bucket(x, 3) is x  # exact fit: no copy
    with pytest.raises(ValueError):
        pad_to_bucket(x, 2)


# ---------------------------------------------------------------------------
# Padding neutrality — the core correctness claim of shape bucketing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["xla", "xnor"])
@pytest.mark.parametrize("conv_impl", ["im2col", "direct"])
def test_padding_neutral_logits(fused_params, images, engine, conv_impl):
    """For EVERY engine x conv_impl pair: a request padded up to a
    larger bucket yields bit-identical logits on the real rows vs
    exact-shape execution. (The forward is per-sample independent, so
    the zero padding rows cannot perturb the real rows.)"""
    # interpret-mode Pallas is python-speed: keep the xnor pairs tiny
    n, bucket = (1, 2) if engine == "xnor" else (3, 8)
    imgs = np.asarray(images[:n])
    exact = np.asarray(
        bnn_apply_fused(fused_params, jnp.asarray(imgs), engine=engine,
                        conv_impl=conv_impl)
    )
    padded_out = np.asarray(
        bnn_apply_fused(
            fused_params, jnp.asarray(pad_to_bucket(imgs, bucket)),
            engine=engine, conv_impl=conv_impl,
        )
    )
    np.testing.assert_array_equal(padded_out[:n], exact)


def test_padding_rows_do_not_depend_on_real_rows(fused_params, images):
    """Dual check: the real rows' logits are identical no matter WHAT
    shares the batch with them (zeros or other live images)."""
    a = np.asarray(images[:2])
    batch_zeros = pad_to_bucket(a, 4)
    batch_other = np.concatenate([a, np.asarray(images[2:4])], axis=0)
    za = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(batch_zeros)))
    zb = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(batch_other)))
    np.testing.assert_array_equal(za[:2], zb[:2])


# ---------------------------------------------------------------------------
# Micro-batcher under a fake clock
# ---------------------------------------------------------------------------

def _rows(batches):
    """Flatten emitted batches into (rid, request_row) pairs, in order."""
    out = []
    for b in batches:
        for s in b.segments:
            out.extend((s.rid, s.offset + i) for i in range(s.length))
    return out


def test_max_wait_flush_with_fake_clock():
    clk = FakeClock()
    mb = MicroBatcher((1, 4, 8), max_wait_s=0.5, clock=clk)
    mb.submit(np.zeros((2, 1, 1, 1)))
    assert mb.poll() == []                      # young: no flush
    clk.advance(0.49)
    assert mb.poll() == []                      # still inside max_wait
    clk.advance(0.02)
    (batch,) = mb.poll()
    assert batch.reason == "max_wait"
    assert batch.bucket == 4 and batch.rows == 2
    assert mb.pending_rows == 0


def test_full_bucket_flushes_immediately():
    clk = FakeClock()
    mb = MicroBatcher((1, 4), max_wait_s=10.0, clock=clk)
    mb.submit(np.zeros((3, 1, 1, 1)))
    mb.submit(np.zeros((3, 1, 1, 1)))
    (batch,) = mb.poll()                        # 6 rows >= max bucket 4
    assert batch.reason == "full"
    assert batch.bucket == 4 and batch.rows == 4
    assert mb.pending_rows == 2                 # split remainder queued

    clk.advance(11.0)
    (tail,) = mb.poll()
    assert tail.reason == "max_wait" and tail.rows == 2


def test_partial_batch_flush_on_drain():
    clk = FakeClock()
    mb = MicroBatcher((1, 4, 8), max_wait_s=10.0, clock=clk)
    mb.submit(np.zeros((1, 1, 1, 1)))
    mb.submit(np.zeros((2, 1, 1, 1)))
    assert mb.poll() == []                      # young + not full
    (batch,) = mb.drain()
    assert batch.reason == "drain"
    assert batch.bucket == 4 and batch.rows == 3
    assert mb.pending_rows == 0 and mb.drain() == []


def test_fifo_order_and_request_splitting():
    clk = FakeClock()
    mb = MicroBatcher((2, 4), max_wait_s=0.0, clock=clk)
    r0 = mb.submit(np.zeros((3, 1, 1, 1)))
    r1 = mb.submit(np.zeros((3, 1, 1, 1)))
    batches = mb.poll() + mb.drain()
    rows = _rows(batches)
    # every row exactly once, FIFO across and within requests
    assert rows == [(r0, 0), (r0, 1), (r0, 2), (r1, 0), (r1, 1), (r1, 2)]
    # r0 was split across the first full batch and the next one
    assert batches[0].rows == 4 and {s.rid for s in batches[0].segments} == {r0, r1}


def test_submit_rejects_mismatched_row_shape():
    """A bad request must bounce at submit(), not poison the batch its
    rows would have been coalesced into."""
    mb = MicroBatcher((4,), max_wait_s=0.0, clock=FakeClock())
    mb.submit(np.zeros((2, 32, 32, 3), np.float32))
    with pytest.raises(ValueError, match="row shape"):
        mb.submit(np.zeros((1, 28, 28, 3), np.float32))
    with pytest.raises(ValueError):
        mb.submit(np.zeros((0, 32, 32, 3), np.float32))
    (batch,) = mb.drain()                       # queue still healthy
    assert batch.rows == 2


def test_batch_assemble_pads_and_orders():
    clk = FakeClock()
    mb = MicroBatcher((4,), max_wait_s=0.0, clock=clk)
    a = np.arange(2 * 4, dtype=np.float32).reshape(2, 2, 2, 1)
    b = 100 + np.arange(4, dtype=np.float32).reshape(1, 2, 2, 1)
    mb.submit(a)
    mb.submit(b)
    (batch,) = mb.drain()
    x = batch.assemble(mb.requests)
    assert x.shape == (4, 2, 2, 1)
    np.testing.assert_array_equal(x[:2], a)
    np.testing.assert_array_equal(x[2:3], b)
    assert not x[3:].any()                      # zero padding rows


# ---------------------------------------------------------------------------
# Executor cache accounting
# ---------------------------------------------------------------------------

def test_executor_cache_hit_miss_and_compile_counts(fused_params):
    cache = ExecutorCache(fused_params, engine="xla")
    warmed = cache.warmup((1, 4))
    assert warmed == 2
    assert cache.stats.executor_compiles == 2
    assert cache.stats.executor_misses == 2
    # steady state: only hits, no new compiles
    for _ in range(3):
        cache.get(1)
        cache.get(4)
    assert cache.stats.executor_compiles == 2
    assert cache.stats.executor_hits >= 6
    assert cache.size == 2
    # a novel bucket is a miss + one compile
    cache.get(8)
    assert cache.stats.executor_compiles == 3
    assert cache.stats.executor_keys == [
        "1|xla|im2col|auto", "4|xla|im2col|auto", "8|xla|im2col|auto"
    ]


def test_blocks_key_distinguishes_configs():
    from repro.kernels.autotune import BlockConfig

    assert blocks_key("auto") == "auto"
    k1 = blocks_key(BlockConfig(128, 256, 16, 8))
    k2 = blocks_key(BlockConfig(128, 256, 32, 8))
    assert k1 != k2 and "bm128" in k1


def test_serving_tuning_cache_roundtrip(fused_params, tmp_path, monkeypatch):
    """tune_serving_blocks persists its winner in the autotune cache;
    load_serving_blocks serves it back (and falls back to AUTO for
    unknown configurations)."""
    from repro.kernels.autotune import BlockConfig
    from repro.serve import load_serving_blocks, tune_serving_blocks

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    cfg = BlockConfig(block_m=64, block_n=128, block_kw=4, word_group=4)
    timings: dict = {}
    best = tune_serving_blocks(
        fused_params, 1, engine="xla", candidates=[cfg], repeats=1,
        timings=timings,
    )
    assert best == cfg and timings[cfg] > 0
    assert load_serving_blocks("xla", "im2col", 1) == cfg
    # unknown bucket / engine: no entry -> AUTO fallback
    assert load_serving_blocks("xla", "im2col", 64) == "auto"
    assert load_serving_blocks("xnor", "im2col", 1) == "auto"


# ---------------------------------------------------------------------------
# End-to-end engine
# ---------------------------------------------------------------------------

def test_engine_serves_ragged_requests_bit_identical(fused_params, images):
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4, 8),
                        max_wait_s=0.5, clock=clk)
    eng.warmup()
    imgs = np.asarray(images)
    requests = {eng.submit(imgs[:3]): imgs[:3]}
    eng.step()
    requests[eng.submit(imgs[3:4])] = imgs[3:4]
    clk.advance(1.0)                            # age out -> max_wait flush
    eng.step()
    requests[eng.submit(imgs[4:8])] = imgs[4:8]
    eng.drain()

    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x)))
        assert got is not None
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["requests"]["completed"] == 3
    assert snap["requests"]["images_completed"] == 8
    assert snap["batches"]["real_rows"] == 8
    # warmup compiled the whole ladder; traffic added no compiles
    assert snap["executors"]["compiles"] == 3


def test_engine_reassembles_request_larger_than_max_bucket(fused_params,
                                                           images):
    """A request exceeding the largest bucket is split across batches
    and its logits reassembled in request-row order."""
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4),
                        max_wait_s=10.0, clock=clk)
    eng.warmup()
    imgs = np.asarray(images[:6])               # 6 > max bucket 4
    rid = eng.submit(imgs)
    eng.step()                                  # full 4-row batch
    assert eng.take(rid) is None                # tail still pending
    eng.drain()
    got = eng.take(rid)
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs)))
    np.testing.assert_array_equal(got, want)


def test_engine_rejects_non_image_rows(fused_params):
    """The engine validates the model's fixed image shape at submit —
    even for the FIRST request (the queue's generic consistency check
    alone would pin itself to whatever arrives first)."""
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=10.0, clock=FakeClock())
    with pytest.raises(ValueError, match="32, 32, 3"):
        eng.submit(np.zeros((2, 16, 16, 3), np.float32))
    rid = eng.submit(np.zeros((1, 32, 32, 3), np.float32))  # still healthy
    eng.drain()
    assert eng.take(rid) is not None


def test_engine_latency_measured_on_injected_clock(fused_params):
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=10.0, clock=clk)
    eng.warmup()
    eng.submit(np.zeros((2, 32, 32, 3), np.float32))
    clk.advance(3.0)
    eng.drain()
    snap = eng.snapshot()
    assert snap["latency_s"]["count"] == 1
    assert snap["latency_s"]["p50"] == pytest.approx(3.0)


def test_serve_fn_matches_apply_fused(fused_params, images):
    fn = bnn_serve_fn(engine="xla")
    got = np.asarray(fn(fused_params, images[:2]))
    want = np.asarray(bnn_apply_fused(fused_params, images[:2]))
    np.testing.assert_array_equal(got, want)


def test_serve_fn_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown serving engine"):
        bnn_serve_fn(engine="warp-drive")


# ---------------------------------------------------------------------------
# Megakernel engine (ISSUE 5): the bucket ladder dispatches
# one-launch-per-stage executors
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mega_params():
    from repro.core.bnn import pack_bnn_params_megakernel

    return pack_bnn_params_megakernel(init_bnn_params(KEY))


@pytest.mark.parametrize("engine", ["megakernel_xla", "megakernel"])
def test_padding_neutral_logits_megakernel(mega_params, fused_params,
                                           images, engine):
    """Bucket padding stays bit-neutral under the megakernel engines,
    and the padded logits still equal the FUSED chain's (the serving
    cache may mix engines across deployments without drift)."""
    from repro.core.bnn import bnn_apply_megakernel

    n, bucket = (1, 2) if engine == "megakernel" else (3, 8)
    imgs = np.asarray(images[:n])
    inner = "xnor" if engine == "megakernel" else "xla"
    exact = np.asarray(
        bnn_apply_megakernel(mega_params, jnp.asarray(imgs), engine=inner)
    )
    padded_out = np.asarray(
        bnn_apply_megakernel(
            mega_params, jnp.asarray(pad_to_bucket(imgs, bucket)),
            engine=inner,
        )
    )
    np.testing.assert_array_equal(padded_out[:n], exact)
    want = np.asarray(
        bnn_apply_fused(fused_params, jnp.asarray(imgs), engine="xla")
    )
    np.testing.assert_array_equal(exact, want)


def test_engine_serves_megakernel_requests_bit_identical(mega_params,
                                                         images):
    """End-to-end ServingEngine on engine="megakernel_xla": ragged
    requests through the bucket ladder come back bit-identical to
    exact-shape megakernel execution, steady state compiles == buckets."""
    from repro.core.bnn import bnn_apply_megakernel

    clk = FakeClock()
    eng = ServingEngine(mega_params, engine="megakernel_xla",
                        buckets=(1, 4), max_wait_s=0.0, clock=clk)
    warmed = eng.warmup()
    imgs = np.asarray(images)
    requests = {}
    for sl in (slice(0, 3), slice(3, 4), slice(4, 8)):
        requests[eng.submit(imgs[sl])] = imgs[sl]
        eng.step()
    eng.drain()
    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(
            bnn_apply_megakernel(mega_params, jnp.asarray(x), engine="xla")
        )
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["executors"]["compiles"] == warmed == 2


# ---------------------------------------------------------------------------
# Cancellation (ISSUE 6 satellite): forget() retires pending cursors
# ---------------------------------------------------------------------------

def test_forget_split_request_retires_pending_cursor():
    """Regression: cancelling a request whose tail is still queued must
    retire its (rid, offset) cursor too — the pre-fix code left an
    orphan cursor whose ghost segment poisoned the next batch."""
    clk = FakeClock()
    mb = MicroBatcher((2,), max_wait_s=10.0, clock=clk)
    r0 = mb.submit(np.zeros((3, 1, 1, 1), np.float32))
    (head,) = mb.poll()                  # full 2-row slice of r0 leaves
    assert [s.rid for s in head.segments] == [r0]
    assert mb.pending_rows == 1          # r0's tail at the queue head
    assert mb.forget(r0) is not None
    assert mb.pending_rows == 0          # cursor retired with the request
    r1 = mb.submit(np.ones((2, 1, 1, 1), np.float32))
    (nxt,) = mb.poll()
    assert [s.rid for s in nxt.segments] == [r1]   # no ghost segment
    np.testing.assert_array_equal(
        nxt.assemble(mb.requests), np.ones((2, 1, 1, 1), np.float32)
    )


def test_batch_assemble_zeroes_cancelled_batchmate_rows():
    """A request cancelled between batching and assembly contributes
    zero rows in place: batchmates' batch_row offsets stay honest."""
    clk = FakeClock()
    mb = MicroBatcher((4,), max_wait_s=0.0, clock=clk)
    a = np.ones((2, 2, 2, 1), np.float32)
    b = 2 * np.ones((1, 2, 2, 1), np.float32)
    ra = mb.submit(a)
    rb = mb.submit(b)
    (batch,) = mb.drain()
    mb.forget(ra)
    x = batch.assemble(mb.requests)
    assert not x[:2].any()               # ghost rows zeroed in place
    np.testing.assert_array_equal(x[2:3], b)
    mb.forget(rb)
    with pytest.raises(ValueError, match="cancelled"):
        batch.assemble(mb.requests)      # nothing left to assemble


def test_engine_cancel_after_split_keeps_batchmates_intact(fused_params,
                                                           images):
    """Cancel a split request between the full flush and the tail flush:
    the tail's cursor disappears and later requests serve normally."""
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 4),
                        max_wait_s=10.0, clock=clk)
    eng.warmup()
    imgs = np.asarray(images)
    big = eng.submit(imgs[:6])           # splits: 4 dispatched, 2 queued
    eng.step()
    assert eng.cancel(big)
    small = eng.submit(imgs[6:8])
    done = eng.drain()
    assert small in done and big not in done
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs[6:8])))
    np.testing.assert_array_equal(eng.take(small), want)
    assert eng.take(big) is None


def test_engine_cancel_between_poll_and_run_drops_only_that_request(
        fused_params, images):
    """Rows of a cancelled request already inside an assembled batch
    compute as zero ghosts and are dropped at scatter; batchmates'
    logits stay bit-identical to their exact-shape forward."""
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=0.0, clock=clk)
    eng.warmup()
    imgs = np.asarray(images)
    ra = eng.submit(imgs[:2])
    rb = eng.submit(imgs[2:3])
    batches = eng.batcher.poll()         # batched but not yet run
    assert eng.cancel(ra)
    done = eng._run(batches)
    assert done == [rb]
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs[2:3])))
    np.testing.assert_array_equal(eng.take(rb), want)
    assert eng.take(ra) is None


def test_engine_skips_batch_when_every_request_cancelled(fused_params):
    clk = FakeClock()
    eng = ServingEngine(fused_params, engine="xla", buckets=(4,),
                        max_wait_s=0.0, clock=clk)
    eng.warmup()
    rid = eng.submit(np.zeros((2, 32, 32, 3), np.float32))
    batches = eng.batcher.poll()
    assert eng.cancel(rid)
    assert eng._run(batches) == []       # skipped entirely, no dispatch
    assert eng.snapshot()["batches"]["dispatched"] == 0


# ---------------------------------------------------------------------------
# Continuous scheduler (ISSUE 6): ragged coalescing over extent classes
# ---------------------------------------------------------------------------

def test_extent_class_helpers():
    assert [extent_for(n) for n in (1, 2, 3, 5, 8, 9, 16, 17, 25, 32)] == \
        [1, 2, 4, 8, 8, 16, 16, 24, 32, 32]
    assert default_extents(32) == (1, 2, 4, 8, 16, 24, 32)
    assert default_extents(8) == (1, 2, 4, 8)
    assert default_extents(1) == (1,)
    for e in default_extents(32):
        assert extent_for(e) == e        # classes closed under re-dispatch
    with pytest.raises(ValueError):
        extent_for(0)
    with pytest.raises(ValueError):
        default_extents(0)


def test_continuous_batcher_full_and_ragged_flush():
    clk = FakeClock()
    cb = ContinuousBatcher(max_rows=8, max_wait_s=0.5, clock=clk)
    cb.submit(np.zeros((5, 1, 1, 1), np.float32))
    assert cb.poll() == []               # young, below budget: coalesce
    cb.submit(np.zeros((6, 1, 1, 1), np.float32))
    (full,) = cb.poll()                  # 11 pending rows >= budget 8
    assert full.reason == "full" and full.rows == full.bucket == 8
    assert cb.pending_rows == 3
    clk.advance(1.0)
    (ragged,) = cb.poll()                # aged out: EXACT rows, no rung
    assert ragged.reason == "max_wait"
    assert ragged.rows == ragged.bucket == 3


def test_continuous_admission_control():
    clk = FakeClock()
    cb = ContinuousBatcher(max_rows=4, max_queue_rows=6, clock=clk)
    cb.submit(np.zeros((4, 1, 1, 1), np.float32))
    cb.submit(np.zeros((2, 1, 1, 1), np.float32))
    with pytest.raises(QueueFull):
        cb.submit(np.zeros((1, 1, 1, 1), np.float32))
    cb.poll()                            # a dispatch frees queue budget
    cb.submit(np.zeros((1, 1, 1, 1), np.float32))
    with pytest.raises(ValueError, match="max_queue_rows"):
        ContinuousBatcher(max_rows=8, max_queue_rows=4)


def test_continuous_service_ewma():
    cb = ContinuousBatcher(max_rows=8, clock=FakeClock())
    assert cb.est_service_s(8) == 0.0    # optimistic before any data
    cb.note_service(8, 0.8)              # 0.1 s/row
    cb.note_service(8, 1.6)              # 0.2 s/row folds in at 0.3
    assert cb.est_service_s(1) == pytest.approx(0.7 * 0.1 + 0.3 * 0.2)
    cb.note_service(0, 1.0)              # degenerate observations ignored
    cb.note_service(8, 0.0)
    assert cb.est_service_s(1) == pytest.approx(0.13)


def test_continuous_slo_aware_wait_shrinks_with_load():
    clk = FakeClock()
    cb = ContinuousBatcher(max_rows=32, max_wait_s=1.0, slo_s=2.0,
                           slo_headroom=0.5, clock=clk)
    assert cb.current_wait() == 1.0      # no service data: static bound
    cb.note_service(8, 0.8)              # 0.1 s/row observed
    cb.submit(np.zeros((4, 1, 1, 1), np.float32))
    # budget 2.0*0.5 minus est service of 4 pending rows = 0.6s
    assert cb.current_wait() == pytest.approx(0.6)
    cb.submit(np.zeros((8, 1, 1, 1), np.float32))
    # 12 pending rows: est service 1.2s exceeds the budget -> no wait
    assert cb.current_wait() == 0.0
    (b,) = cb.poll()
    assert b.reason == "max_wait" and b.rows == 12


@pytest.mark.parametrize("engine", ["xla", "xnor"])
@pytest.mark.parametrize("conv_impl", ["im2col", "direct"])
def test_continuous_engine_bit_identical(fused_params, images, engine,
                                         conv_impl):
    """The v2 engine's contract (DESIGN.md §9): every request's logits
    are bit-identical to its exact-shape forward, for every engine x
    conv_impl pair — extent padding is as neutral as rung padding."""
    clk = FakeClock()
    if engine == "xnor":                 # interpret Pallas is python-speed
        max_rows, slices = 2, (slice(0, 1), slice(1, 3))
    else:
        max_rows, slices = 4, (slice(0, 3), slice(3, 4), slice(4, 8))
    eng = ContinuousServingEngine(fused_params, engine=engine,
                                  conv_impl=conv_impl, max_rows=max_rows,
                                  max_wait_s=0.0, clock=clk)
    imgs = np.asarray(images)
    requests = {}
    for sl in slices:
        requests[eng.submit(imgs[sl])] = imgs[sl]
        eng.step()
    eng.drain()
    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(
            bnn_apply_fused(fused_params, jnp.asarray(x), engine=engine,
                            conv_impl=conv_impl)
        )
        assert got is not None
        np.testing.assert_array_equal(got, want)


def test_continuous_engine_extent_accounting(fused_params):
    clk = FakeClock()
    eng = ContinuousServingEngine(fused_params, engine="xla", max_rows=8,
                                  max_wait_s=0.0, slo_s=10.0, clock=clk)
    assert eng.extents == (1, 2, 4, 8)
    assert eng.warmup() == 4
    rid = eng.submit(np.zeros((7, 32, 32, 3), np.float32))
    eng.step()                           # 7 real rows -> extent 8
    assert eng.take(rid) is not None
    snap = eng.snapshot()
    assert snap["scheduler"] == "continuous"
    assert snap["batches"]["real_rows"] == 7
    assert snap["batches"]["dispatched_rows"] == 8   # 1 tile-pad row
    assert snap["batches"]["pad_row_fraction"] == pytest.approx(1 / 8)
    assert snap["batches"]["per_bucket"] == {8: 1}   # keyed on extent
    assert snap["executors"]["compiles"] == 4        # none past warmup
    assert snap["slo"]["slo_s"] == 10.0
    assert snap["slo"]["images_within_slo"] == 7


def test_continuous_engine_counts_rejections(fused_params):
    eng = ContinuousServingEngine(fused_params, engine="xla", max_rows=4,
                                  max_queue_rows=4, max_wait_s=10.0,
                                  clock=FakeClock())
    eng.submit(np.zeros((3, 32, 32, 3), np.float32))
    with pytest.raises(QueueFull):
        eng.submit(np.zeros((2, 32, 32, 3), np.float32))
    snap = eng.snapshot()
    assert snap["requests"]["rejected"] == 1
    assert snap["requests"]["images_rejected"] == 2
    assert snap["requests"]["submitted"] == 1        # never entered queue


def test_continuous_engine_cancel_split_request(fused_params, images):
    clk = FakeClock()
    eng = ContinuousServingEngine(fused_params, engine="xla", max_rows=4,
                                  max_wait_s=10.0, clock=clk)
    imgs = np.asarray(images)
    big = eng.submit(imgs[:6])           # 6 > budget 4: splits
    eng.step()                           # full 4-row dispatch; 2 queued
    assert eng.cancel(big)
    small = eng.submit(imgs[6:8])
    done = eng.drain()
    assert done == [small]
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(imgs[6:8])))
    np.testing.assert_array_equal(eng.take(small), want)
    assert eng.take(big) is None
