"""Session-scoped multi-device simulation for the whole test run.

The mesh-sharded serving tests (tests/test_sharded_serve.py, the
device-count legs of the property/golden suites) need more than one
jax device, and jax locks the device count at first backend
initialization — so the flag must be injected BEFORE any test module
imports jax. A root conftest is the one file pytest guarantees to
import first; setting the env var at module scope here is therefore
the "session-scoped fixture" that every test shares.

Forcing 8 host devices is bit-neutral for every single-device test:
computations without an explicit sharding run on device 0 exactly as
before (the golden-logits fixture passing unchanged under this
conftest is the proof, and is itself asserted — tests/test_golden.py).
A count already present in XLA_FLAGS (e.g. a CI leg exporting its own)
wins over the default here.
"""

import os

FORCED_HOST_DEVICES = 8

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count="
        f"{FORCED_HOST_DEVICES}"
    ).strip()
