"""Mesh-sharded SPMD serving (DESIGN.md §10): the bit-identity matrix
extended to the device-count axis, plus the mesh-divisibility ladder
rules and the sharded steady-state compile invariant.

The tentpole claim: ``bnn_serve_fn(mesh=...)`` — packed weights
REPLICATED on every device of a 1-D ``("data",)`` mesh, batch sharded
— produces logits bit-identical to single-device dispatch, for every
serving engine x conv lowering x device count in {1, 2, 8}. No
tolerance: per-sample independence means each device runs exactly the
per-shard program the single-device path runs, so there is nothing to
be approximately equal about.

Needs >= 8 devices; tests/conftest.py forces 8 simulated host devices
for the whole session (the multi-device CI leg exports the same flag
explicitly).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bnn import (
    bnn_apply_fused,
    bnn_serve_fn,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.launch.mesh import make_serving_mesh
from repro.serve import (
    ContinuousServingEngine,
    ExecutorCache,
    RaggedExecutorCache,
    ServingEngine,
    default_extents,
    extent_for,
    mesh_buckets,
)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 (forced host) devices — conftest.py sets XLA_FLAGS "
           "before any jax import; a pre-initialized backend wins",
)

BATCH = 8  # divides every mesh size under test (1, 2, 8)


@pytest.fixture(scope="module")
def params():
    return init_bnn_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def fused_params(params):
    return pack_bnn_params_fused(params)


@pytest.fixture(scope="module")
def mega_params(params):
    return pack_bnn_params_megakernel(params)


@pytest.fixture(scope="module")
def images():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.normal(size=(BATCH, 32, 32, 3)).astype(np.float32))


def _params_for(engine, fused_params, mega_params):
    return mega_params if engine.startswith("megakernel") else fused_params


# Compiled serve fns shared across the parametrized matrix — one jit per
# (engine, conv_impl, devices) cell, references included as devices=0.
_FNS: dict = {}


def _serve(engine, conv_impl, devices):
    key = (engine, conv_impl, devices)
    if key not in _FNS:
        mesh = make_serving_mesh(devices) if devices else None
        _FNS[key] = bnn_serve_fn(engine=engine, conv_impl=conv_impl,
                                 mesh=mesh)
    return _FNS[key]


# The serving matrix: conv_impl varies on the per-layer fused chain
# engines only (megakernel conv stages are direct-path by construction).
MATRIX = [
    ("xla", "im2col"),
    ("xla", "direct"),
    ("xnor", "im2col"),
    ("xnor", "direct"),
    ("megakernel", "im2col"),
    ("megakernel_xla", "im2col"),
]


@pytest.mark.parametrize("devices", [1, 2, 8])
@pytest.mark.parametrize("engine,conv_impl", MATRIX)
def test_sharded_logits_bit_identical(engine, conv_impl, devices,
                                      fused_params, mega_params, images):
    """THE acceptance matrix: sharded == single-device, bit for bit,
    for every engine x conv_impl x device count."""
    packed = _params_for(engine, fused_params, mega_params)
    want = np.asarray(_serve(engine, conv_impl, 0)(packed, images))
    got = np.asarray(_serve(engine, conv_impl, devices)(packed, images))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def test_make_serving_mesh_shapes():
    for n in (1, 2, 8):
        mesh = make_serving_mesh(n)
        assert mesh.shape == {"data": n}
    # default: every device
    assert make_serving_mesh().shape == {"data": jax.device_count()}


def test_make_serving_mesh_rejects_bad_counts():
    with pytest.raises(ValueError):
        make_serving_mesh(0)
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_serving_mesh(jax.device_count() + 1)


# ---------------------------------------------------------------------------
# mesh-divisible ladders
# ---------------------------------------------------------------------------


def test_extent_for_mesh_multiples():
    # per-device ladder scaled by the device count: every class divides
    # the mesh, full-tile classes land on tile x devices multiples
    assert [extent_for(n, devices=8) for n in (1, 3, 8, 9, 16, 17, 64, 65)] \
        == [8, 8, 8, 16, 16, 32, 64, 128]
    assert [extent_for(n, devices=2) for n in (1, 2, 3, 5, 15, 16, 17)] \
        == [1 * 2, 1 * 2, 2 * 2, 4 * 2, 16, 16, 32]
    # devices=1 is exactly the single-device ladder
    assert [extent_for(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_extent_classes_closed_under_redispatch(devices):
    for n in range(1, 100):
        e = extent_for(n, devices=devices)
        assert e % devices == 0
        assert e >= n
        assert extent_for(e, devices=devices) == e  # closure
        if n > 1:  # monotone
            assert e >= extent_for(n - 1, devices=devices)


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_default_extents_cover_every_class(devices):
    for max_rows in (1, 3, 8, 32, 64):
        exts = default_extents(max_rows, devices=devices)
        produced = {extent_for(n, devices=devices)
                    for n in range(1, max_rows + 1)}
        assert produced == set(exts)


def test_mesh_buckets_round_to_device_multiples():
    assert mesh_buckets((1, 8, 32, 128), 8) == (8, 32, 128)
    assert mesh_buckets((1, 8, 32, 128), 2) == (2, 8, 32, 128)
    assert mesh_buckets((1, 4, 8), 1) == (1, 4, 8)
    assert mesh_buckets((3, 5), 8) == (8,)  # collapsed rungs dedup
    with pytest.raises(ValueError):
        mesh_buckets((1, 8), 0)


# ---------------------------------------------------------------------------
# executor caches under a mesh
# ---------------------------------------------------------------------------


def test_mesh_executor_cache_keys_and_compiles(fused_params):
    """Mesh-keyed cache: key gains the device-count component, compiles
    == shapes warmed, steady-state traffic adds ZERO compiles (the
    acceptance criterion), and a same-shape single-device key never
    aliases the sharded executable."""
    mesh = make_serving_mesh(8)
    cache = ExecutorCache(fused_params, engine="xla", mesh=mesh)
    assert cache.key(8) == (8, "xla", "im2col", "auto", "mesh8")
    single = ExecutorCache(fused_params, engine="xla")
    assert single.key(8) == (8, "xla", "im2col", "auto")

    warmed = cache.warmup((8, 32))
    assert warmed == 2
    assert cache.stats.executor_compiles == 2
    rng = np.random.default_rng(0)
    for _ in range(4):  # steady-state sharded traffic: hits only
        cache.run(rng.normal(size=(8, 32, 32, 3)).astype(np.float32))
        cache.run(rng.normal(size=(32, 32, 32, 3)).astype(np.float32))
    assert cache.stats.executor_compiles == 2
    assert cache.size == 2


def test_mesh_executor_pads_non_divisible_batch(fused_params):
    """Satellite regression: a batch whose rows don't divide the mesh
    pads with bit-neutral zero rows (never crashes, never truncates)
    and hands back exactly the real rows."""
    mesh = make_serving_mesh(8)
    cache = ExecutorCache(fused_params, engine="xla", mesh=mesh)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(3, 32, 32, 3)).astype(np.float32)
    out = cache.run(x)
    assert out.shape[0] == 3
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x),
                                      engine="xla"))
    np.testing.assert_array_equal(out, want)
    # it dispatched at the padded device multiple, not the real count
    assert cache.key(8) in cache._fns and cache.key(3) not in cache._fns


def test_mesh_ragged_executor_n3_on_8_devices(fused_params):
    """The ISSUE's named edge: n_real=3 on 8 devices — extent class 8,
    5 bit-neutral pad rows, sliced back to exactly 3 rows that match
    single-device exact-shape execution bit-for-bit."""
    mesh = make_serving_mesh(8)
    cache = RaggedExecutorCache(fused_params, engine="xla", mesh=mesh)
    assert cache.devices == 8
    assert cache.extent_of(3) == 8
    rng = np.random.default_rng(2)
    x = rng.normal(size=(3, 32, 32, 3)).astype(np.float32)
    out = cache.run(x)
    assert out.shape[0] == 3
    want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x),
                                      engine="xla"))
    np.testing.assert_array_equal(out, want)
    assert cache.key(8) in cache._fns
    assert cache.key(8)[-2:] == ("ragged", "mesh8")


# ---------------------------------------------------------------------------
# serving engines over a mesh
# ---------------------------------------------------------------------------


def test_sharded_serving_engine_bit_identical_and_no_recompiles(
        fused_params):
    """The bucket engine on an 8-device mesh: ladder normalized to
    device multiples, every request's logits bit-identical to its
    exact-shape single-device forward, and steady-state compile count
    == buckets warmed."""
    mesh = make_serving_mesh(8)
    eng = ServingEngine(fused_params, engine="xla", buckets=(1, 8, 32),
                        mesh=mesh, max_wait_s=0.0)
    assert eng.batcher.buckets == (8, 32)  # 1 rounded up, deduped
    warmed = eng.warmup()
    assert warmed == 2

    rng = np.random.default_rng(3)
    requests = {}
    for n in (1, 3, 8, 5, 32, 2):
        x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        eng.step()
    eng.drain()
    for rid, x in requests.items():
        got = eng.take(rid)
        assert got is not None
        want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x),
                                          engine="xla"))
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["executors"]["compiles"] == warmed  # zero under traffic


def test_sharded_continuous_engine_bit_identical(fused_params):
    """The continuous engine on an 8-device mesh: extent ladder is
    mesh-multiple classes, coalesced ragged batches pad bit-neutrally,
    per-request logits bit-identical to exact-shape single-device."""
    mesh = make_serving_mesh(8)
    eng = ContinuousServingEngine(fused_params, engine="xla",
                                  max_rows=16, mesh=mesh,
                                  max_wait_s=0.0)
    assert eng.extents == (8, 16)
    assert all(e % 8 == 0 for e in eng.extents)
    warmed = eng.warmup()
    assert warmed == len(eng.extents)

    rng = np.random.default_rng(4)
    requests = {}
    for n in (3, 1, 7, 16, 2):
        x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        eng.step()
    eng.drain()
    for rid, x in requests.items():
        got = eng.take(rid)
        assert got is not None
        want = np.asarray(bnn_apply_fused(fused_params, jnp.asarray(x),
                                          engine="xla"))
        np.testing.assert_array_equal(got, want)
    snap = eng.snapshot()
    assert snap["executors"]["compiles"] == warmed
    # every dispatch ran at a mesh-divisible extent
    assert all(e % 8 == 0 for e in snap["batches"]["per_bucket"])
