"""Property-based tests (hypothesis) on the system's core invariants:

* pack/unpack is a bijection on ±1 tensors,
* pack_channels round-trips ragged C (tail bits pinned to +1),
* xnor-popcount GEMM == ±1 float GEMM for ANY packed shapes,
* packed BitLinear == fake-quant BitLinear on ±1-valued weights,
* EF-compression error is bounded by one quantization step,
* sharding specs always divide (the divisibility guard is total),
* the serving micro-batcher never drops/duplicates/reorders rows
  under randomized arrival patterns,
* the continuous scheduler (ISSUE 6) serves ANY ragged arrival pattern
  with per-request logits bit-identical to exact-shape execution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an *optional* test dependency (see requirements-test.txt);
# without it the deterministic suite still collects and runs — only this
# module is skipped.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitops
from repro.core.binarize import QuantMode
from repro.core.layers import (
    BitLinearConfig,
    bit_linear,
    pack_linear_params,
    stack_chain_layers,
)
from repro.distributed import compression, sharding
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=6)


@given(
    m=st.integers(1, 5), kw=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(m, kw, seed):
    rng = np.random.default_rng(seed)
    x = np.sign(rng.normal(size=(m, kw * 32))) + 0.0
    x[x == 0] = 1.0
    packed = bitops.pack_bits(jnp.asarray(x), axis=1)
    assert packed.shape == (m, kw)
    back = bitops.unpack_bits(packed, axis=1)
    np.testing.assert_array_equal(np.asarray(back), x)


@given(
    m=st.integers(1, 4), kw=st.integers(1, 3), n=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_xnor_gemm_equals_pm1_gemm(m, kw, n, seed):
    rng = np.random.default_rng(seed)
    k = kw * 32
    w = np.sign(rng.normal(size=(m, k))) + 0.0
    x = np.sign(rng.normal(size=(k, n))) + 0.0
    w[w == 0] = 1.0
    x[x == 0] = 1.0
    wp = bitops.pack_bits(jnp.asarray(w), axis=1)
    xp = bitops.pack_bits(jnp.asarray(x), axis=0)
    ref = (w @ x).astype(np.int32)
    got = bitops.xnor_popcount_matmul(wp, xp, k)
    np.testing.assert_array_equal(np.asarray(got), ref)


@given(
    din=st.integers(1, 70), dout=st.integers(1, 8), b=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_packed_linear_matches_fakequant(din, dout, b, seed):
    """For ±1-valued latent weights, PACKED == FAKE_QUANT exactly —
    including the K-padding correction for din not divisible by 32."""
    rng = np.random.default_rng(seed)
    w = np.sign(rng.normal(size=(dout, din))).astype(np.float32)
    w[w == 0] = 1.0
    x = rng.normal(size=(b, din)).astype(np.float32)
    params = {"w": jnp.asarray(w)}
    packed = pack_linear_params(params)
    fq = bit_linear(params, jnp.asarray(x),
                    BitLinearConfig(mode=QuantMode.FAKE_QUANT,
                                    binarize_acts=False))
    pk = bit_linear(packed, jnp.asarray(x),
                    BitLinearConfig(mode=QuantMode.PACKED,
                                    binarize_acts=False, engine="xla"))
    np.testing.assert_allclose(np.asarray(pk), np.asarray(fq),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# STE training boundary (ISSUE 9, DESIGN.md §12)
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 200), scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_ste_sign_gradient_is_htanh_window(n, scale, seed):
    """The straight-through estimator's backward is the clamped
    pass-through: d/dx ste_sign(x) == 1 for |x| <= 1 and == 0 strictly
    outside — the exact support AdamW's latent clip pins weights to
    (a latent outside [-1, 1] would have zero gradient forever)."""
    from repro.core.binarize import ste_sign

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
    g = jax.grad(lambda v: jnp.sum(ste_sign(v)))(x)
    want = (np.abs(np.asarray(x)) <= 1.0).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(g), want)


@given(
    n=st.integers(1, 200), scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_ste_sign_forward_matches_pack_convention(n, scale, seed):
    """Forward sign convention: ste_sign(x) == where(x >= 0, 1, -1) —
    including x == 0 -> +1 — which is the SAME predicate pack_bits /
    pack_channels use, so training, float-boundary eval, and the packed
    engines binarize identically (the hinge of the bit-identity
    contract)."""
    from repro.core.binarize import ste_sign

    rng = np.random.default_rng(seed)
    x = rng.normal(0, scale, n).astype(np.float32)
    x[rng.random(n) < 0.1] = 0.0        # force exact zeros into the draw
    got = np.asarray(ste_sign(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.where(x >= 0, 1.0, -1.0))
    # and the packed path binarizes the same values to the same bits
    pad = -n % 32
    packed = bitops.pack_bits(jnp.asarray(got if pad == 0 else
                                          np.pad(got, (0, pad),
                                                 constant_values=1.0))[None],
                              axis=1)
    rt = np.asarray(bitops.unpack_bits(packed, axis=1))[0, :n]
    np.testing.assert_array_equal(rt, got)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_trained_export_roundtrip_property(seed):
    """pack_trained_params round trip for ANY model weights: snap a
    random init to sign form (what save/load_binary_checkpoint commits),
    export, and the packed engines' logits equal the float-boundary
    eval forward EXACTLY. Runs the cheap exact engines (packed/xla +
    fused xla over both conv lowerings) — the full engine matrix
    including the interpret-Pallas xnor/megakernel legs is asserted
    deterministically on the committed checkpoint in tests/test_train.py
    (interpret Pallas inside a hypothesis loop would be minutes per
    example)."""
    from repro.core.bnn import (
        BNNConfig, bnn_apply, bnn_apply_fused, bnn_eval_logits,
        init_bnn_params, pack_trained_params,
    )

    params = init_bnn_params(jax.random.PRNGKey(seed))
    # sign-form snap — the committed-checkpoint transform
    for group in ("conv", "fc"):
        params[group] = [
            {**p, "w": jnp.where(p["w"] >= 0, 1.0, -1.0)}
            for p in params[group]
        ]
    images = jax.random.normal(jax.random.fold_in(
        jax.random.PRNGKey(seed), 1), (2, 32, 32, 3))
    out = pack_trained_params(params)      # no probe: cheap engines below
    want = np.asarray(bnn_eval_logits(params, images))
    got_packed = np.asarray(bnn_apply(
        out["packed"], images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    ))
    np.testing.assert_array_equal(got_packed, want)
    for conv_impl in ("im2col", "direct"):
        got = np.asarray(bnn_apply_fused(
            out["fused"], images, engine="xla", conv_impl=conv_impl))
        np.testing.assert_array_equal(got, want)


@given(
    n=st.integers(2, 300), scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_compression_error_bounded(n, scale, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
    deq, err = compression.compress_decompress(g, jnp.zeros_like(g))
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= step * 0.5 + 1e-6


def _rand_pm1(key, shape):
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    kw=st.integers(1, 12),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_xnor_gemm_property(m, kw, n, seed):
    """For random packed operands of any shape, the kernel equals the
    exact ±1 dot product (invariant: 2*popcount(xnor) - K)."""
    k = kw * 32
    key = jax.random.PRNGKey(seed)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    out = ops.xnor_gemm(
        bitops.pack_bits(wb, -1), bitops.pack_bits(xb, 0), k, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.binary_matmul_ref(wb, xb))
    )


@settings(max_examples=25, deadline=None)
@given(
    kw=st.integers(1, 16),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip_property(kw, n, seed):
    k = kw * 32
    x = _rand_pm1(jax.random.PRNGKey(seed), (k, n))
    packed = bitops.pack_bits(x, axis=0)
    rt = bitops.unpack_bits(packed, axis=0)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(x))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    kw=st.integers(1, 8),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_engines_agree_property(m, kw, n, seed):
    """xnor and unpack engines compute the same binary contraction."""
    k = kw * 32
    key = jax.random.PRNGKey(seed)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    wp = bitops.pack_bits(wb, -1)
    a = ops.xnor_gemm(wp, bitops.pack_bits(xb, 0), k, interpret=True)
    b = ops.unpack_gemm(wp, xb, interpret=True)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    kw=st.integers(1, 6),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_layer_matches_unfused_property(m, kw, n, seed):
    """fused epilogue (affine+sign+repack) == unfused dot->affine->pack
    for any shape, including M not divisible by 32."""
    k = kw * 32
    key = jax.random.PRNGKey(seed)
    wb = _rand_pm1(jax.random.fold_in(key, 0), (m, k))
    xb = _rand_pm1(jax.random.fold_in(key, 1), (k, n))
    a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
    wp = bitops.pack_bits(wb, -1)
    xp = bitops.pack_bits(xb, 0)
    got = bitops.fused_xnor_layer(wp, xp, k, a, b)
    dot = ref.binary_matmul_ref(wb, xb).astype(jnp.float32)
    y = a[:, None] * dot + b[:, None]
    pad = -m % 32
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)), constant_values=1.0)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(bitops.pack_bits(y, axis=0))
    )


@given(
    c=st.integers(1, 80), lead=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pack_channels_roundtrip_ragged_c(c, lead, seed):
    """pack_channels tolerates ANY channel count: the first C unpacked
    values reproduce the signs exactly and every tail bit of the last
    word is +1 (the activation-pad half of the xnor-neutral
    convention)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(lead, c)).astype(np.float32)
    packed = bitops.pack_channels(jnp.asarray(x))
    assert packed.shape == (lead, -(-c // 32))
    back = np.asarray(bitops.unpack_bits(packed, axis=-1))
    want = np.where(x >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(back[:, :c], want)
    np.testing.assert_array_equal(
        back[:, c:], np.ones_like(back[:, c:])
    )


@given(
    sizes=st.lists(st.integers(1, 11), min_size=1, max_size=12),
    buckets=st.sets(st.integers(1, 8), min_size=1, max_size=3),
    events=st.lists(st.sampled_from(["poll", "wait"]), max_size=12),
    max_wait=st.floats(0.0, 2.0),
)
@settings(max_examples=50, deadline=None)
def test_microbatcher_invariants(sizes, buckets, events, max_wait):
    """Under ANY arrival pattern and flush timing: no request row is
    dropped, none is duplicated, rows stay FIFO (within and across
    requests), every batch respects its bucket, and batches never carry
    more rows than their bucket."""
    from repro.serve import MicroBatcher

    class Clock:
        t = 0.0
        def __call__(self):
            return self.t

    clk = Clock()
    mb = MicroBatcher(sorted(buckets), max_wait_s=max_wait, clock=clk)
    batches = []
    it = iter(events + ["poll"] * len(sizes))
    for n in sizes:
        mb.submit(np.zeros((n, 1, 1, 1), np.float32))
        ev = next(it)
        if ev == "wait":
            clk.t += max_wait + 0.01
        batches.extend(mb.poll())
    batches.extend(mb.drain())
    assert mb.pending_rows == 0

    ladder = mb.buckets
    seen = []
    for b in batches:
        assert b.bucket in ladder
        assert 1 <= b.rows <= b.bucket
        filled = 0
        for s in b.segments:
            assert s.batch_row == filled  # contiguous, in order
            filled += s.length
            seen.extend((s.rid, s.offset + i) for i in range(s.length))
        assert filled == b.rows
    want = [
        (rid, row) for rid, n in enumerate(sizes) for row in range(n)
    ]
    assert seen == want  # exactly once each, global FIFO order


# ---------------------------------------------------------------------------
# Continuous scheduler (ISSUE 6): ragged batches stay bit-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_fused_params():
    from repro.core.bnn import init_bnn_params, pack_bnn_params_fused

    return pack_bnn_params_fused(init_bnn_params(jax.random.PRNGKey(7)))


# Executor caches shared across hypothesis examples: re-jitting the
# forward for every drawn arrival pattern would dominate the run, and
# the compiled executable is shape-keyed state the property does not
# vary.
_EXEC_CACHES: dict = {}


def _continuous_engine(params, engine, conv_impl, clock):
    from repro.serve import ContinuousServingEngine

    eng = ContinuousServingEngine(params, engine=engine,
                                  conv_impl=conv_impl, max_rows=8,
                                  max_wait_s=0.25, clock=clock)
    eng.executors = _EXEC_CACHES.setdefault((engine, conv_impl),
                                            eng.executors)
    return eng


@pytest.mark.parametrize("conv_impl", ["im2col", "direct"])
@given(
    sizes=st.lists(st.integers(1, 9), min_size=1, max_size=6),
    events=st.lists(st.sampled_from(["poll", "wait"]), max_size=6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_continuous_engine_serves_any_arrivals_bit_identical(
        serve_fused_params, conv_impl, sizes, events, seed):
    """ISSUE 6 property: under ANY ragged arrival pattern and flush
    timing, the continuous engine returns every request's logits
    bit-identical to its exact-shape forward, drains clean, and no
    dispatch extent exceeds the row budget. (Runs the CPU-fast xla
    engine across both conv lowerings; the interpret xnor/megakernel
    legs of the matrix are asserted deterministically in
    tests/test_serve.py — interpret Pallas inside a hypothesis loop
    would be minutes per example.)"""
    from repro.core.bnn import bnn_apply_fused

    class Clock:
        t = 0.0
        def __call__(self):
            return self.t

    clk = Clock()
    eng = _continuous_engine(serve_fused_params, "xla", conv_impl, clk)
    rng = np.random.default_rng(seed)
    it = iter(events + ["poll"] * len(sizes))
    requests = {}
    for n in sizes:
        x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        if next(it) == "wait":
            clk.t += 1.0            # age past max_wait: ragged flush
        eng.step()
    eng.drain()
    assert eng.batcher.pending_rows == 0
    for rid, x in requests.items():
        got = eng.take(rid)
        want = np.asarray(
            bnn_apply_fused(serve_fused_params, jnp.asarray(x),
                            engine="xla", conv_impl=conv_impl)
        )
        assert got is not None
        np.testing.assert_array_equal(got, want)
    for extent in eng.snapshot()["batches"]["per_bucket"]:
        assert extent <= 8          # budget bounds every dispatch extent


@given(n=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ragged_executor_returns_exactly_real_rows(serve_fused_params, n,
                                                   seed):
    """RaggedExecutorCache.run pads to the extent class internally and
    hands back exactly the n real rows, bit-identical to the exact-shape
    forward — for ANY n."""
    from repro.core.bnn import bnn_apply_fused
    from repro.serve import RaggedExecutorCache, extent_for

    cache = _EXEC_CACHES.setdefault(
        ("xla", "im2col"),
        RaggedExecutorCache(serve_fused_params, engine="xla"),
    )
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
    out = cache.run(x)
    assert out.shape[0] == n
    assert cache.extent_of(n) == extent_for(n)
    want = np.asarray(bnn_apply_fused(serve_fused_params, jnp.asarray(x)))
    np.testing.assert_array_equal(out, want)


class _ShapeMesh:
    def __init__(self, **axes):
        self.shape = axes


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    pod=st.sampled_from([1, 2]),
    data=st.sampled_from([4, 16]),
    model=st.sampled_from([4, 16]),
)
@settings(max_examples=50, deadline=None)
def test_sharding_specs_always_divide(dims, pod, data, model):
    """No rule may emit a spec whose axis size does not divide the dim."""
    mesh = _ShapeMesh(pod=pod, data=data, model=model)
    leaf = np.zeros(tuple(dims))
    for path in (["q_proj", "w"], ["down_proj", "w"], ["moe", "up_proj", "w"],
                 ["lm_head", "w"], ["up_proj", "w_packed"]):
        keys = tuple(jax.tree_util.DictKey(k) for k in path)
        spec = sharding.param_spec(mesh, keys, leaf)
        for dim, ax in zip(dims, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (path, dims, spec)


@given(
    dims=st.lists(st.integers(1, 80), min_size=2, max_size=5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_stacked_chain_padding_roundtrip_property(dims, seed):
    """Megakernel stacking (ISSUE 5): stack_chain_layers is lossless —
    slicing the padded [L, M_max, KW_max] stack recovers every layer's
    packed words and affines exactly, and every pad element carries the
    xnor-neutral convention (zero weight words, a=0, b=+1), for ANY
    ragged chain of layer sizes."""
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(dims) - 1):
        k, m = dims[i], dims[i + 1]
        kw = -(-k // 32)
        w = np.sign(rng.normal(size=(m, kw * 32))) + 0.0
        w[w == 0] = 1.0
        w[:, k:] = -1.0  # ragged-K weight pad bits
        layers.append({
            "w_packed": bitops.pack_bits(jnp.asarray(w), axis=1),
            "a": jnp.asarray(rng.normal(size=m).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=m).astype(np.float32)),
        })
    stack = stack_chain_layers(layers)
    l = len(layers)
    m_max = max(-(-p["w_packed"].shape[0] // 32) * 32 for p in layers)
    kw_max = max(p["w_packed"].shape[1] for p in layers)
    assert stack["w"].shape == (l, m_max, kw_max)
    for i, p in enumerate(layers):
        m, kw = p["w_packed"].shape
        np.testing.assert_array_equal(
            np.asarray(stack["w"][i, :m, :kw]), np.asarray(p["w_packed"])
        )
        np.testing.assert_array_equal(
            np.asarray(stack["a"][i, :m]), np.asarray(p["a"])
        )
        np.testing.assert_array_equal(
            np.asarray(stack["b"][i, :m]), np.asarray(p["b"])
        )
        # pad conventions: zero weight rows/words, a=0, b=+1
        assert not np.asarray(stack["w"][i, m:]).any()
        assert not np.asarray(stack["w"][i, :, kw:]).any()
        assert not np.asarray(stack["a"][i, m:]).any()
        np.testing.assert_array_equal(
            np.asarray(stack["b"][i, m:]),
            np.ones(m_max - m, np.float32),
        )


# ---------------------------------------------------------------------------
# mesh-sharded serving (ISSUE 7, DESIGN.md §10)
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 300),
    devices=st.sampled_from([1, 2, 4, 8]),
    tile=st.sampled_from([8, 16]),
)
@settings(max_examples=100, deadline=None)
def test_mesh_extent_rounding_closed_under_redispatch(n, devices, tile):
    """Mesh-multiple extent rounding (ISSUE 7): for ANY n/devices/tile,
    the class covers n, divides the mesh, is idempotent (re-dispatching
    a padded batch lands on the same class), is monotone, and appears
    in the warmup set of any budget that covers n."""
    from repro.serve.executor import default_extents, extent_for

    e = extent_for(n, tile=tile, devices=devices)
    assert e >= n
    assert e % devices == 0
    assert extent_for(e, tile=tile, devices=devices) == e
    if n > 1:
        assert e >= extent_for(n - 1, tile=tile, devices=devices)
    assert e in default_extents(n, tile=tile, devices=devices)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs the conftest's 8 forced host devices")
@given(
    sizes=st.lists(st.integers(1, 11), min_size=1, max_size=6),
    events=st.lists(st.sampled_from(["poll", "wait"]), max_size=6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_sharded_continuous_engine_serves_any_arrivals_bit_identical(
        serve_fused_params, sizes, events, seed):
    """ISSUE 7 property: the continuous engine on an 8-device mesh
    preserves no-drop / no-dup / FIFO under ANY ragged arrival pattern,
    every dispatch extent divides the mesh, and each request's logits
    are bit-identical to exact-shape SINGLE-DEVICE execution (the
    sharded path must be observationally indistinguishable)."""
    from repro.core.bnn import bnn_apply_fused
    from repro.launch.mesh import make_serving_mesh
    from repro.serve import ContinuousServingEngine

    class Clock:
        t = 0.0
        def __call__(self):
            return self.t

    clk = Clock()
    eng = ContinuousServingEngine(serve_fused_params, engine="xla",
                                  max_rows=8, max_wait_s=0.25,
                                  mesh=make_serving_mesh(8), clock=clk)
    eng.executors = _EXEC_CACHES.setdefault(("xla", "im2col", "mesh8"),
                                            eng.executors)
    rng = np.random.default_rng(seed)
    it = iter(events + ["poll"] * len(sizes))
    requests = {}
    completed = []
    for n in sizes:
        x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        if next(it) == "wait":
            clk.t += 1.0
        completed.extend(eng.step())
    completed.extend(eng.drain())
    assert eng.batcher.pending_rows == 0
    # no drop, no dup, FIFO completion (rids are assigned in submit
    # order and coalescing always takes the FIFO prefix)
    assert completed == sorted(completed)
    assert sorted(completed) == sorted(requests)
    for rid, x in requests.items():
        got = eng.take(rid)
        assert got is not None
        want = np.asarray(
            bnn_apply_fused(serve_fused_params, jnp.asarray(x),
                            engine="xla")
        )
        np.testing.assert_array_equal(got, want)
    for extent in eng.snapshot()["batches"]["per_bucket"]:
        assert extent % 8 == 0  # every dispatch divides the mesh


# ---------------------------------------------------------------------------
# resilience (ISSUE 8, DESIGN.md §11)
# ---------------------------------------------------------------------------


@given(
    sizes=st.lists(st.integers(1, 6), min_size=1, max_size=6),
    events=st.lists(st.sampled_from(["poll", "wait"]), max_size=6),
    rate=st.floats(0.0, 0.5),
    deadline=st.sampled_from([None, 0.5, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_faulty_engine_never_loses_a_request(serve_fused_params, sizes,
                                             events, rate, deadline, seed):
    """ISSUE 8 property: under ANY seeded fault schedule (raise + NaN +
    latency at up to 50% of dispatches), ragged arrivals, and optional
    deadlines, EVERY submitted request resolves to exactly one of
    {bit-identical logits, DeadlineExceeded, RequestFailed} — none is
    ever lost or served corrupt bits — and completion order among
    successes stays FIFO."""
    from repro.core.bnn import bnn_apply_fused
    from repro.serve import (DeadlineExceeded, FaultPlan, RequestFailed,
                             RetryPolicy, is_error)

    class Clock:
        def __init__(self):
            self.t = 0.0
        def __call__(self):
            return self.t
        def advance(self, dt):
            self.t += dt

    clk = Clock()
    eng = _continuous_engine(serve_fused_params, "xla", "im2col", clk)
    eng.deadline_s = deadline
    eng.retry = RetryPolicy(max_attempts=2, backoff_base_s=0.05,
                            jitter=0.0)
    eng.faults = FaultPlan(rate=rate, kinds=("raise", "nan", "latency"),
                           latency_s=0.3, seed=seed, sleep=clk.advance)
    rng = np.random.default_rng(seed)
    it = iter(events + ["poll"] * len(sizes))
    requests = {}
    resolved = []
    for n in sizes:
        x = rng.normal(size=(n, 32, 32, 3)).astype(np.float32)
        requests[eng.submit(x)] = x
        if next(it) == "wait":
            clk.t += 1.0
        resolved.extend(eng.step())
    resolved.extend(eng.drain())
    assert eng.batcher.pending_rows == 0
    # exactly-once resolution: no request lost, none resolved twice
    assert sorted(resolved) == sorted(requests)
    completed = []
    for rid in resolved:        # in resolution order
        got = eng.take(rid)
        assert got is not None
        if is_error(got):
            assert isinstance(got, (DeadlineExceeded, RequestFailed))
            continue
        completed.append(rid)
        want = np.asarray(
            bnn_apply_fused(serve_fused_params,
                            jnp.asarray(requests[rid]), engine="xla")
        )
        np.testing.assert_array_equal(got, want)
    # FIFO among successes (rids are assigned in submit order)
    assert completed == sorted(completed)


@given(
    base=st.floats(0.1, 10.0),
    inflation=st.floats(2.0, 10.0),
    hosts=st.integers(3, 8),
    patience=st.integers(1, 5),
)
@settings(max_examples=25, deadline=None)
def test_straggler_detector_ewma_property(base, inflation, hosts, patience):
    """For ANY fleet size >= 3, base step time, and >= 2x persistent
    inflation: the MAD-robust z-score flags the straggler after exactly
    ``patience`` observations — never earlier, and never a healthy
    host."""
    from repro.distributed.fault_tolerance import StragglerDetector

    det = StragglerDetector(patience=patience)
    times = {h: base for h in range(hosts - 1)}
    times[hosts - 1] = base * inflation
    for round_ in range(1, patience + 3):
        flagged = det.observe(times)
        if round_ < patience:
            assert flagged == []
        else:
            assert flagged == [hosts - 1]


_RESUME_BASELINE: dict = {}


def _resume_cfg(ckpt_dir, cadence):
    from repro.train.bnn_trainer import BNNTrainerConfig

    return BNNTrainerConfig(
        steps=5, batch=4, checkpoint_every=cadence, eval_batches=0,
        checkpoint_dir=ckpt_dir,
    )


@given(kill_at=st.integers(1, 4), cadence=st.integers(1, 3))
@settings(max_examples=5, deadline=None)
def test_kill_anywhere_resume_is_bit_identical(kill_at, cadence):
    """Kill training at ANY step, restore via latest_valid_step,
    continue: final params bit-identical to the uninterrupted run. Any
    divergence is a resume bug — the stateless (seed, step) data stream
    plus full (params, Adam, EF) checkpoints admit no drift. The
    checkpoint cadence sweep covers kill-before-first-save (fresh-init
    replay) through kill-right-after-save (zero recompute)."""
    import shutil
    import tempfile

    from repro.train.bnn_trainer import train_bnn
    from repro.train.resilience import (
        TrainFaultPlan, TrainFaultSpec, train_bnn_resilient,
    )

    if "params" not in _RESUME_BASELINE:   # one uninterrupted reference
        d = tempfile.mkdtemp()
        try:
            _RESUME_BASELINE["params"] = train_bnn(_resume_cfg(d, 50)).params
        finally:
            shutil.rmtree(d, ignore_errors=True)

    d = tempfile.mkdtemp()
    try:
        plan = TrainFaultPlan([TrainFaultSpec("preempt", at=kill_at)])
        r = train_bnn_resilient(_resume_cfg(d, cadence), faults=plan)
    finally:
        shutil.rmtree(d, ignore_errors=True)

    base = jax.tree.leaves(_RESUME_BASELINE["params"])
    got = jax.tree.leaves(r.params)
    assert len(base) == len(got)
    for want, have in zip(base, got):
        np.testing.assert_array_equal(np.asarray(want), np.asarray(have))
    # recomputed work is bounded by the distance to the last checkpoint
    assert r.recomputed_steps == kill_at - (kill_at // cadence) * cadence
