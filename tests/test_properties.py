"""Property-based tests (hypothesis) on the system's core invariants:

* pack/unpack is a bijection on ±1 tensors,
* xnor-popcount GEMM == ±1 float GEMM for ANY packed shapes,
* packed BitLinear == fake-quant BitLinear on ±1-valued weights,
* EF-compression error is bounded by one quantization step,
* sharding specs always divide (the divisibility guard is total).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bitops
from repro.core.binarize import QuantMode
from repro.core.layers import BitLinearConfig, bit_linear, pack_linear_params
from repro.distributed import compression, sharding

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=6)


@given(
    m=st.integers(1, 5), kw=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(m, kw, seed):
    rng = np.random.default_rng(seed)
    x = np.sign(rng.normal(size=(m, kw * 32))) + 0.0
    x[x == 0] = 1.0
    packed = bitops.pack_bits(jnp.asarray(x), axis=1)
    assert packed.shape == (m, kw)
    back = bitops.unpack_bits(packed, axis=1)
    np.testing.assert_array_equal(np.asarray(back), x)


@given(
    m=st.integers(1, 4), kw=st.integers(1, 3), n=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_xnor_gemm_equals_pm1_gemm(m, kw, n, seed):
    rng = np.random.default_rng(seed)
    k = kw * 32
    w = np.sign(rng.normal(size=(m, k))) + 0.0
    x = np.sign(rng.normal(size=(k, n))) + 0.0
    w[w == 0] = 1.0
    x[x == 0] = 1.0
    wp = bitops.pack_bits(jnp.asarray(w), axis=1)
    xp = bitops.pack_bits(jnp.asarray(x), axis=0)
    ref = (w @ x).astype(np.int32)
    got = bitops.xnor_popcount_matmul(wp, xp, k)
    np.testing.assert_array_equal(np.asarray(got), ref)


@given(
    din=st.integers(1, 70), dout=st.integers(1, 8), b=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_packed_linear_matches_fakequant(din, dout, b, seed):
    """For ±1-valued latent weights, PACKED == FAKE_QUANT exactly —
    including the K-padding correction for din not divisible by 32."""
    rng = np.random.default_rng(seed)
    w = np.sign(rng.normal(size=(dout, din))).astype(np.float32)
    w[w == 0] = 1.0
    x = rng.normal(size=(b, din)).astype(np.float32)
    params = {"w": jnp.asarray(w)}
    packed = pack_linear_params(params)
    fq = bit_linear(params, jnp.asarray(x),
                    BitLinearConfig(mode=QuantMode.FAKE_QUANT,
                                    binarize_acts=False))
    pk = bit_linear(packed, jnp.asarray(x),
                    BitLinearConfig(mode=QuantMode.PACKED,
                                    binarize_acts=False, engine="xla"))
    np.testing.assert_allclose(np.asarray(pk), np.asarray(fq),
                               rtol=2e-5, atol=2e-4)


@given(
    n=st.integers(2, 300), scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_compression_error_bounded(n, scale, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
    deq, err = compression.compress_decompress(g, jnp.zeros_like(g))
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= step * 0.5 + 1e-6


class _ShapeMesh:
    def __init__(self, **axes):
        self.shape = axes


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    pod=st.sampled_from([1, 2]),
    data=st.sampled_from([4, 16]),
    model=st.sampled_from([4, 16]),
)
@settings(max_examples=50, deadline=None)
def test_sharding_specs_always_divide(dims, pod, data, model):
    """No rule may emit a spec whose axis size does not divide the dim."""
    mesh = _ShapeMesh(pod=pod, data=data, model=model)
    leaf = np.zeros(tuple(dims))
    for path in (["q_proj", "w"], ["down_proj", "w"], ["moe", "up_proj", "w"],
                 ["lm_head", "w"], ["up_proj", "w_packed"]):
        keys = tuple(jax.tree_util.DictKey(k) for k in path)
        spec = sharding.param_spec(mesh, keys, leaf)
        for dim, ax in zip(dims, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (path, dims, spec)
