"""SSM selective-scan Pallas kernel vs the model's associative-scan
oracle (the two implementations of the same recurrence must agree)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ssm_scan_chunk


def ref_scan(dt, xh, bmat, cmat, a, h0):
    """Sequential reference recurrence."""
    b, c, di = dt.shape
    h = h0
    ys = []
    for t in range(c):
        da = jnp.exp(dt[:, t, :, None] * a)
        dbx = (dt[:, t] * xh[:, t])[..., None] * bmat[:, t, None, :]
        h = h * da + dbx
        ys.append(jnp.einsum("bdn,bn->bd", h, cmat[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("b,c,di,ds,bd", [
    (2, 16, 64, 8, 32),
    (1, 32, 128, 16, 128),
    (3, 8, 32, 4, 16),
])
def test_ssm_scan_matches_ref(b, c, di, ds, bd):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, c, di)))
    xh = jax.random.normal(ks[1], (b, c, di))
    bmat = jax.random.normal(ks[2], (b, c, ds))
    cmat = jax.random.normal(ks[3], (b, c, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.5)
    h0 = jax.random.normal(ks[5], (b, di, ds)) * 0.1

    y, h_last = ssm_scan_chunk(dt, xh, bmat, cmat, a, h0, block_d=bd,
                               interpret=True)
    y_ref, h_ref = ref_scan(dt, xh, bmat, cmat, a, h0)
    np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h_last, h_ref, atol=1e-5, rtol=1e-5)


def test_ssm_scan_matches_model_chunk():
    """Against the associative-scan formulation used by models/mamba.py."""
    from repro.models.mamba import _selective_scan_chunk

    b, c, di, ds = 2, 16, 64, 8
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, c, di)))
    xh = jax.random.normal(ks[1], (b, c, di))
    bmat = jax.random.normal(ks[2], (b, c, ds))
    cmat = jax.random.normal(ks[3], (b, c, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.5)
    h0 = jax.random.normal(ks[5], (b, di, ds)) * 0.1

    h_model, y_model = _selective_scan_chunk(h0, (dt, xh, bmat, cmat, a))
    y, h_last = ssm_scan_chunk(dt, xh, bmat, cmat, a, h0, block_d=32,
                               interpret=True)
    np.testing.assert_allclose(y, y_model, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h_last, h_model, atol=1e-5, rtol=1e-5)
