"""The train half of the train-to-serve loop (ISSUE 9, DESIGN.md §12):

* warmup off-by-one regression — the schedule must see the
  POST-increment optimizer step, or cosine_schedule(0) == 0.0 turns the
  entire first optimizer step into a no-op;
* actionable microbatch errors instead of cryptic reshape failures;
* model metrics (accuracy, BN batch stats) threading through
  make_train_step, including the microbatch-accumulation path;
* train_bnn: loss decreases, latent clip invariant, running BN stats
  move, checkpoints write and RESUME;
* pack_trained_params: the committed trained checkpoint exports to all
  engine formats bit-identically (the full engine x conv_impl matrix on
  a fixed artifact — the hypothesis round-trip in test_properties.py
  covers random models on the cheap engines);
* the shard_map data-parallel step across all grad compressions.
"""

import dataclasses
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.checkpoint import manager as ckpt_manager
from repro.core.bnn import (
    bnn_eval_logits,
    init_bnn_params,
    load_binary_checkpoint,
    pack_trained_params,
    save_binary_checkpoint,
)
from repro.data.pipeline import DataConfig, synthetic_cifar_batches
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import cosine_schedule
from repro.train.bnn_trainer import (
    DP_COMPRESSIONS,
    BNNTrainerConfig,
    _BNNTask,
    bnn_clip_predicate,
    init_dp_error_feedback,
    make_dp_train_step,
    train_bnn,
)
from repro.train.step import (
    TrainConfig,
    _split_microbatches,
    init_opt_state,
    make_train_step,
)

GOLDEN_CKPT = pathlib.Path(__file__).parent / "golden" / "bnn_trained_ckpt.npz"


@dataclasses.dataclass(frozen=True)
class _ToyTask:
    """Quadratic model.loss stand-in: loss = mean((x @ w - y)^2)."""

    def loss(self, params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"mae": jnp.mean(jnp.abs(pred - batch["y"]))}


def _toy_setup(batch=8, din=4):
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(din, 1)).astype(np.float32))}
    batch = {
        "x": jnp.asarray(rng.normal(size=(batch, din)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(batch, 1)).astype(np.float32)),
    }
    return params, batch


# ------------------------- warmup off-by-one ---------------------------------


def test_first_step_has_nonzero_lr():
    """Regression (ISSUE 9): the schedule is fed the post-increment
    step. cosine_schedule(0) == 0.0, so the pre-increment count would
    multiply the very first update by a zero learning rate — a wasted
    step, and with gradient accumulation a wasted accumulated batch."""
    assert float(cosine_schedule(0, warmup_steps=10, total_steps=100)) == 0.0
    params, batch = _toy_setup()
    tcfg = TrainConfig(adamw=AdamWConfig(lr=0.1), warmup_steps=10,
                       total_steps=100)
    step = make_train_step(_ToyTask(), tcfg)
    new_params, _, metrics = step(params, init_opt_state(params), batch)
    assert float(metrics["lr_scale"]) > 0.0
    # and therefore the params actually moved on step 1
    assert np.any(np.asarray(new_params["w"]) != np.asarray(params["w"]))


def test_warmup_schedule_is_linear_in_post_increment_step():
    params, batch = _toy_setup()
    tcfg = TrainConfig(adamw=AdamWConfig(lr=0.1), warmup_steps=4,
                       total_steps=100)
    step = make_train_step(_ToyTask(), tcfg)
    opt = init_opt_state(params)
    scales = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        scales.append(float(metrics["lr_scale"]))
    np.testing.assert_allclose(scales, [0.25, 0.5, 0.75, 1.0], rtol=1e-6)


# ------------------------- microbatch validation -----------------------------


def test_microbatch_indivisible_batch_raises_actionable():
    params, batch = _toy_setup(batch=6)
    tcfg = TrainConfig(microbatches=4)
    step = make_train_step(_ToyTask(), tcfg)
    with pytest.raises(ValueError, match=r"batch size 6.*microbatches=4"):
        step(params, init_opt_state(params), batch)


def test_microbatch_scalar_leaf_raises_actionable():
    params, batch = _toy_setup(batch=8)
    batch = dict(batch, step=jnp.asarray(3))  # bookkeeping scalar
    tcfg = TrainConfig(microbatches=2)
    step = make_train_step(_ToyTask(), tcfg)
    with pytest.raises(ValueError, match=r"scalar bookkeeping keys"):
        step(params, init_opt_state(params), batch)


def test_microbatch_mismatched_leading_dims_raise():
    batch = {"x": jnp.zeros((8, 3)), "y": jnp.zeros((4, 1))}
    with pytest.raises(ValueError, match="leading"):
        _split_microbatches(batch, 2)


def test_split_microbatches_shape():
    batch = {"x": jnp.zeros((8, 3)), "y": jnp.zeros((8, 1))}
    out = _split_microbatches(batch, 4)
    assert out["x"].shape == (4, 2, 3)
    assert out["y"].shape == (4, 2, 1)


# ------------------------- metrics threading ---------------------------------


def test_model_metrics_ride_along():
    params, batch = _toy_setup()
    step = make_train_step(_ToyTask(), TrainConfig())
    _, _, metrics = step(params, init_opt_state(params), batch)
    assert set(metrics) >= {"mae", "loss", "grad_norm", "lr_scale"}
    assert np.isfinite(float(metrics["mae"]))


def test_microbatch_metrics_average_matches_full_batch():
    """Accumulated gradients average over microbatches, and so must the
    model metrics — for this quadratic task the per-microbatch MAE mean
    equals neither 0 nor the full-batch value in general, so just check
    finiteness + loss consistency against the mathematically equal
    mean-of-means decomposition (equal microbatch sizes)."""
    params, batch = _toy_setup(batch=8)
    full = make_train_step(_ToyTask(), TrainConfig())
    micro = make_train_step(_ToyTask(), TrainConfig(microbatches=4))
    _, _, m_full = full(params, init_opt_state(params), batch)
    _, _, m_micro = micro(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m_micro["loss"]),
                               float(m_full["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m_micro["mae"]),
                               float(m_full["mae"]), rtol=1e-5)


# ------------------------- the BNN trainer -----------------------------------


def test_train_bnn_learns_and_respects_invariants(tmp_path):
    cfg = BNNTrainerConfig(
        steps=20, batch=32, lr=3e-3, warmup_steps=2, eval_batches=1,
        checkpoint_dir=str(tmp_path), checkpoint_every=10,
    )
    res = train_bnn(cfg)
    # warmup fix: step 1 is live
    assert res.history["lr_scale"][0] > 0.0
    # learning signal: back-half mean loss clearly below the first loss
    # (measured: ~1.6 vs 2.75 for this config; 0.5 margin kills noise)
    assert np.mean(res.history["loss"][10:]) < res.history["loss"][0] - 0.5
    # latent clip invariant after real optimizer steps
    for group in ("conv", "fc"):
        for layer in res.params[group]:
            w = np.asarray(layer["w"])
            assert w.min() >= -1.0 and w.max() <= 1.0
    # running BN stats moved off the init values (mean 0 / var 1)
    m0 = np.asarray(res.params["bn_conv"][0]["mean"])
    assert np.any(m0 != 0.0)
    # checkpoints were written and validate
    assert ckpt_manager.latest_valid_step(str(tmp_path)) == cfg.steps


def test_train_bnn_resumes_from_checkpoint(tmp_path):
    """Simulate preemption the honest way: run the FULL job with
    checkpoints, delete the final checkpoint (as if the process died
    after step 2), and rerun the SAME config. The cosine horizon is
    ``total_steps = cfg.steps``, so a shorter-steps run is NOT a prefix
    of the full run — resume must replay under the original horizon."""
    cfg = BNNTrainerConfig(steps=4, batch=8, warmup_steps=1,
                           eval_batches=1, checkpoint_dir=str(tmp_path),
                           checkpoint_every=2)
    full = train_bnn(cfg)
    assert full.start_step == 0
    shutil.rmtree(tmp_path / f"step_{cfg.steps:08d}")
    assert ckpt_manager.latest_valid_step(str(tmp_path)) == 2
    resumed = train_bnn(cfg)
    assert resumed.start_step == 2
    # deterministic data stream + saved opt state => identical end params
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------- trained-checkpoint export -------------------------


@pytest.fixture(scope="module")
def trained_params():
    assert GOLDEN_CKPT.exists(), (
        "committed trained checkpoint missing — run examples/bnn_cifar.py"
    )
    return load_binary_checkpoint(GOLDEN_CKPT)


def test_pack_trained_params_engine_matrix(trained_params):
    """The committed trained checkpoint exports to every serving-engine
    format and the probe verifies ALL of them bit-identical to the
    float-boundary forward (pack_trained_params raises otherwise):
    packed/xla, fused xla+xnor x im2col+direct, megakernel + its xla
    twin."""
    images = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32, 3))
    out = pack_trained_params(trained_params, probe_images=images)
    assert set(out) == {"packed", "fused", "megakernel"}


def test_sign_checkpoint_roundtrip_bit_identical(trained_params, tmp_path):
    images = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 32, 3))
    p = str(tmp_path / "rt.npz")
    save_binary_checkpoint(p, trained_params)
    re = load_binary_checkpoint(p)
    np.testing.assert_array_equal(
        np.asarray(bnn_eval_logits(trained_params, images)),
        np.asarray(bnn_eval_logits(re, images)),
    )


def test_pack_trained_params_detects_corruption(trained_params):
    """The export probe must refuse to ship a checkpoint that cannot
    serve what it computes. A latent sign flip stays self-consistent
    (the probe re-derives the reference from the same params), but a
    poisoned final BN variance drives every forward to NaN — and under
    the exact-equality contract NaN != NaN, so the probe raises and
    names the diverging engines instead of exporting garbage."""
    var = np.asarray(trained_params["bn_fc"][-1]["var"]).copy()
    var[0] = -1.0
    forged = {**trained_params,
              "bn_fc": list(trained_params["bn_fc"][:-1])
              + [{**trained_params["bn_fc"][-1], "var": jnp.asarray(var)}]}
    images = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 32, 3))
    with pytest.raises(ValueError, match="bit-identity"):
        pack_trained_params(forged, probe_images=images)
    # a sign flip is a DIFFERENT trained model, not corruption: packing
    # it against its own forward must still pass the probe
    w = np.asarray(trained_params["fc"][0]["w"]).copy()
    w[0, 0] = -w[0, 0]
    flipped = {**trained_params,
               "fc": [{**trained_params["fc"][0], "w": jnp.asarray(w)}]
               + list(trained_params["fc"][1:])}
    pack_trained_params(flipped, probe_images=images)


# ------------------------- data-parallel trainer -----------------------------


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices")
@pytest.mark.parametrize("compression", DP_COMPRESSIONS)
def test_dp_train_step_all_compressions(compression):
    cfg = BNNTrainerConfig(steps=2, batch=8, warmup_steps=1)
    task = _BNNTask(cfg.model_config())
    params = init_bnn_params(jax.random.PRNGKey(0))
    n_dev = 2
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("data",))
    batch = next(iter(synthetic_cifar_batches(
        DataConfig(global_batch=8, seed=11))))
    batch = {k: batch[k] for k in ("images", "labels")}
    step = jax.jit(make_dp_train_step(
        task, cfg.train_config(), mesh, grad_compression=compression,
        clip_predicate=bnn_clip_predicate,
    ))
    err = init_dp_error_feedback(params, n_dev)
    p, o, e, m1 = step(params, init_opt_state(params), err, batch)
    p, o, e, m2 = step(p, o, e, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # the residual stays stacked per shard and (for compressed paths)
    # actually accumulates quantization error
    lead = {leaf.shape[0] for leaf in jax.tree.leaves(e)}
    assert lead == {n_dev}
    if compression != "none":
        assert any(np.any(np.asarray(leaf) != 0)
                   for leaf in jax.tree.leaves(e))
    # latent clip invariant survives the DP path too
    for group in ("conv", "fc"):
        for layer in p[group]:
            w = np.asarray(layer["w"])
            assert w.min() >= -1.0 and w.max() <= 1.0


def test_dp_train_step_rejects_unknown_compression():
    cfg = BNNTrainerConfig()
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="unknown grad_compression"):
        make_dp_train_step(_BNNTask(cfg.model_config()),
                           cfg.train_config(), mesh,
                           grad_compression="fp8")
