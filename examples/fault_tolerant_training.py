"""Fault-tolerant BNN training demo (DESIGN.md §13): the resilient
driver surviving a scripted fault plan — simulated preemption, a NaN
batch caught by the loss sentinel and rolled back, a torn checkpoint —
and finishing bit-identical to an uninterrupted run.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import shutil
import tempfile

import jax
import numpy as np

from repro.train.bnn_trainer import BNNTrainerConfig, train_bnn
from repro.train.resilience import (
    TrainFaultPlan,
    TrainFaultSpec,
    train_bnn_resilient,
)


def main():
    def cfg(ckpt_dir):
        return BNNTrainerConfig(
            steps=8, batch=8, checkpoint_every=2, eval_batches=2,
            checkpoint_dir=ckpt_dir,
        )

    # The reference: the same run, uninterrupted.
    ref_dir = tempfile.mkdtemp(prefix="bnn_ref_")
    reference = train_bnn(cfg(ref_dir))

    # The chaos run: a preemption (process kill, restore from the last
    # checkpoint), a torn checkpoint write (skipped as invalid by the
    # next restore), and a NaN batch (the sentinel sees the non-finite
    # grad norm, discards the poisoned update, replays clean).
    plan = TrainFaultPlan([
        TrainFaultSpec("preempt", at=3),
        TrainFaultSpec("torn_ckpt", at=4),
        TrainFaultSpec("nan_batch", at=5),
    ])
    chaos_dir = tempfile.mkdtemp(prefix="bnn_chaos_")
    result = train_bnn_resilient(cfg(chaos_dir), faults=plan, verbose=True)

    print("\nfault/recovery events:")
    for e in result.events:
        print(f"  step {e.get('step', '?'):>3}  {e['kind']}")
    print(f"restore points: {[p['step'] for p in result.restore_points]}")
    print(f"recomputed steps: {result.recomputed_steps}")

    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(reference.params),
                        jax.tree.leaves(result.params))
    )
    print(f"final params bit-identical to uninterrupted run: {identical}")
    print(f"eval: loss {result.eval_loss:.4f} acc {result.eval_acc:.3f} "
          f"(chance 0.10)")
    assert identical, "resume bug: chaos run diverged from the reference"

    shutil.rmtree(ref_dir, ignore_errors=True)
    shutil.rmtree(chaos_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
