"""Fault-tolerant training demo: checkpoint cadence, simulated worker
failure, elastic mesh rebuild, auto-resume from the latest valid step.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs import smoke_config, train_policy
from repro.data.pipeline import DataConfig, synthetic_lm_batches
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    WorkerFailure,
    plan_mesh_for,
    run_with_recovery,
)
from repro.models.model_factory import build_model
from repro.train.step import TrainConfig, init_opt_state, make_train_step


def main():
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg, train_policy())
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, TrainConfig()))

    data_iter = synthetic_lm_batches(
        DataConfig(global_batch=4, seq_len=32, vocab_size=cfg.vocab_size))
    batches = [next(data_iter) for _ in range(40)]

    ckpt_dir = tempfile.mkdtemp(prefix="ft_ckpt_")
    state = {"params": params, "opt": opt}
    crash_at = {"step": 12, "armed": True}
    monitor = HeartbeatMonitor(num_hosts=2, timeout=1e9)
    log = []

    def train_one(step):
        if step == crash_at["step"] and crash_at["armed"]:
            crash_at["armed"] = False
            print(f"  !! injected worker failure at step {step}")
            raise WorkerFailure([1])
        b = batches[step % len(batches)]
        state["params"], state["opt"], m = step_fn(
            state["params"], state["opt"],
            {"tokens": b["tokens"], "labels": b["labels"]},
        )
        log.append(step)
        return {"loss": float(m["loss"])}

    def save(step):
        ckpt.save(ckpt_dir, step, state)
        print(f"  checkpoint @ step {step}")

    def restore():
        latest = ckpt.latest_valid_step(ckpt_dir)
        if latest is None:
            return 0
        restored = ckpt.restore(ckpt_dir, latest, state)
        state.update(restored)
        print(f"  restored from step {latest}")
        return latest

    def rebuild(dead_hosts):
        # elastic: plan the largest mesh from surviving devices
        survivors = 512 - 256 * len(dead_hosts)
        plan = plan_mesh_for(max(survivors, 1))
        print(f"  rebuilt mesh for {survivors} devices: "
              f"{plan.shape} {plan.axes}")

    out = run_with_recovery(
        num_steps=20, step_fn=train_one, save_fn=save, restore_fn=restore,
        monitor=monitor, rebuild_fn=rebuild, checkpoint_every=5,
    )
    print(f"finished: last loss {out['loss']:.4f}; "
          f"steps executed (with replay): {len(log)}")
    assert log[-1] == 19


if __name__ == "__main__":
    main()
