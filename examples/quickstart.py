"""Quickstart: train a binarized LM, pack it to 1-bit, serve it.

The full pipeline of the paper's technique applied to a modern LM:
  1. train with fake-quant STE binarization (what released BNNs do),
  2. pack every *_proj weight to int32 bitwise matrices (paper §3.1),
  3. serve with the packed-weight kernel path (paper §3.2 / DESIGN §2).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config, serve_policy, train_policy
from repro.launch.train import train
from repro.models.model_factory import build_model


def main():
    # 1. train (smoke-sized smollm; --full for the real config on a fleet)
    out = train("smollm-360m", smoke=True, steps=60, batch=8, seq=64,
                lr=1e-3, log_every=20)
    first, last = np.mean(out["losses"][:5]), np.mean(out["losses"][-5:])
    print(f"\ntraining loss {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NOT LEARNING'})")

    # 2. pack to 1-bit
    cfg = smoke_config("smollm-360m")
    model = build_model(cfg, serve_policy())
    float_params = out["params"]
    packed = model.pack(float_params)
    fbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(float_params))
    pbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(packed))
    print(f"params {fbytes/1e6:.1f} MB float -> {pbytes/1e6:.1f} MB packed "
          f"({fbytes/pbytes:.1f}x smaller)")

    # 3. serve
    state = model.init_state(2, 48, dtype=jnp.float32)
    prompts = jnp.ones((2, 32), jnp.int32)
    logits, state = jax.jit(model.prefill)(packed, state, {"tokens": prompts})
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen = [tok]
    decode = jax.jit(model.decode_step)
    for _ in range(7):
        logits, state = decode(packed, state, {"tokens": tok})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen.append(tok)
    print("generated:", np.asarray(jnp.concatenate(gen, 1)))


if __name__ == "__main__":
    main()
