"""Data-parallel training with error-feedback int8 gradient compression
(shard_map path — see distributed/compression.py scope note).

Runs on however many devices exist; with 1 device the collective is a
no-op but the quantize/EF math is exercised end to end, and the loss
still converges — demonstrating the compression does not break training.

  PYTHONPATH=src python examples/ddp_compression.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import compression


def main():
    devices = np.asarray(jax.devices())
    mesh = Mesh(devices, ("data",))
    ndev = len(devices)
    print(f"devices: {ndev}")

    # toy regression model
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(32, 1)).astype(np.float32)
    X = rng.normal(size=(128 * ndev, 32)).astype(np.float32)
    Y = X @ w_true + 0.01 * rng.normal(size=(128 * ndev, 1)).astype(np.float32)

    w = jnp.zeros((32, 1))
    err = jnp.zeros_like(w)

    @jax.jit
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P("data"), P("data"), P()),
        out_specs=(P(), P()),
    )
    def step(w, x, y, err):
        def loss_fn(w):
            return jnp.mean((x @ w - y) ** 2)

        g = jax.grad(loss_fn)(w)
        # EF-int8 all-reduce: int8 payload on the wire (4x fewer bytes)
        g_mean, err = compression.psum_compressed(g, err, "data")
        return w - 0.05 * g_mean, err

    for i in range(200):
        w, err = step(w, jnp.asarray(X), jnp.asarray(Y), err)
    final = float(jnp.mean((jnp.asarray(X) @ w - jnp.asarray(Y)) ** 2))
    print(f"final mse {final:.5f} (w err {float(jnp.max(jnp.abs(w - w_true))):.4f})")
    assert final < 1e-2, "compressed DP training failed to converge"
    print("EF-int8 compressed data-parallel training converged OK")


if __name__ == "__main__":
    main()
