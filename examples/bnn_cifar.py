"""The paper's own experiment, end to end — now the full train-to-serve
loop (DESIGN.md §12): train the Courbariaux BNN on (synthetic) CIFAR-10
with the real trainer (STE forward, latent clip, running BN statistics,
resumable checkpoints), export the trained model to every packed
serving format with a bit-identity probe, and write the compact
sign-form checkpoint.

This script (with ``--steps 120 --export tests/golden/bnn_trained_ckpt.npz``)
is what produced the committed trained checkpoint behind
tests/golden/bnn_logits.json — rerunning it reproduces that artifact
bit-for-bit (deterministic seeds, stateless data stream).

  PYTHONPATH=src python examples/bnn_cifar.py [--steps 120] \
      [--checkpoint-dir /tmp/bnn_ckpts] [--export trained.npz]
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.core.bnn import (
    bnn_eval_logits,
    load_binary_checkpoint,
    pack_trained_params,
    save_binary_checkpoint,
)
from repro.data.pipeline import DataConfig, synthetic_cifar_batches
from repro.train.bnn_trainer import BNNTrainerConfig, train_bnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="resumable float checkpoints (checkpoint/manager)")
    ap.add_argument("--export", default=None,
                    help="write the compact sign-form checkpoint here")
    args = ap.parse_args()

    cfg = BNNTrainerConfig(
        steps=args.steps, batch=args.batch, lr=args.lr,
        warmup_steps=max(1, args.steps // 12),
        checkpoint_dir=args.checkpoint_dir,
    )
    t0 = time.time()
    res = train_bnn(cfg, verbose=True)
    resumed = f" (resumed from {res.start_step})" if res.start_step else ""
    print(f"trained {args.steps - res.start_step} steps in "
          f"{time.time() - t0:.1f}s{resumed}")
    print(f"held-out eval: loss {res.eval_loss:.4f} acc {res.eval_acc:.3f} "
          f"(chance 0.10)")

    # Export: pack every serving format, VERIFIED bit-identical to the
    # trained float-boundary forward on a probe batch (raises otherwise).
    probe = next(iter(synthetic_cifar_batches(
        DataConfig(global_batch=4, seed=2024))))["images"]
    packs = pack_trained_params(res.params, probe_images=probe)
    print("export verified bit-identical across engines:",
          ", ".join(sorted(packs)))

    fbytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(res.params))
    pbytes = sum(
        np.asarray(v).nbytes for v in jax.tree.leaves(packs["packed"])
    )
    print(f"weights {fbytes/1e6:.1f} MB -> {pbytes/1e6:.1f} MB packed "
          f"({fbytes/pbytes:.1f}x)")

    if args.export:
        save_binary_checkpoint(args.export, res.params)
        re = load_binary_checkpoint(args.export)
        a = np.asarray(bnn_eval_logits(res.params, probe))
        b = np.asarray(bnn_eval_logits(re, probe))
        assert np.array_equal(a, b), "sign-form round trip diverged"
        pack_trained_params(re, probe_images=probe)
        print(f"sign-form checkpoint: {args.export} "
              f"({os.path.getsize(args.export)/1e6:.2f} MB, "
              f"round trip bit-identical)")


if __name__ == "__main__":
    main()
