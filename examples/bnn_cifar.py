"""The paper's own experiment, end to end: train the Courbariaux BNN on
(synthetic) CIFAR-10, then run packed 1-bit inference and compare all
three kernel modes (paper §4).

  PYTHONPATH=src python examples/bnn_cifar.py [--steps 100]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bnn_cifar import CONTROL_GROUP, SIMULATION, XLA_PACKED
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    bnn_loss,
    init_bnn_params,
    pack_bnn_params,
)
from repro.data.pipeline import DataConfig, synthetic_cifar_batches
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = init_bnn_params(key)
    opt = adamw_init(params)
    # latent_clip: BNN keeps latent weights in [-1, 1] (STE support)
    acfg = AdamWConfig(lr=1e-3, latent_clip=True)

    @jax.jit
    def step(params, opt, images, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: bnn_loss(p, images, labels, SIMULATION), has_aux=True
        )(params)
        params, opt = adamw_update(grads, opt, params, acfg)
        return params, opt, loss, acc

    data = synthetic_cifar_batches(DataConfig(global_batch=args.batch))
    t0 = time.time()
    for i, b in zip(range(args.steps), data):
        params, opt, loss, acc = step(params, opt, b["images"], b["labels"])
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} acc {float(acc):.3f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    # pack to 1-bit and check the three inference modes agree on argmax
    packed = pack_bnn_params(params)
    x = next(data)["images"]
    sim = bnn_apply(params, x, SIMULATION)
    pk = bnn_apply(packed, x, XLA_PACKED)
    agree = float(jnp.mean(jnp.argmax(sim, -1) == jnp.argmax(pk, -1)))
    print(f"packed vs simulation argmax agreement: {agree:.3f}")

    fbytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(params))
    pbytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(packed))
    print(f"weights {fbytes/1e6:.1f} MB -> {pbytes/1e6:.1f} MB "
          f"({fbytes/pbytes:.1f}x)")


if __name__ == "__main__":
    main()
