"""BNN serving driver: run the batched serving engine against synthetic
image traffic and report latency/throughput percentiles.

``--scheduler`` picks the dispatch discipline (both drive the same
modes): ``bucket`` — the PR-4 shape-bucket ladder (pad every dispatch
to a rung); ``continuous`` — the v2 ragged scheduler (DESIGN.md §9:
coalesce real rows up to ``--max-rows``, pad only to a tile-padded
extent class, admission control via ``--max-queue-rows``, SLO-aware
wait via ``--slo-ms``).

Two modes:

* ``--smoke`` (default) — a short fixed burst of ragged requests:
  warms every bucket/extent, verifies per-request logits against a
  direct exact-shape forward, prints the stats snapshot. CI runs this.
* ``--sustained`` — an open-loop load run: requests with random image
  counts arrive at ``--rate`` req/s for ``--duration`` seconds (real
  clock); the engine's dispatch loop runs in the gaps. Reports p50/p95/
  p99 latency, throughput, goodput (with ``--slo-ms``), pad-row waste
  and compile counts.

``--devices N`` (DESIGN.md §10) scales either scheduler out
data-parallel over a 1-D serving mesh: packed weights replicated on
every device, each dispatch's batch sharded over ``data``. Off-TPU the
devices are simulated — the flag forces
``--xla_force_host_platform_device_count=N`` into ``XLA_FLAGS`` before
the first jax backend touch (so it must not be combined with code that
already initialized jax in-process).

  PYTHONPATH=src python -m repro.launch.serve_bnn --smoke
  PYTHONPATH=src python -m repro.launch.serve_bnn --smoke --devices 8
  PYTHONPATH=src python -m repro.launch.serve_bnn --scheduler continuous \
      --sustained --rate 20 --duration 10 --max-images 8 --slo-ms 2500
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.bnn import (
    bnn_apply_fused,
    init_bnn_params,
    pack_bnn_params_fused,
    pack_bnn_params_megakernel,
)
from repro.serve import (
    DEFAULT_BUCKETS,
    ContinuousServingEngine,
    FallbackPolicy,
    QueueFull,
    RetryPolicy,
    ServingEngine,
    is_error,
    load_serving_blocks,
)


def _force_host_devices(n: int) -> None:
    """Simulated scale-out: force ``n`` host platform devices. Must run
    before the first jax backend touch; a pre-set count in XLA_FLAGS
    (e.g. the CI leg's environment) wins."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )


def build_engine(args, *, clock=time.monotonic) -> ServingEngine:
    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.devices)
        print(f"serving mesh: {args.devices} devices, 1-D data axis "
              f"(weights replicated, batch sharded)")
    params = init_bnn_params(jax.random.PRNGKey(args.seed))
    if args.engine.startswith("megakernel"):
        # one-launch-per-stage executors (DESIGN.md §8) take the
        # pre-stacked megakernel params; conv_impl is direct-path by
        # construction and ignored.
        fused = pack_bnn_params_megakernel(params)
    else:
        fused = pack_bnn_params_fused(params)
    blocks = "auto"
    if args.blocks == "tuned":
        # deployment config saved by benchmarks/serving.py (or any
        # tune_serving_blocks run) in the autotune cache. The tuner may
        # have run at any bucket of the ladder (the benchmark tunes at
        # its largest MEASURED bucket), so probe largest-first and say
        # which entry — if any — was found.
        for b in sorted(args.buckets, reverse=True):
            blocks = load_serving_blocks(args.engine, args.conv_impl, b)
            if blocks != "auto":
                print(f"using tuned serving config for bucket {b}: "
                      f"{blocks}")
                break
        else:
            print("no tuned serving config in the autotune cache for "
                  f"engine={args.engine} conv_impl={args.conv_impl} "
                  f"buckets={args.buckets}; falling back to 'auto'")
    slo_s = args.slo_ms / 1e3 if args.slo_ms is not None else None
    deadline_s = (args.deadline_ms / 1e3
                  if args.deadline_ms is not None else None)
    # --max-retries counts RE-dispatches; the policy counts total
    # attempts (first dispatch included).
    retry = RetryPolicy(max_attempts=args.max_retries + 1)
    fallback = None
    if args.fallback == "on":
        # Arm the bit-identical demotion ladder: hold both param
        # packings so every SERVE_FALLBACKS rung is reachable.
        fallback = FallbackPolicy(
            fused_params=pack_bnn_params_fused(params),
            mega_params=(fused if args.engine.startswith("megakernel")
                         else None),
        )
    if args.scheduler == "continuous":
        return ContinuousServingEngine(
            fused,
            engine=args.engine,
            conv_impl=args.conv_impl,
            blocks=blocks,
            max_rows=args.max_rows,
            max_wait_s=args.max_wait_ms / 1e3,
            max_queue_rows=args.max_queue_rows,
            slo_s=slo_s,
            mesh=mesh,
            deadline_s=deadline_s,
            retry=retry,
            fallback=fallback,
            clock=clock,
        )
    eng = ServingEngine(
        fused,
        engine=args.engine,
        conv_impl=args.conv_impl,
        blocks=blocks,
        buckets=args.buckets,
        max_wait_s=args.max_wait_ms / 1e3,
        mesh=mesh,
        deadline_s=deadline_s,
        retry=retry,
        fallback=fallback,
        clock=clock,
    )
    # SLO is a measurement concern, not a policy one, for the bucket
    # ladder — arm the goodput accounting so head-to-head runs compare
    # like with like.
    eng.stats.slo_s = slo_s
    return eng


def _random_request(rng, max_images: int) -> np.ndarray:
    """One synthetic request: U{1..max_images} random images — the ONE
    traffic distribution both smoke and sustained modes draw from."""
    n = int(rng.integers(1, max_images + 1))
    return rng.normal(size=(n, 32, 32, 3)).astype(np.float32)


def _random_requests(rng, count: int, max_images: int) -> list[np.ndarray]:
    return [_random_request(rng, max_images) for _ in range(count)]


def run_smoke(args) -> dict:
    eng = build_engine(args)
    t0 = time.monotonic()
    n_compiled = eng.warmup()
    t_warm = time.monotonic() - t0
    shapes = (eng.extents if args.scheduler == "continuous"
              else eng.batcher.buckets)
    kind = "extent" if args.scheduler == "continuous" else "bucket"
    print(f"warmup: {n_compiled} {kind} executors compiled "
          f"({', '.join(map(str, shapes))}) in {t_warm:.1f}s")

    rng = np.random.default_rng(args.seed)
    requests = _random_requests(rng, args.requests, args.max_images)
    rids = []
    for imgs in requests:
        rids.append(eng.submit(imgs))
        eng.step()
    eng.drain()

    # Verify the engine's core contract on the smoke traffic: per-request
    # logits are bit-identical to running that request's images alone.
    mismatches = 0
    errored = 0
    for rid, imgs in zip(rids, requests):
        got = eng.take(rid)
        if got is not None and is_error(got):
            # terminal resilience marker (deadline/retries) — possible
            # only when --deadline-ms is set tight; not a divergence
            errored += 1
            continue
        if args.engine.startswith("megakernel"):
            from repro.core.bnn import bnn_apply_megakernel

            inner = "xnor" if args.engine == "megakernel" else "xla"
            want = np.asarray(
                bnn_apply_megakernel(eng.executors.packed, imgs,
                                     engine=inner)
            )
        else:
            want = np.asarray(
                bnn_apply_fused(eng.executors.packed, imgs,
                                engine=args.engine,
                                conv_impl=args.conv_impl)
            )
        if got is None or not np.array_equal(got, want):
            mismatches += 1
    snap = eng.snapshot()
    print(f"served {snap['requests']['completed']} requests "
          f"({snap['requests']['images_completed']} images), "
          f"{mismatches} logits mismatches, {errored} expired/failed")
    print(json.dumps(snap, indent=2))
    if mismatches:
        raise SystemExit(f"{mismatches} requests diverged from the "
                         "exact-shape forward")
    return snap


def run_sustained(args) -> dict:
    eng = build_engine(args)
    eng.warmup()
    rng = np.random.default_rng(args.seed)
    interval = 1.0 / args.rate
    t_end = time.monotonic() + args.duration
    t_next = time.monotonic()
    submitted = 0
    rejected = 0
    while time.monotonic() < t_end:
        now = time.monotonic()
        if now >= t_next:
            try:
                eng.submit(_random_request(rng, args.max_images))
                submitted += 1
            except QueueFull:
                rejected += 1  # admission control shed it (counted in
                               # the snapshot too)
            t_next += interval
        # pop finished logits as we go: a long load run must not
        # accumulate every completed result in engine memory
        for rid in eng.step():
            eng.take(rid)
    for rid in eng.drain():
        eng.take(rid)
    snap = eng.snapshot()
    lat, bat = snap["latency_s"], snap["batches"]
    print(f"sustained[{snap['scheduler']}]: {submitted} requests "
          f"({rejected} rejected) over {args.duration:.0f}s "
          f"at {args.rate}/s target")
    print(f"throughput {snap['throughput']['images_per_s']:.1f} img/s | "
          f"latency p50 {lat['p50']*1e3:.0f}ms p95 {lat['p95']*1e3:.0f}ms "
          f"p99 {lat['p99']*1e3:.0f}ms")
    print(f"dispatch shapes {bat['per_bucket']} | pad-row fraction "
          f"{bat['pad_row_fraction']:.1%} | compiles "
          f"{snap['executors']['compiles']} (steady state: 0 new)")
    if snap["slo"]["slo_s"] is not None:
        print(f"SLO {snap['slo']['slo_s']*1e3:.0f}ms: goodput "
              f"{snap['slo']['goodput_images_per_s']:.1f} img/s "
              f"({snap['slo']['images_within_slo']} images within SLO)")
    req, disp = snap["requests"], snap["dispatch"]
    if (req["expired"] or req["failed"] or disp["retries"]
            or snap["degraded"]):
        print(f"resilience: {req['expired']} expired, {req['failed']} "
              f"failed, {disp['retries']} batch retries, "
              f"{disp['fallbacks']} fallbacks "
              f"({' '.join(disp['engine_path']) or 'none'}), "
              f"{snap['mesh']['shrinks']} mesh shrinks | "
              f"degraded={snap['degraded']}")
    print(json.dumps(snap, indent=2))
    return snap


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="xla",
                    choices=["xla", "xnor", "megakernel", "megakernel_xla"],
                    help="xla/xnor: per-layer fused chain (pure-XLA "
                         "fallback, CPU-fast / Pallas, interpret "
                         "off-TPU); megakernel/megakernel_xla: one "
                         "launch per network stage (DESIGN.md §8) — "
                         "uses megakernel-packed params and ignores "
                         "--conv-impl")
    ap.add_argument("--conv-impl", default="im2col",
                    choices=["im2col", "direct"])
    ap.add_argument("--scheduler", default="bucket",
                    choices=["bucket", "continuous"],
                    help="bucket: pad-to-rung ladder (DESIGN.md §7); "
                         "continuous: ragged coalescing over tile-"
                         "padded extent classes with admission control "
                         "and SLO-aware wait (DESIGN.md §9)")
    ap.add_argument("--buckets", type=lambda s: tuple(
        int(b) for b in s.split(",")), default=None,
        help="bucket scheduler: comma-separated batch-size ladder "
             "(default: 1,4,8 for smoke, 1,8,32,128 for sustained)")
    ap.add_argument("--max-rows", type=int, default=None,
                    help="continuous scheduler: per-dispatch row budget "
                         "(default: 8 for smoke, 32 for sustained)")
    ap.add_argument("--max-queue-rows", type=int, default=None,
                    help="continuous scheduler: admission-control bound "
                         "on queued rows (default: unbounded)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency SLO: arms goodput accounting on both "
                         "schedulers and the continuous scheduler's "
                         "SLO-aware max-wait")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="micro-batcher head-of-line latency bound")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (DESIGN.md §11): past "
                         "it a request completes as DeadlineExceeded "
                         "instead of being served late (default: none)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="re-dispatches of a failed batch before its "
                         "requests complete as RequestFailed")
    ap.add_argument("--fallback", default="off", choices=["on", "off"],
                    help="'on' arms the bit-identical engine demotion "
                         "ladder (SERVE_FALLBACKS) on repeated kernel "
                         "failure")
    ap.add_argument("--blocks", default="auto", choices=["auto", "tuned"],
                    help="'tuned': use the serving config persisted in "
                         "the autotune cache (benchmarks/serving.py "
                         "writes it); 'auto': per-shape resolution")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--sustained", action="store_true",
                      help="open-loop load run")
    mode.add_argument("--smoke", action="store_true",
                      help="short burst + logits verification (default)")
    ap.add_argument("--requests", type=int, default=12,
                    help="smoke: number of requests in the burst")
    ap.add_argument("--rate", type=float, default=10.0,
                    help="sustained: request arrivals per second")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="sustained: seconds of traffic")
    ap.add_argument("--max-images", type=int, default=8,
                    help="images per request ~ U{1..max}")
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh-sharded serving (DESIGN.md §10): shard "
                         "every dispatch data-parallel over N devices "
                         "(weights replicated). Off-TPU forces N "
                         "simulated host devices via XLA_FLAGS")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _force_host_devices(args.devices)
    if args.buckets is None:
        # Smoke keeps the ladder small so warmup + the per-request
        # exact-shape verification forwards stay CI-cheap.
        args.buckets = DEFAULT_BUCKETS if args.sustained else (1, 4, 8)
    if args.max_rows is None:
        args.max_rows = 32 if args.sustained else 8
    if args.sustained:
        run_sustained(args)
    else:
        run_smoke(args)


if __name__ == "__main__":
    main()
