import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above must precede any jax import)
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell against the production mesh, with NO real hardware and NO
allocation (ShapeDtypeStruct stand-ins end to end).

For each cell this prints/records:
  * memory_analysis()  — proves the program fits per-device HBM,
  * cost_analysis()    — per-device FLOPs/bytes for the roofline,
  * the collective schedule parsed from the compiled HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ASSIGNED,
    SHAPES,
    cell_applicable,
    float_policy,
    get_config,
    serve_policy,
    train_policy,
)
from repro.distributed import sharding as shard_rules
from repro.launch.mesh import MULTI_POD, SINGLE_POD, make_production_mesh
from repro.models.model_factory import build_model
from repro.roofline import analysis as roofline
from repro.train.step import TrainConfig, init_opt_state, make_train_step


def _policy_for(kind: str, name: str):
    if name == "float":
        return float_policy()
    if name == "auto":
        return train_policy() if kind == "train" else serve_policy()
    if name == "train":
        return train_policy()
    return serve_policy()


def build_cell(arch: str, shape_name: str, *, policy_name: str = "auto",
               train_cfg: TrainConfig | None = None,
               cache_dtype=None):
    """Returns (step_fn, example_args (SDS), donate, model_flops, meta)."""
    import jax.numpy as _jnp

    cache_dtype = cache_dtype or _jnp.bfloat16
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    policy = _policy_for(shape.kind, policy_name)
    model = build_model(cfg, policy)

    key = jax.random.PRNGKey(0)
    float_params = jax.eval_shape(model.init, key)
    n_total = roofline.count_params(float_params)
    frac = (cfg.experts_per_token / cfg.num_experts
            if cfg.num_experts else None)
    n_active = roofline.count_params(float_params, active_moe_fraction=frac)
    model_flops = roofline.model_flops_for(cfg, shape, n_total, n_active)
    batch = model.input_specs(shape)
    meta = {"n_params": n_total, "n_active": n_active, "cfg": cfg,
            "shape": shape}

    if shape.kind == "train":
        step = make_train_step(model, train_cfg or TrainConfig())
        opt = jax.eval_shape(init_opt_state, float_params)
        return step, (float_params, opt, batch), (0, 1), model_flops, meta

    packed = (jax.eval_shape(model.pack, float_params)
              if model.policy.packed else float_params)
    state = jax.eval_shape(
        functools.partial(model.init_state, shape.global_batch,
                          shape.seq_len, dtype=cache_dtype)
    )
    if shape.kind == "prefill":
        def step(params, st, b):
            return model.prefill(params, st, b)
        return step, (packed, state, batch), (1,), model_flops, meta

    def step(params, st, b):
        return model.decode_step(params, st, b)
    return step, (packed, state, batch), (1,), model_flops, meta


def shardings_for(mesh, args, kind: str):
    p, s_or_o, batch = args
    p_sh = shard_rules.params_shardings(mesh, p)
    b_sh = shard_rules.batch_shardings(mesh, batch)
    if kind == "train":
        o_sh = shard_rules.params_shardings(mesh, s_or_o)  # mirrors params
        # adam count scalar -> replicated
        return (p_sh, o_sh, b_sh)
    st_sh = shard_rules.state_shardings(mesh, s_or_o)
    return (p_sh, st_sh, b_sh)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             policy_name: str = "auto", out_dir: str | None = None,
             train_cfg: TrainConfig | None = None, verbose: bool = True,
             tag: str = "", cache_dtype=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "policy": policy_name, "tag": tag}
    if not ok:
        result.update(status="skipped", reason=reason)
        _emit(result, out_dir, verbose)
        return result

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    t0 = time.time()
    try:
        step, args, donate, model_flops, _ = build_cell(
            arch, shape_name, policy_name=policy_name, train_cfg=train_cfg,
            cache_dtype=cache_dtype,
        )
        in_sh = shardings_for(mesh, args, shape.kind)
        with mesh, shard_rules.activation_mesh(mesh):
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_stats = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_stats[attr] = int(v)

        rf = roofline.from_compiled(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
            chips=chips, model_flops=model_flops,
            memory_stats={"temp_bytes": mem_stats.get("temp_size_in_bytes")},
        )
        result.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory_analysis=mem_stats,
            roofline=rf.to_dict(),
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    _emit(result, out_dir, verbose)
    return result


def _emit(result: dict, out_dir: str | None, verbose: bool):
    if verbose:
        status = result["status"]
        line = f"[{status:7s}] {result['arch']:24s} {result['shape']:12s} " \
               f"{result['mesh']}"
        if status == "ok":
            rf = result["roofline"]
            line += (f"  compute={rf['compute_s']:.4f}s"
                     f" memory={rf['memory_s']:.4f}s"
                     f" coll={rf['collective_s']:.4f}s"
                     f" bottleneck={rf['bottleneck']}"
                     f" (compile {result['compile_s']}s)")
        elif status == "skipped":
            line += f"  ({result['reason']})"
        else:
            line += f"  {result['error']}"
        print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = result.get("tag") or ""
        suffix = f"_{tag}" if tag else ""
        fname = (f"{result['arch']}_{result['shape']}_{result['mesh']}"
                 f"{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--policy", choices=["auto", "float", "train", "packed"],
                    default="auto")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="suffix for output JSONs")
    ap.add_argument("--cache-dtype", default="bf16",
                    choices=["bf16", "int8", "f32"],
                    help="KV-cache storage dtype (int8 = quantized cache)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    args = ap.parse_args()
    import jax.numpy as _jnp
    cache_dtype = {"bf16": _jnp.bfloat16, "int8": _jnp.int8,
                   "f32": _jnp.float32}[args.cache_dtype]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for mesh_name in meshes:
        for arch, shape_name in cells:
            tc = (TrainConfig(microbatches=args.microbatches)
                  if args.microbatches > 1 else None)
            r = run_cell(arch, shape_name, mesh_name,
                         policy_name=args.policy, out_dir=args.out,
                         tag=args.tag, cache_dtype=cache_dtype,
                         train_cfg=tc)
            failures += r["status"] == "error"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
