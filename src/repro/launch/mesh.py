"""Mesh construction: the transformer dry-run's production meshes
(DESIGN.md §5) and the packed-BNN serving mesh (DESIGN.md §10).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 placeholder
hosts).
"""

from __future__ import annotations

import math

import jax
import numpy as np

# Both meshes carry the full (pod, data, model) axis-name set so one
# sharding-rule table serves both; single-pod just has pod=1.
SINGLE_POD = (1, 16, 16)              # 256 chips
MULTI_POD = (2, 16, 16)               # 512 chips


def make_serving_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D data-parallel mesh for the packed-BNN serving stack.

    Unlike :func:`make_production_mesh` there is no 256-chip assumption:
    the serving mesh is ``("data",)`` over the first ``n_devices``
    devices (default: all of them), because the packed model is tiny
    (~1.75 MB — XNOR-Net's 32x memory saving) and is REPLICATED on every
    device; only the batch shards. The forward is then collective-free:
    each device runs the whole network on its batch slice (DESIGN.md
    §10).

    Simulated scale-out uses forced host devices exactly like the
    dry-run path: set ``XLA_FLAGS=--xla_force_host_platform_device_
    count=N`` BEFORE the first jax backend touch (``tests/conftest.py``
    and ``benchmarks/scaling.py`` both do).
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"serving mesh needs >= 1 device, got {n}")
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the serving mesh, have {len(devices)}"
            " — simulated scale-out must set XLA_FLAGS=--xla_force_"
            f"host_platform_device_count={n} before any jax device use"
        )
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        # real fleet: ICI-adjacency-aware assignment
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    # dry-run: 512 placeholder hosts, single-pod uses the first 256
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
