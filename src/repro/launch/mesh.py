"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 placeholder
hosts).
"""

from __future__ import annotations

import math

import jax
import numpy as np

# Both meshes carry the full (pod, data, model) axis-name set so one
# sharding-rule table serves both; single-pod just has pod=1.
SINGLE_POD = (1, 16, 16)              # 256 chips
MULTI_POD = (2, 16, 16)               # 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        # real fleet: ICI-adjacency-aware assignment
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    # dry-run: 512 placeholder hosts, single-pod uses the first 256
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
