"""Serving driver: pack a trained checkpoint to 1-bit (paper §3.1) and
decode batched requests with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config, serve_policy, float_policy
from repro.models.model_factory import build_model


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, quantized: bool = True,
          seed: int = 0, greedy: bool = True,
          cache_dtype=jnp.float32) -> dict:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    policy = serve_policy() if quantized else float_policy()
    model = build_model(cfg, policy)

    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    if quantized:
        params = model.pack(params)   # float -> packed 1-bit weights

    max_len = prompt_len + gen
    state = model.init_state(batch, max_len, dtype=cache_dtype)
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    batch_in = {"tokens": prompts}
    if cfg.input_kind == "embeddings":
        batch_in = {"input_embeds": jax.random.normal(
            key, (batch, prompt_len, cfg.d_model))}
        if cfg.family == "encdec":
            batch_in["tokens"] = prompts

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, state = prefill(params, state, batch_in)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tokens]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, state = decode(params, state, {"tokens": tokens})
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    return {
        "tokens": out,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--float", dest="quantized", action="store_false")
    ap.add_argument("--cache-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="KV-cache storage dtype (int8 halves the "
                         "decode-dominant cache reads, EXPERIMENTS §Perf)")
    args = ap.parse_args()
    cache_dtype = {"f32": jnp.float32, "bf16": jnp.bfloat16,
                   "int8": jnp.int8}[args.cache_dtype]
    r = serve(args.arch, smoke=args.smoke, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen,
              quantized=args.quantized, cache_dtype=cache_dtype)
    print("generated shape", r["tokens"].shape)
    print(f"prefill {r['prefill_s']:.2f}s  decode {r['decode_s']:.2f}s  "
          f"{r['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
