"""End-to-end training driver.

Runs on whatever devices exist: a single CPU (smoke configs, used by
examples/ and tests) or a real fleet (full configs under the production
mesh). Wires together every substrate layer: synthetic data pipeline,
quantization-aware model, AdamW + clip + schedule, sharded+checksummed
async checkpointing with auto-resume, and the fault-tolerance monitor.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs import get_config, smoke_config, train_policy, float_policy
from repro.data.pipeline import DataConfig, Prefetcher, synthetic_lm_batches
from repro.distributed import sharding as shard_rules
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    make_elastic_mesh,
)
from repro.models.model_factory import build_model
from repro.train.step import TrainConfig, init_opt_state, make_train_step


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    microbatches: int = 1,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    quantized: bool = True,
    log_every: int = 10,
    seed: int = 0,
) -> dict:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    policy = train_policy() if quantized else float_policy()
    model = build_model(cfg, policy)

    devices = jax.devices()
    mesh = make_elastic_mesh(devices, model_parallel=min(len(devices), 16)) \
        if len(devices) > 1 else None

    dcfg = DataConfig(seed=seed, global_batch=batch, seq_len=seq,
                      vocab_size=cfg.vocab_size)
    data = Prefetcher(synthetic_lm_batches(dcfg))

    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = init_opt_state(params)
    tcfg = TrainConfig(microbatches=microbatches)
    tcfg = TrainConfig(
        adamw=type(tcfg.adamw)(lr=lr, weight_decay=0.01, latent_clip=quantized),
        microbatches=microbatches,
    )
    step_fn = make_train_step(model, tcfg)

    start_step = 0
    writer = None
    if ckpt_dir:
        writer = ckpt.AsyncCheckpointer(ckpt_dir)
        latest = ckpt.latest_valid_step(ckpt_dir)
        if latest is not None:
            state = ckpt.restore(ckpt_dir, latest,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"resumed from step {latest}")

    if mesh is not None:
        p_sh = shard_rules.params_shardings(mesh, params)
        o_sh = shard_rules.params_shardings(mesh, opt_state)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        # Pin outputs to the input shardings: params/opt feed back into
        # the next step (donated), and an unconstrained compiler choice
        # for an output leaf would mismatch in_shardings on step 2.
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        ctx = shard_rules.activation_mesh(mesh)
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        import contextlib
        ctx = contextlib.nullcontext()

    monitor = HeartbeatMonitor(num_hosts=1, timeout=3600.0)
    straggler = StragglerDetector()
    metrics = {}
    losses = []
    with ctx:
        for step, b in zip(range(start_step, steps), data):
            t0 = time.time()
            monitor.beat(0)
            monitor.check()
            batch_arrays = {"tokens": b["tokens"], "labels": b["labels"]}
            if cfg.input_kind == "embeddings":
                # modality stub: derive embeddings deterministically
                tok = np.asarray(b["tokens"])
                rng = np.random.default_rng(tok[0, 0] if tok.size else 0)
                emb = rng.normal(0, 1, (*tok.shape, cfg.d_model)).astype(
                    np.float32)
                batch_arrays["input_embeds"] = jnp.asarray(emb)
            params, opt_state, metrics = jitted(params, opt_state,
                                                batch_arrays)
            dt = time.time() - t0
            straggler.observe({0: dt})
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  {dt:.2f}s",
                      flush=True)
            if writer and (step + 1) % ckpt_every == 0:
                writer.save(step + 1, {"params": params, "opt": opt_state})
    if writer:
        writer.close()
    return {"params": params, "losses": losses, "final_metrics": metrics}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--float", dest="quantized", action="store_false")
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, lr=args.lr,
                microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                quantized=args.quantized)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    print(f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
