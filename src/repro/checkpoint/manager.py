"""Sharded, checksummed, async checkpointing with auto-resume.

Layout per step:

  <dir>/step_000100/
    tree.json            # pytree structure + per-leaf shape/dtype
    shard_00000.npz      # leaves (one file per host in multi-host runs)
    MANIFEST.json        # per-file sha256 + leaf index; written LAST

A checkpoint is valid iff MANIFEST.json exists and every checksum
matches — a process killed mid-write leaves no MANIFEST, so
``latest_valid_step`` silently skips it (torn-write safety, the
restart half of fault tolerance). ``AsyncCheckpointer`` moves the
serialization off the training thread and overlaps it with compute;
``restore`` reshards to *whatever mesh is current* because leaves are
read as plain numpy and re-placed with ``jax.device_put`` under the
caller's shardings — this is what makes elastic restarts
(distributed/fault_tolerance.py) a pure restore-path feature.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(directory: str, step: int, tree, *, host_id: int = 0) -> str:
    """Blocking save. Returns the checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    tmp = ckpt + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _leaf_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [
            {"key": k, "shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
            for k, v in leaves
        ],
    }
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(meta, f)

    shard = os.path.join(tmp, f"shard_{host_id:05d}.npz")
    np.savez(shard, **{k: np.asarray(v) for k, v in leaves})

    manifest = {
        "step": step,
        "files": {
            name: _sha256(os.path.join(tmp, name))
            for name in os.listdir(tmp)
            if name != "MANIFEST.json"
        },
    }
    # manifest written last + atomic rename => torn writes are invisible
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    return ckpt


def is_valid(ckpt: str) -> bool:
    mpath = os.path.join(ckpt, "MANIFEST.json")
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for name, digest in manifest["files"].items():
            if _sha256(os.path.join(ckpt, name)) != digest:
                return False
        return True
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def _step_entries(directory: str) -> list[tuple[int, str]]:
    """``(step, dirname)`` for every conforming ``step_<digits>`` entry,
    sorted by step. Non-conforming names (``step_abc``, editor leftovers,
    ``.tmp`` staging dirs) are silently skipped — a stray file in the
    checkpoint directory must never be able to crash ``latest_valid_step``
    or ``retain`` (they run inside the recovery path)."""
    out = []
    for name in os.listdir(directory):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        tail = name[len("step_"):]
        if not tail.isdigit():
            continue
        out.append((int(tail), name))
    return sorted(out)


def latest_valid_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    for step, name in reversed(_step_entries(directory)):
        if is_valid(os.path.join(directory, name)):
            return step
    return None


def restore(directory: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-places each
    leaf on the *current* mesh — elastic resharding for free."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    data: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(ckpt)):
        if name.startswith("shard_"):
            with np.load(os.path.join(ckpt, name)) as z:
                data.update({k: z[k] for k in z.files})

    keys = [k for k, _ in _leaf_paths(like)]
    missing = [k for k in keys if k not in data]
    if missing:
        unexpected = [k for k in sorted(data) if k not in set(keys)]
        raise ValueError(
            f"checkpoint {ckpt} does not match the restore structure: "
            f"missing leaf keys {missing}; unexpected leaf keys in the "
            f"checkpoint {unexpected}. Pass a `like` tree with the same "
            f"structure the checkpoint was saved with (keys are "
            f"path-joined, e.g. 'params/conv/0/w')."
        )
    leaves = [data[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, s: jax.device_put(leaf, s), tree, shardings
        )
    return tree


def retain(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    for _, name in _step_entries(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


class AsyncCheckpointer:
    """Serializes checkpoints on a background thread.

    ``save`` enqueues a host-side snapshot (jax.device_get, the only
    synchronous part) and returns; the writer thread does npz + sha256.
    ``wait()`` drains the queue (call before exit / before restore).
    """

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._errors: list[BaseException] = []
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree = item
            try:
                save(self.directory, step, tree)
                retain(self.directory, self.keep)
            except BaseException as e:  # surfaced by wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, tree) -> None:
        if self._closed:
            # The writer thread has exited; an enqueued snapshot would sit
            # in the queue forever — silent checkpoint loss. Fail loudly.
            raise RuntimeError(
                "AsyncCheckpointer.save() after close(): the writer "
                "thread has exited and this snapshot would never be "
                "written. Create a new AsyncCheckpointer (or call save() "
                "before close())."
            )
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._q.join()
        if self._errors:
            raise self._errors[0]
