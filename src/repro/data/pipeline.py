"""Deterministic, shardable synthetic data pipeline.

Offline container => no real CIFAR-10/corpora. The pipeline still has the
production shape: stateless index-based batch generation (any step's
batch is reproducible from (seed, step) alone — a restart resumes
mid-epoch with zero drift), per-host sharding for multi-host meshes, and
a background prefetcher.

Synthetic tasks are *learnable* (class-conditional image means; Zipf
token stream with induced bigram structure) so examples show loss
actually decreasing.
"""

from __future__ import annotations

import dataclasses
import functools
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 32
    seq_len: int = 512
    vocab_size: int = 32000
    num_classes: int = 10
    image_size: int = 32
    num_hosts: int = 1
    host_id: int = 0


def host_shard_slice(cfg: DataConfig) -> tuple[int, int]:
    """[start, size) of the global batch owned by this host."""
    if cfg.global_batch % cfg.num_hosts:
        raise ValueError(
            f"global_batch {cfg.global_batch} not divisible by "
            f"{cfg.num_hosts} hosts"
        )
    per_host = cfg.global_batch // cfg.num_hosts
    return cfg.host_id * per_host, per_host


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # Stateless: (seed, step) fully determines the batch on every host.
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step])
    )


@functools.lru_cache(maxsize=8)
def cifar_class_means(cfg: DataConfig) -> np.ndarray:
    """The per-class image means — a pure function of ``cfg.seed``."""
    rng0 = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xC1FA]))
    return rng0.normal(
        0.0, 1.0, (cfg.num_classes, cfg.image_size, cfg.image_size, 3)
    ).astype(np.float32)


def cifar_batch_at(cfg: DataConfig, step: int) -> dict:
    """The synthetic-CIFAR batch for ``step`` — random access into the
    stateless stream. ``synthetic_cifar_batches`` yields exactly
    ``cifar_batch_at(cfg, 0), cifar_batch_at(cfg, 1), ...``, so a
    rollback/replay driver (train/resilience.py) can re-fetch any
    step's batch bit-identically without holding an iterator."""
    start, per_host = host_shard_slice(cfg)
    class_means = cifar_class_means(cfg)
    rng = _batch_rng(cfg, step)
    labels = rng.integers(0, cfg.num_classes, cfg.global_batch)
    noise = rng.normal(
        0.0, 1.0, (cfg.global_batch, cfg.image_size, cfg.image_size, 3)
    ).astype(np.float32)
    images = class_means[labels] * 0.8 + noise
    sl = slice(start, start + per_host)
    return {
        "images": jnp.asarray(images[sl]),
        "labels": jnp.asarray(labels[sl].astype(np.int32)),
        "step": step,
    }


def synthetic_cifar_batches(cfg: DataConfig) -> Iterator[dict]:
    """Class-conditional Gaussian images — learnable 10-way problem."""
    step = 0
    while True:
        yield cifar_batch_at(cfg, step)
        step += 1


def synthetic_lm_batches(cfg: DataConfig) -> Iterator[dict]:
    """Zipf unigram + deterministic successor structure: next-token
    prediction has learnable signal (P(next = (tok*7+1) % V) boosted)."""
    start, per_host = host_shard_slice(cfg)
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    step = 0
    while True:
        rng = _batch_rng(cfg, step)
        base = rng.choice(
            cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1), p=probs
        )
        # overwrite ~half the positions with the deterministic successor
        succ = (base[:, :-1] * 7 + 1) % cfg.vocab_size
        mask = rng.random((cfg.global_batch, cfg.seq_len)) < 0.5
        base[:, 1:][mask] = succ[mask]
        tokens = base.astype(np.int32)
        sl = slice(start, start + per_host)
        yield {
            "tokens": jnp.asarray(tokens[sl, :-1]),
            "labels": jnp.asarray(tokens[sl, 1:]),
            "step": step,
        }
        step += 1


class Prefetcher:
    """Background-thread prefetch: overlaps host-side batch synthesis /
    IO with device compute (the standard input-pipeline trick)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
