from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    synthetic_cifar_batches,
    synthetic_lm_batches,
    host_shard_slice,
)
