"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM is linear attention with a matrix memory ``C [dk, dv]`` and
*exponential* input gating. Training/prefill run the chunkwise-parallel
form (intra-chunk quadratic + inter-chunk recurrence, the same shape as
chunked GLA) so nothing quadratic in the full sequence is materialized;
decode runs the O(1) recurrence — which is why this arch owns the
``long_500k`` cell. All exponentials are max-stabilized; the stabilizer
``m`` is carried across chunks.

sLSTM has scalar memory and a true sequential recurrence (R·h_{t-1});
it runs as ``lax.scan`` over time with the input-side projections
hoisted out (those are the binarizable bulk).

Projections (q/k/v/up/down/gates-from-input) are ``*_proj`` ->
binarizable; recurrent R matrices and norms stay real (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import Params, QuantPolicy, init_proj, proj, rmsnorm

# --------------------------------- mLSTM -------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = 2 * d
    h = cfg.num_heads
    ks = jax.random.split(key, 7)
    return {
        "up_proj": init_proj(ks[0], d, 2 * di),
        "q_proj": init_proj(ks[1], di, di),
        "k_proj": init_proj(ks[2], di, di),
        "v_proj": init_proj(ks[3], di, di),
        "if_proj": init_proj(ks[4], di, 2 * h, bias=True),  # i, f pre-acts
        "down_proj": init_proj(ks[5], di, d),
        "gn_scale": jnp.ones((di,), jnp.float32),
    }


def _mlstm_chunk(carry, xs):
    """One chunk of the stabilized mLSTM recurrence.

    carry: C [B,H,dk,dv], n [B,H,dk], m [B,H]
    xs: q,k,v [B,L,H,dk|dv], logf/logi [B,L,H]
    """
    C, n, m = carry
    q, k, v, logi, logf = xs
    # the whole chunk body is tile-resident in the TPU chunked-linear-
    # attention kernel; the roofline classifies this scope's traffic as
    # VMEM-fusible (roofline/hlo_cost.py)
    with jax.named_scope("vmem_fusible"):
        b_cum = jnp.cumsum(logf, axis=1)               # [B,L,H] inclusive
        g = logi - b_cum                               # exp-gate in b-units
        M = lax.cummax(g, axis=1)                      # running max_{j<=t} g_j
        m_loc = jnp.maximum(M, m[:, None])             # [B,L,H]
        inter_scale = jnp.exp(m[:, None] - m_loc)      # <= 1
        # intra-chunk weights: S[t,j] = exp(b_t - b_j + i_j - (b_t + m_loc_t))
        #                             = exp(g_j - m_loc_t), masked j <= t
        # index order: [B, t, j, H]
        w_intra = jnp.exp(g[:, None, :, :] - m_loc[:, :, None, :])
        lmask = jnp.tril(jnp.ones((q.shape[1], q.shape[1]), bool))
        w_intra = jnp.where(lmask[None, :, :, None], w_intra, 0.0)

        qk = jnp.einsum("bthd,bjhd->btjh", q, k)       # [B,t,j,H]
        num_intra = jnp.einsum("btjh,btjh,bjhv->bthv", qk, w_intra, v)
        den_intra = jnp.einsum("btjh,btjh->bth", qk, w_intra)
        num_inter = jnp.einsum("bthd,bhdv->bthv", q, C) * inter_scale[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", q, n) * inter_scale

        num = num_intra + num_inter
        den = den_intra + den_inter
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # advance carry to chunk end: new stabilizer m' = b_L + max(M_L, m)
        bL = b_cum[:, -1]                              # [B,H]
        m_loc_L = jnp.maximum(M[:, -1], m)
        m_new = bL + m_loc_L
        wk = jnp.exp(g - m_loc_L[:, None])             # per-j key weight
        decay = jnp.exp(m - m_loc_L)                   # [B,H]
        C_new = decay[..., None, None] * C \
            + jnp.einsum("bjhd,bjh,bjhv->bhdv", k, wk, v)
        n_new = decay[..., None] * n + jnp.einsum("bjhd,bjh->bhd", k, wk)
    return (C_new, n_new, m_new), y


def mlstm_cell(q, k, v, logi, logf, state, *, chunk: int = 256):
    """q,k,v: [B,S,H,dh]; logi/logf: [B,S,H]. Returns (y, new_state)."""
    b, s, h, dh = q.shape
    q = q * dh ** -0.5
    if state is None:
        C = jnp.zeros((b, h, dh, dh), jnp.float32)
        n = jnp.zeros((b, h, dh), jnp.float32)
        m = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C, n, m = state["C"], state["n"], state["m"]

    if s == 1:  # decode recurrence
        li, lf = logi[:, 0], logf[:, 0]
        m_new = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
            "bhd,bhv->bhdv", k[:, 0], v[:, 0]
        )
        n = f_s[..., None] * n + i_s[..., None] * k[:, 0]
        num = jnp.einsum("bhd,bhdv->bhv", q[:, 0], C)
        den = jnp.einsum("bhd,bhd->bh", q[:, 0], n)
        y = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None])[:, None]
        return y, {"C": C, "n": n, "m": m_new}

    c = min(chunk, s)
    assert s % c == 0, (s, c)

    if jax.default_backend() == "tpu" and state is None:
        # native path: Pallas chunkwise kernel (VMEM-resident C/n/m)
        from repro.kernels.mlstm_chunk import mlstm_chunked

        fq = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh).astype(jnp.float32)
        fk = k.transpose(0, 2, 1, 3).reshape(b * h, s, dh).astype(jnp.float32)
        fv = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh).astype(jnp.float32)
        fi = logi.transpose(0, 2, 1).reshape(b * h, s)
        ff = logf.transpose(0, 2, 1).reshape(b * h, s)
        y, Ck, nk, mk = mlstm_chunked(fq, fk, fv, fi, ff, chunk=c)
        y = y.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
        return y, {
            "C": Ck.reshape(b, h, dh, dh),
            "n": nk.reshape(b, h, dh),
            "m": mk.reshape(b, h),
        }

    def chunked(t):
        return t.reshape(b, s // c, c, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(chunked(t) for t in
               (q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), logi, logf))
    (C, n, m), ys = lax.scan(_mlstm_chunk, (C, n, m), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, h, dh)
    return y, {"C": C, "n": n, "m": m}


def mlstm_block(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                policy: QuantPolicy, *, state: Optional[dict] = None,
                ) -> tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    h = cfg.num_heads
    di = 2 * d
    dh = di // h
    xz = proj(params["up_proj"], x, policy)
    xm, z = jnp.split(xz, 2, axis=-1)

    q = proj(params["q_proj"], xm, policy).reshape(b, s, h, dh)
    k = proj(params["k_proj"], xm, policy).reshape(b, s, h, dh)
    v = proj(params["v_proj"], xm, policy).reshape(b, s, h, dh)
    gates = proj(params["if_proj"], xm, policy).astype(jnp.float32)
    logi, f_pre = jnp.split(gates.reshape(b, s, 2, h), 2, axis=2)
    logi = logi[:, :, 0]
    logf = jax.nn.log_sigmoid(f_pre[:, :, 0])

    y, new_state = mlstm_cell(q, k, v, logi, logf, state)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm({"scale": params["gn_scale"]}, y)      # per-cell group norm
    y = y * jax.nn.silu(z)
    # training (no streaming state in) must not emit state — the period
    # scan would stack per-layer C matrices as ys for nothing
    if state is None:
        new_state = None
    return proj(params["down_proj"], y, policy), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int, *, layers: int) -> dict:
    h = cfg.num_heads
    dh = 2 * cfg.d_model // h
    return {
        "C": jnp.zeros((layers, batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((layers, batch, h, dh), jnp.float32),
        "m": jnp.full((layers, batch, h), -1e30, jnp.float32),
    }


# --------------------------------- sLSTM -------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    dff = int(d * 4 / 3 / 64) * 64 * 2  # gated ffn, proj factor 4/3
    return {
        # input-side projections for the 4 gates (binarizable bulk)
        "gates_proj": init_proj(ks[0], d, 4 * d, bias=True),
        # recurrent block-diagonal weights per head, per gate (stay real)
        "R": jax.random.normal(ks[1], (4, h, dh, dh)) * dh ** -0.5,
        "up_proj": init_proj(ks[2], d, dff),
        "down_proj": init_proj(ks[3], dff // 2, d),
        "gn_scale": jnp.ones((d,), jnp.float32),
    }


def _slstm_step(carry, xs, *, R, h_heads, dh):
    hprev, c, n, m = carry            # h: [B,d], c/n: [B,d], m: [B,d]
    wx = xs                           # [B, 4d] precomputed input projections
    b = hprev.shape[0]
    hh = hprev.reshape(b, h_heads, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hh, R).reshape(b, 4, h_heads * dh)
    pre = wx.reshape(b, 4, -1) + rec
    zi, ii, fi, oi = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logi = ii
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, logi)
    i_s = jnp.exp(logi - m_new)
    f_s = jnp.exp(logf + m - m_new)
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    c_new = f_s * c + i_s * zt
    n_new = f_s * n + i_s
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_block(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                policy: QuantPolicy, *, state: Optional[dict] = None,
                ) -> tuple[jnp.ndarray, Optional[dict]]:
    import functools

    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = proj(params["gates_proj"], x, policy).astype(jnp.float32)  # [B,S,4d]

    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.full((b, d), -1e30, jnp.float32))
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    step = functools.partial(_slstm_step, R=params["R"], h_heads=h, dh=dh)
    (hT, cT, nT, mT), ys = lax.scan(step, carry, wx.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).astype(x.dtype)                 # [B,S,d]
    y = rmsnorm({"scale": params["gn_scale"]}, y)

    up = proj(params["up_proj"], y, policy)
    a, g = jnp.split(up, 2, axis=-1)
    y = proj(params["down_proj"], a * jax.nn.silu(g), policy)
    new_state = (None if state is None
                 else {"h": hT, "c": cT, "n": nT, "m": mT})
    return y, new_state


def init_slstm_state(cfg: ModelConfig, batch: int, *, layers: int) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((layers, batch, d), jnp.float32),
        "c": jnp.zeros((layers, batch, d), jnp.float32),
        "n": jnp.zeros((layers, batch, d), jnp.float32),
        "m": jnp.full((layers, batch, d), -1e30, jnp.float32),
    }
