"""Model zoo. ``build_model`` is re-exported lazily to avoid the
configs<->models import cycle (configs.base needs models.common)."""

from repro.models.common import QuantPolicy  # noqa: F401


def __getattr__(name):
    if name == "build_model":
        from repro.models.model_factory import build_model

        return build_model
    raise AttributeError(name)
