"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a stub per the assignment: ``input_specs`` feeds
precomputed frame embeddings ``[B, S_enc, D]`` to the encoder. The
decoder is a standard causal stack with cross-attention into the encoder
memory; its self-attention KV cache follows the same layout as the
decoder-only models. LayerNorm (not RMS) per the original architecture.

All projections are binarizable ``*_proj`` modules (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shard_rules
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models.common import (
    Params,
    QuantPolicy,
    embed,
    init_embedding,
    init_layernorm,
    layernorm,
    softmax_cross_entropy,
)


def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_layernorm(cfg.d_model),
        "attn": attn_mod.init_attention(k1, cfg),
        "norm2": init_layernorm(cfg.d_model),
        "ffn": ffn_mod.init_dense_ffn(k2, cfg.d_model, cfg.d_ff, cfg.act),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_layernorm(cfg.d_model),
        "self_attn": attn_mod.init_attention(k1, cfg),
        "norm2": init_layernorm(cfg.d_model),
        "cross_attn": attn_mod.init_attention(k2, cfg),
        "norm3": init_layernorm(cfg.d_model),
        "ffn": ffn_mod.init_dense_ffn(k3, cfg.d_model, cfg.d_ff, cfg.act),
    }


def init_encdec_params(key, cfg: ModelConfig) -> Params:
    ke, kd, kemb, khead = jax.random.split(key, 4)
    enc_layers = jax.vmap(lambda k: _init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.encoder_layers)
    )
    dec_layers = jax.vmap(lambda k: _init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.num_layers)
    )
    return {
        "encoder": {"layers": enc_layers, "final_norm": init_layernorm(cfg.d_model)},
        "decoder": {"layers": dec_layers, "final_norm": init_layernorm(cfg.d_model)},
        "embed": init_embedding(kemb, cfg.padded_vocab, cfg.d_model),
        "lm_head": {
            "w": (jax.random.normal(khead, (cfg.padded_vocab, cfg.d_model))
                  * cfg.d_model ** -0.5)
        },
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig,
           policy: QuantPolicy, *, remat: bool = False) -> jnp.ndarray:
    """frames: [B, S_enc, D] (audio frontend stub output) -> memory."""
    s = frames.shape[1]
    positions = jnp.arange(s)

    def body(x, lp):
        x = shard_rules.constrain_seq(x)
        h = layernorm(lp["norm1"], x)
        out, _ = attn_mod.attention(
            lp["attn"], h, cfg, policy, positions=positions, causal=False
        )
        x = x + out
        h = layernorm(lp["norm2"], x)
        x = x + ffn_mod.dense_ffn(lp["ffn"], h, policy, cfg.act)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, frames.astype(cfg.dtype), params["encoder"]["layers"])
    return layernorm(params["encoder"]["final_norm"], x)


def decode(params: Params, tokens: jnp.ndarray, memory: jnp.ndarray,
           cfg: ModelConfig, policy: QuantPolicy, *,
           state: Optional[dict] = None, remat: bool = False):
    """tokens [B, S]; memory [B, S_enc, D]. Returns (logits, new_state)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens, dtype=cfg.dtype)
    index = state["index"] if state is not None else jnp.zeros((), jnp.int32)
    positions = index + jnp.arange(s)

    def body(carry, xs):
        x, = carry
        x = shard_rules.constrain_seq(x)
        lp, lstate = xs
        h = layernorm(lp["norm1"], x)
        cache = None
        if lstate is not None:
            cache = {"k": lstate["k"], "v": lstate["v"], "index": index}
        out, new_cache = attn_mod.attention(
            lp["self_attn"], h, cfg, policy, positions=positions, cache=cache
        )
        x = x + out
        h = layernorm(lp["norm2"], x)
        x = x + attn_mod.cross_attention(lp["cross_attn"], h, memory, cfg, policy)
        h = layernorm(lp["norm3"], x)
        x = x + ffn_mod.dense_ffn(lp["ffn"], h, policy, cfg.act)
        new_state = (None if new_cache is None
                     else {"k": new_cache["k"], "v": new_cache["v"]})
        return (x,), new_state

    if remat:
        body = jax.checkpoint(body)

    if state is None:
        (x,), _ = lax.scan(
            lambda c, lp: body(c, (lp, None)), (x,), params["decoder"]["layers"]
        )
        new_state = None
    else:
        kv = {"k": state["kv"]["k"], "v": state["kv"]["v"]}
        (x,), ys = lax.scan(body, (x,), (params["decoder"]["layers"], kv))
        new_state = {"kv": ys, "index": index + s}

    x = layernorm(params["decoder"]["final_norm"], x)
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32),
        params["lm_head"]["w"].astype(jnp.float32),
    )
    logits = shard_rules.constrain(
        logits, shard_rules.DATA_AXES, None, shard_rules.MODEL_AXIS
    )
    return logits, new_state


def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    c = attn_mod.init_cache(cfg, batch, max_len, layers=cfg.num_layers,
                            dtype=dtype)
    return {"kv": {"k": c["k"], "v": c["v"]},
            "index": jnp.zeros((), jnp.int32)}


def encdec_loss(params, batch: dict, cfg: ModelConfig, policy: QuantPolicy,
                *, remat: bool = True):
    memory = encode(params, batch["input_embeds"], cfg, policy, remat=remat)
    logits, _ = decode(params, batch["tokens"], memory, cfg, policy,
                       remat=remat)
    loss = softmax_cross_entropy(logits[..., : cfg.vocab_size], batch["labels"])
    return loss, {"loss": loss}


def decode_step(params, cfg: ModelConfig, policy: QuantPolicy, *,
                state: dict, memory: jnp.ndarray, tokens: jnp.ndarray):
    logits, state = decode(params, tokens, memory, cfg, policy, state=state)
    return logits[:, -1, : cfg.vocab_size], state
