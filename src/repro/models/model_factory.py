"""Unified model API: ``build_model(cfg)`` -> a ``Model`` bundle of
pure functions (init / loss / prefill / decode_step / init_state /
input_specs). The launcher, dry-run, trainer, server, benchmarks, and
tests all go through this one entry point, so every architecture is
selectable with ``--arch <id>`` and every step function has a single
canonical signature:

  loss(params, batch)                 -> (scalar, metrics)    [train]
  prefill(params, state, batch)       -> (last_logits, state) [inference]
  decode_step(params, state, batch)   -> (logits, state)      [inference]

``input_specs(shape)`` returns ShapeDtypeStruct stand-ins for every
input (weak-type-correct, shardable, no allocation) — the multi-pod
dry-run lowers against exactly these.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.common import QuantPolicy, pack_projection_tree

Params = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    policy: QuantPolicy
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, dict], tuple[jnp.ndarray, dict]]
    prefill: Callable[[Params, dict, dict], tuple[jnp.ndarray, dict]]
    decode_step: Callable[[Params, dict, dict], tuple[jnp.ndarray, dict]]
    init_state: Callable[..., dict]
    input_specs: Callable[[ShapeConfig], dict]

    def pack(self, params: Params) -> Params:
        """Trained float params -> 1-bit packed serving params (§3.1)."""
        return pack_projection_tree(params, use_scale=self.policy.use_scale)


def _lm_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        if cfg.input_kind == "embeddings":
            return {
                "input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        s = shape.seq_len
        if cfg.input_kind == "embeddings":
            return {"input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _encdec_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        return {
            "input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "memory": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype),
    }


def build_model(cfg: ModelConfig, policy: QuantPolicy) -> Model:
    if cfg.family == "encdec":
        def loss(params, batch):
            return encdec_mod.encdec_loss(params, batch, cfg, policy)

        def prefill(params, state, batch):
            memory = encdec_mod.encode(params, batch["input_embeds"], cfg, policy)
            logits, state = encdec_mod.decode(
                params, batch["tokens"], memory, cfg, policy, state=state
            )
            return logits[:, -1, : cfg.vocab_size], dict(state, memory=memory)

        def decode_step(params, state, batch):
            memory = state.get("memory", batch.get("memory"))
            st = {"kv": state["kv"], "index": state["index"]}
            logits, st = encdec_mod.decode(
                params, batch["tokens"], memory, cfg, policy, state=st
            )
            out = dict(st)
            if "memory" in state:
                out["memory"] = memory
            return logits[:, -1, : cfg.vocab_size], out

        return Model(
            cfg=cfg, policy=policy,
            init=lambda key: encdec_mod.init_encdec_params(key, cfg),
            loss=loss, prefill=prefill, decode_step=decode_step,
            init_state=functools.partial(encdec_mod.init_state, cfg),
            input_specs=functools.partial(_encdec_input_specs, cfg),
        )

    def loss(params, batch):
        return tf_mod.lm_loss(params, batch, cfg, policy)

    def prefill(params, state, batch):
        return tf_mod.prefill(
            params, cfg, policy, state=state,
            tokens=batch.get("tokens"), input_embeds=batch.get("input_embeds"),
        )

    def decode_step(params, state, batch):
        return tf_mod.decode_step(
            params, cfg, policy, state=state, tokens=batch["tokens"]
        )

    return Model(
        cfg=cfg, policy=policy,
        init=lambda key: tf_mod.init_lm_params(key, cfg),
        loss=loss, prefill=prefill, decode_step=decode_step,
        init_state=functools.partial(tf_mod.init_state, cfg),
        input_specs=functools.partial(_lm_input_specs, cfg),
    )
