"""Mamba (S6 selective SSM) block — the non-attention layer of jamba.

Chunked selective scan: ``lax.scan`` over sequence chunks carrying the
recurrent state ``[B, d_inner, d_state]``; inside a chunk the recurrence
runs as an associative scan. This bounds the materialized state tensor
to ``[B, chunk, d_inner, d_state]`` (the naive full-sequence associative
scan would be ~1 TB for jamba's train_4k cell) while keeping the
parallel-scan FLOPs profile.

Projections (``in_proj/x_proj/dt_proj/out_proj``) are binarizable; the
SSM dynamics params (A_log, D, conv) stay real — they are tiny and
numerically sensitive (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import Params, QuantPolicy, init_proj, proj


def _dt_rank(cfg: ModelConfig) -> int:
    return -(-cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": init_proj(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, di)) * 0.1,
        "conv_b": jnp.zeros((di,)),
        "x_proj": init_proj(ks[2], di, r + 2 * ds),
        "dt_proj": init_proj(ks[3], r, di, bias=True),
        "out_proj": init_proj(ks[4], di, d),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B, S, di]; w: [K, di].

    Returns (y, new_state) where state is the last K-1 inputs
    ([B, K-1, di]) for streaming decode.
    """
    k = w.shape[0]
    if state is None:
        hist = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        hist[:, i : i + x.shape[1], :] * w[i] for i in range(k)
    ) + b
    new_state = hist[:, -(k - 1):, :]
    return y.astype(x.dtype), new_state


def _selective_scan_chunk(carry, xs):
    """Associative scan within one chunk; carry: h [B, di, ds].

    Emits the chunk's *outputs* y = C·h (not the states) so the live
    footprint per step is [B, C, di, ds] and the stacked result is only
    [B, S, di].
    """
    dt, xh, bmat, cmat, a = xs  # [B,C,di], [B,C,di], [B,C,ds], [B,C,ds], [di,ds]
    if jax.default_backend() == "tpu":
        # native path: Pallas selective-scan kernel (VMEM-resident state)
        from repro.kernels.ssm_scan import ssm_scan_chunk

        y, h_last = ssm_scan_chunk(dt, xh, bmat, cmat, a, carry)
        return h_last, y
    # XLA fallback: associative scan; its [B, C, di, ds] state tensor is
    # tile-resident in the kernel above (see roofline/hlo_cost.py)
    with jax.named_scope("vmem_fusible"):
        da = jnp.exp(dt[..., None] * a)                   # [B, C, di, ds]
        dbx = (dt * xh)[..., None] * bmat[:, :, None, :]

        def combine(u, v):
            (a1, b1), (a2, b2) = u, v
            return a1 * a2, b1 * a2 + b2

        da_s, dbx_s = lax.associative_scan(combine, (da, dbx), axis=1)
        h = carry[:, None] * da_s + dbx_s      # [B, C, di, ds]
        y = jnp.einsum("bcdn,bcn->bcd", h, cmat)
    return h[:, -1], y


def mamba(params: Params, x: jnp.ndarray, cfg: ModelConfig, policy: QuantPolicy,
          *, state: Optional[dict] = None, chunk: int = 256
          ) -> tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, D] -> (y [B, S, D], new streaming state).

    ``state = {"h": [B, di, ds], "conv": [B, K-1, di]}`` for decode.
    """
    b, s, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = proj(params["in_proj"], x, policy)
    xh, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xh, new_conv = _causal_conv(xh, params["conv_w"], params["conv_b"], conv_state)
    xh = jax.nn.silu(xh)

    bcdt = proj(params["x_proj"], xh, policy).astype(jnp.float32)
    r = _dt_rank(cfg)
    dt_in, bmat, cmat = jnp.split(bcdt, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(
        proj(params["dt_proj"], dt_in.astype(x.dtype), policy).astype(jnp.float32)
    )                                               # [B, S, di]
    a = -jnp.exp(params["A_log"])                   # [di, ds]
    xh32 = xh.astype(jnp.float32)

    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, di, ds), jnp.float32))

    if s == 1:  # decode fast path: one recurrence step
        da = jnp.exp(dt[:, 0, :, None] * a)
        dbx = (dt[:, 0] * xh32[:, 0])[..., None] * bmat[:, 0, None, :]
        h_last = h0 * da + dbx
        y = jnp.einsum("bdn,bn->bd", h_last, cmat[:, 0])[:, None]
    else:
        c = min(chunk, s)
        assert s % c == 0, (s, c)

        def chunked(t, width):
            return t.reshape(b, s // c, c, width).swapaxes(0, 1)

        xs = (chunked(dt, di), chunked(xh32, di),
              chunked(bmat, ds), chunked(cmat, ds),
              jnp.broadcast_to(a, (s // c, di, ds)))
        h_last, ys = lax.scan(_selective_scan_chunk, h0, xs)
        y = ys.swapaxes(0, 1).reshape(b, s, di)

    y = y + xh.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = proj(params["out_proj"], y, policy)

    new_state = {"h": h_last, "conv": new_conv} if state is not None else None
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, *, layers: int) -> dict:
    return {
        "h": jnp.zeros((layers, batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((layers, batch, cfg.conv_width - 1, cfg.d_inner),
                          jnp.float32),
    }
