"""GQA attention with RoPE, KV cache, sliding window — quantization-aware.

All four projections (``q_proj/k_proj/v_proj/o_proj``) go through
:func:`repro.models.common.proj`, so under a PACKED policy they run the
paper's 1-bit packed-weight contraction (DESIGN.md §4). KV cache layout
is ``[B, S, Hkv, Dh]`` per layer (stacked ``[L, B, S, Hkv, Dh]`` by the
model), sharded batch->data and heads/seq->model by the launcher.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, QuantPolicy, apply_rope, init_proj, proj


def init_attention(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "q_proj": init_proj(ks[0], d, cfg.q_dim, bias=cfg.qkv_bias),
        "k_proj": init_proj(ks[1], d, cfg.kv_dim, bias=cfg.qkv_bias),
        "v_proj": init_proj(ks[2], d, cfg.kv_dim, bias=cfg.qkv_bias),
        "o_proj": init_proj(ks[3], cfg.q_dim, d, bias=False),
    }


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, Hkv, Dh] -> [B, S, Hkv*groups, Dh] (GQA head expansion).

    Only used by the (test-oracle) dense reference path; the production
    paths use grouped einsums that never materialize the repeat — the
    12x-replicated KV read was the dominant decode HBM term
    (EXPERIMENTS.md §Perf, mistral decode hillclimb)."""
    if groups == 1:
        return x
    b, s, h, dh = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, h, groups, dh)
    ).reshape(b, s, h * groups, dh)


# Above this many score elements per (q, kv) pair, switch to the
# flash-style chunked path so [Sq, Skv] score matrices are never
# materialized (32k prefill would otherwise need TBs of activations).
_DENSE_SCORE_LIMIT = 2048 * 2048

# int8 KV-cache quantization (beyond-paper bandwidth optimization in the
# same spirit as the paper's weight packing: decode is KV-read-bound, so
# halving cache bytes halves the dominant roofline term). Fixed-scale
# symmetric quantization — RoPE'd keys/values are O(1) by construction.
_KV_INT8_SCALE = 24.0


def _cache_quantize(x, cache_dtype):
    if cache_dtype == jnp.int8:
        return jnp.clip(
            jnp.round(x.astype(jnp.float32) * _KV_INT8_SCALE), -127, 127
        ).astype(jnp.int8)
    return x.astype(cache_dtype)


def _cache_dequantize(x, out_dtype):
    if x.dtype == jnp.int8:
        return (x.astype(out_dtype) * (1.0 / _KV_INT8_SCALE)).astype(out_dtype)
    return x.astype(out_dtype)


def _mask_for(q_pos, kv_pos, *, causal, sliding_window, kv_valid):
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if sliding_window:
        mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    return mask


def _attend_chunked(
    q, k, v, *, groups, causal, q_positions, kv_positions, kv_valid,
    sliding_window, q_chunk: int = 512, kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax GQA attention: outer map over query chunks, inner
    scan over KV chunks carrying (acc, row-max, row-sum). KV stays at
    kv-head width (grouped einsums — never materialize the GQA repeat);
    peak live score tensor is [B, Hkv, G, q_chunk, kv_chunk]."""
    b, sq, h, dh = q.shape
    hkv = h // groups
    skv = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    assert sq % qc == 0 and skv % kc == 0, (sq, qc, skv, kc)
    scale = dh ** -0.5
    if kv_valid is None:
        kv_valid = jnp.ones((skv,), bool)

    kb = k.reshape(b, skv // kc, kc, hkv, dh).swapaxes(0, 1)
    vb = v.reshape(b, skv // kc, kc, hkv, dh).swapaxes(0, 1)
    kpos_b = kv_positions.reshape(skv // kc, kc)
    kval_b = kv_valid.reshape(skv // kc, kc)

    def one_q_chunk(args):
        qi, qpos = args                              # [B, qc, H, Dh], [qc]
        q5 = qi.reshape(b, qc, hkv, groups, dh)

        # the whole online-softmax inner loop is tile-resident in the
        # Pallas flash-attention kernel on TPU; the roofline classifies
        # this scope's traffic as VMEM-fusible (roofline/hlo_cost.py)
        def kv_step(carry, xs):
            acc, mx, den = carry
            kj, vj, kpos, kval = xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kj,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask_for(qpos, kpos, causal=causal,
                            sliding_window=sliding_window, kv_valid=kval)
            s = jnp.where(msk[None, None, None], s, -1e30)
            mx_new = jnp.maximum(mx, jnp.max(s, -1))
            corr = jnp.exp(mx - mx_new)
            p = jnp.exp(s - mx_new[..., None])
            den_new = den * corr + jnp.sum(p, -1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qi.dtype), vj
            ).astype(jnp.float32)
            return (acc_new, mx_new, den_new), None

        acc0 = jnp.zeros((b, hkv, groups, qc, dh), jnp.float32)
        mx0 = jnp.full((b, hkv, groups, qc), -jnp.inf, jnp.float32)
        den0 = jnp.zeros((b, hkv, groups, qc), jnp.float32)
        with jax.named_scope("vmem_fusible"):
            (acc, _, den), _ = jax.lax.scan(
                kv_step, (acc0, mx0, den0), (kb, vb, kpos_b, kval_b)
            )
            out = acc / jnp.maximum(den, 1e-30)[..., None]
        # [B, Hkv, G, qc, Dh] -> [B, qc, H, Dh]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, dh).astype(
            qi.dtype)

    qb = q.reshape(b, sq // qc, qc, h, dh).swapaxes(0, 1)
    qpos_b = q_positions.reshape(sq // qc, qc)
    outs = jax.lax.map(one_q_chunk, (qb, qpos_b))     # [nq, B, qc, H, Dh]
    return outs.swapaxes(0, 1).reshape(b, sq, h, dh)


def _attend(
    q: jnp.ndarray,           # [B, Sq, H, Dh]
    k: jnp.ndarray,           # [B, Skv, Hkv, Dh]  (kv-head width!)
    v: jnp.ndarray,           # [B, Skv, Hkv, Dh]
    *,
    groups: int = 1,          # H / Hkv
    causal: bool,
    q_positions: jnp.ndarray,     # [Sq] absolute positions of the queries
    kv_positions: jnp.ndarray,    # [Skv]
    kv_valid: Optional[jnp.ndarray] = None,   # [Skv] bool (cache fill mask)
    sliding_window: int = 0,
) -> jnp.ndarray:
    b, sq, h, dh = q.shape
    hkv = h // groups
    if sq * k.shape[1] > _DENSE_SCORE_LIMIT:
        if (
            jax.default_backend() == "tpu"
            and causal and not sliding_window and kv_valid is None
            and sq == k.shape[1]
        ):
            # native path: Pallas flash-attention kernel (VMEM tiles)
            from repro.kernels.flash_attention import flash_attention

            fq = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
            fk = _repeat_kv(k, groups).transpose(0, 2, 1, 3).reshape(
                b * h, sq, dh)
            fv = _repeat_kv(v, groups).transpose(0, 2, 1, 3).reshape(
                b * h, sq, dh)
            out = flash_attention(fq, fk, fv, causal=True)
            return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
        return _attend_chunked(
            q, k, v, groups=groups, causal=causal, q_positions=q_positions,
            kv_positions=kv_positions, kv_valid=kv_valid,
            sliding_window=sliding_window,
        )
    # dense path — grouped einsums, the GQA repeat is never materialized
    q5 = q.reshape(b, sq, hkv, groups, dh)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q5, k, preferred_element_type=jnp.float32
    ) * (dh ** -0.5)

    mask = jnp.ones(scores.shape[-2:], bool)
    if causal:
        mask &= kv_positions[None, :] <= q_positions[:, None]
    if sliding_window:
        mask &= kv_positions[None, :] > q_positions[:, None] - sliding_window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, dh)


def attention(
    params: Params,
    x: jnp.ndarray,                     # [B, S, D]
    cfg: ModelConfig,
    policy: QuantPolicy,
    *,
    positions: jnp.ndarray,             # [S] absolute positions
    cache: Optional[dict] = None,       # {"k","v": [B, Smax, Hkv, Dh], "index": int}
    causal: bool = True,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """Returns (output [B, S, D], updated cache)."""
    b, s, _ = x.shape
    q = proj(params["q_proj"], x, policy).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = proj(params["k_proj"], x, policy).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = proj(params["v_proj"], x, policy).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)

    q = apply_rope(q, positions[None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, :], cfg.rope_theta)

    new_cache = None
    if cache is not None:
        idx = cache["index"]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], _cache_quantize(k, cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], _cache_quantize(v, cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv, "index": idx + s}
        smax = ck.shape[1]
        kv_positions = jnp.arange(smax)
        kv_valid = kv_positions < idx + s
        k_full = _cache_dequantize(ck, q.dtype)
        v_full = _cache_dequantize(cv, q.dtype)
    else:
        kv_positions = positions
        kv_valid = None
        k_full, v_full = k, v

    out = _attend(
        q, k_full, v_full,
        groups=cfg.num_heads // cfg.num_kv_heads,
        causal=causal,
        q_positions=positions,
        kv_positions=kv_positions,
        kv_valid=kv_valid,
        sliding_window=cfg.sliding_window,
    )
    out = out.reshape(b, s, cfg.q_dim)
    return proj(params["o_proj"], out, policy), new_cache


def cross_attention(
    params: Params,
    x: jnp.ndarray,                 # [B, Sq, D] decoder states
    memory: jnp.ndarray,            # [B, Skv, D] encoder output
    cfg: ModelConfig,
    policy: QuantPolicy,
) -> jnp.ndarray:
    """Enc-dec cross attention (seamless decoder). No RoPE on cross-keys."""
    b, sq, _ = x.shape
    skv = memory.shape[1]
    q = proj(params["q_proj"], x, policy).reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k = proj(params["k_proj"], memory, policy).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = proj(params["v_proj"], memory, policy).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    out = _attend(
        q, k, v,
        groups=cfg.num_heads // cfg.num_kv_heads,
        causal=False,
        q_positions=jnp.arange(sq),
        kv_positions=jnp.arange(skv),
    )
    return proj(params["o_proj"], out.reshape(b, sq, cfg.q_dim), policy)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, layers: Optional[int] = None,
               dtype=jnp.bfloat16) -> dict:
    """Stacked per-layer KV cache. ``index`` is a scalar write cursor."""
    layers = cfg.num_layers if layers is None else layers
    shape = (layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "index": jnp.zeros((), jnp.int32),
    }
