"""Decoder-only LM assembly for all assigned architectures.

Layer stacks are *periodic*: each arch defines a short repeating pattern
of (mixer, ffn) layer kinds (dense: 1-layer period; jamba: 8-layer
period of 7 mamba + 1 attention with MoE every other layer; xlstm:
1 sLSTM + 7 mLSTM; ...). Parameters are stacked per period and the
forward pass is ``lax.scan`` over periods — so the compiled HLO contains
ONE period body regardless of depth (72-layer jamba compiles the same
8-layer body 9x cheaper), which is what makes the 512-device dry-run
tractable and keeps roofline terms per-layer x L.

Streaming state (KV cache / SSM state / xLSTM cells) is stacked with a
leading per-kind layer axis, reshaped to [periods, per_period, ...] and
threaded through the scan as xs/ys.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shard_rules
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (
    Params,
    QuantPolicy,
    embed,
    init_embedding,
    init_proj,
    init_rmsnorm,
    layernorm,
    init_layernorm,
    rmsnorm,
    softmax_cross_entropy,
)

# ------------------------------ period spec ----------------------------------


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str        # attn | mamba | mlstm | slstm
    ffn: str          # dense | moe | moe+dense | none


def period_spec(cfg: ModelConfig) -> list[LayerKind]:
    if cfg.family == "hybrid":
        period = []
        for i in range(cfg.attn_every):
            mixer = "attn" if cfg.is_attention_layer(i) else "mamba"
            ffn = "moe" if cfg.is_moe_layer(i) else "dense"
            period.append(LayerKind(mixer, ffn))
        return period
    if cfg.family == "ssm":
        return [
            LayerKind("slstm" if cfg.is_slstm_layer(i) else "mlstm", "none")
            for i in range(cfg.slstm_every)
        ]
    ffn = "moe" if cfg.num_experts else "dense"
    if cfg.dense_residual_ff:
        ffn = "moe+dense"
    return [LayerKind("attn", ffn)]


def num_periods(cfg: ModelConfig) -> int:
    p = len(period_spec(cfg))
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return cfg.num_layers // p


# ------------------------------ layer init -----------------------------------


def _norm_fns(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return init_layernorm, layernorm
    return init_rmsnorm, rmsnorm


def _init_layer(key, cfg: ModelConfig, kind: LayerKind) -> Params:
    init_norm, _ = _norm_fns(cfg)
    keys = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg.d_model)}
    if kind.mixer == "attn":
        p["attn"] = attn_mod.init_attention(keys[0], cfg)
    elif kind.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(keys[0], cfg)
    elif kind.mixer == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(keys[0], cfg)
    elif kind.mixer == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(keys[0], cfg)
    if kind.ffn != "none":
        p["norm2"] = init_norm(cfg.d_model)
        if "moe" in kind.ffn:
            p["moe"] = ffn_mod.init_moe(keys[1], cfg)
        if kind.ffn == "dense" or kind.ffn == "moe+dense":
            width = cfg.dense_residual_ff or cfg.d_ff
            p["ffn"] = ffn_mod.init_dense_ffn(keys[2], cfg.d_model, width, cfg.act)
    return p


def init_lm_params(key, cfg: ModelConfig) -> Params:
    period = period_spec(cfg)
    np_ = num_periods(cfg)
    init_norm, _ = _norm_fns(cfg)
    kemb, khead, kstack = jax.random.split(key, 3)

    def init_period(k):
        ks = jax.random.split(k, len(period))
        return [
            _init_layer(ks[i], cfg, kind) for i, kind in enumerate(period)
        ]

    stacked = jax.vmap(init_period)(jax.random.split(kstack, np_))
    params: Params = {
        "layers": stacked,
        "final_norm": init_norm(cfg.d_model),
    }
    if cfg.input_kind == "tokens":
        params["embed"] = init_embedding(kemb, cfg.padded_vocab, cfg.d_model)
    else:
        # modality stub: inputs are precomputed frame/patch embeddings
        params["in_norm"] = init_norm(cfg.d_model)
        params["embed"] = init_embedding(kemb, cfg.padded_vocab, cfg.d_model)
    if not cfg.tie_embeddings:
        # LM head stays real-valued (DESIGN.md §4) — plain param, not *_proj
        params["lm_head"] = {
            "w": (jax.random.normal(khead, (cfg.padded_vocab, cfg.d_model))
                  * cfg.d_model ** -0.5)
        }
    return params


# ------------------------------ streaming state -------------------------------


def _kind_counts(cfg: ModelConfig) -> dict[str, int]:
    period = period_spec(cfg)
    np_ = num_periods(cfg)
    out: dict[str, int] = {}
    for k in period:
        out[k.mixer] = out.get(k.mixer, 0) + np_
    return out


def init_state(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """All streaming state for decode: per-mixer-kind stacked arrays."""
    counts = _kind_counts(cfg)
    st: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if "attn" in counts:
        c = attn_mod.init_cache(cfg, batch, max_len, layers=counts["attn"],
                                dtype=dtype)
        st["kv"] = {"k": c["k"], "v": c["v"]}
    if "mamba" in counts:
        st["mamba"] = mamba_mod.init_mamba_state(cfg, batch, layers=counts["mamba"])
    if "mlstm" in counts:
        st["mlstm"] = xlstm_mod.init_mlstm_state(cfg, batch, layers=counts["mlstm"])
    if "slstm" in counts:
        st["slstm"] = xlstm_mod.init_slstm_state(cfg, batch, layers=counts["slstm"])
    return st


# Streaming state is threaded through the scan as xs (per-period slices
# in) / ys (updated slices out): scan's own stacking machinery double-
# buffers them with clean aliasing. The carry-held alternative (full
# stack in the carry + dynamic-index read / dynamic-update write) was
# tried and REFUTED: XLA copy-insertion cannot prove the in-iteration
# read and write of the same buffer don't conflict and inserts two full
# cache-stack copies per layer (2x520 GB/step for mistral decode_32k —
# EXPERIMENTS.md §Perf, hc2).


def _split_state_for_scan(cfg: ModelConfig, st: Optional[dict]):
    """[L_kind, ...] arrays -> [periods, per_period_kind, ...] scan xs."""
    if st is None:
        return None
    np_ = num_periods(cfg)

    def resh(t):
        return t.reshape(np_, t.shape[0] // np_, *t.shape[1:])

    out = {}
    for k, v in st.items():
        if k == "index":
            continue
        out[k] = jax.tree.map(resh, v)
    return out


def _merge_state_from_scan(st: dict, ys: dict, new_index) -> dict:
    def unresh(t):
        return t.reshape(t.shape[0] * t.shape[1], *t.shape[2:])

    out = {"index": new_index}
    for k, v in ys.items():
        out[k] = jax.tree.map(unresh, v)
    return out


# ------------------------------ forward --------------------------------------


def _apply_layer(x, lp: Params, cfg: ModelConfig, policy: QuantPolicy,
                 kind: LayerKind, *, positions, layer_state, causal=True):
    """One residual block. Returns (x, new_layer_state, aux_loss)."""
    _, norm = _norm_fns(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = norm(lp["norm1"], x)
    new_state = layer_state
    if kind.mixer == "attn":
        cache = None
        if layer_state is not None:
            cache = {"k": layer_state["k"], "v": layer_state["v"],
                     "index": layer_state["index"]}
        out, new_cache = attn_mod.attention(
            lp["attn"], h, cfg, policy, positions=positions, cache=cache,
            causal=causal,
        )
        if new_cache is not None:
            new_state = {"k": new_cache["k"], "v": new_cache["v"],
                         "index": layer_state["index"]}
    elif kind.mixer == "mamba":
        out, new_state = mamba_mod.mamba(lp["mamba"], h, cfg, policy,
                                         state=layer_state)
    elif kind.mixer == "mlstm":
        out, new_state = xlstm_mod.mlstm_block(lp["mlstm"], h, cfg, policy,
                                               state=layer_state)
    elif kind.mixer == "slstm":
        out, new_state = xlstm_mod.slstm_block(lp["slstm"], h, cfg, policy,
                                               state=layer_state)
    else:
        raise ValueError(kind.mixer)
    # name the POST-collective block outputs: the remat policy saves
    # exactly these, so the backward pass neither re-runs the forward
    # all-reduces nor stashes every wide dot output (§Perf, mistral
    # train hillclimb)
    out = checkpoint_name(out, "mixer_out")
    x = x + out

    if kind.ffn != "none":
        h = norm(lp["norm2"], x)
        y = jnp.zeros_like(x)
        if "moe" in kind.ffn:
            mo, aux = ffn_mod.moe_ffn(lp["moe"], h, cfg, policy, cfg.act)
            y = y + mo
        if kind.ffn in ("dense", "moe+dense"):
            y = y + ffn_mod.dense_ffn(lp["ffn"], h, policy, cfg.act)
        y = checkpoint_name(y, "ffn_out")
        x = x + y
    return x, new_state, aux


def _kind_per_period(cfg: ModelConfig) -> dict[str, int]:
    out: dict[str, int] = {}
    for k in period_spec(cfg):
        out[k.mixer] = out.get(k.mixer, 0) + 1
    return out


def _period_fn(cfg: ModelConfig, policy: QuantPolicy, *, causal=True,
               remat=False):
    period = period_spec(cfg)
    per_period = _kind_per_period(cfg)

    def body(carry, xs):
        x, positions, index = carry
        x = shard_rules.constrain_seq(x)   # residual layout (no-op w/o mesh)
        pparams, pstate = xs
        new_states: dict[str, list] = {}
        kind_cursor: dict[str, int] = {}
        aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(period):
            lstate = None
            key = "kv" if kind.mixer == "attn" else kind.mixer
            if pstate is not None and key in pstate:
                j = kind_cursor.get(kind.mixer, 0)
                kind_cursor[kind.mixer] = j + 1
                lstate = jax.tree.map(lambda t: t[j], pstate[key])
                if kind.mixer == "attn":
                    lstate = dict(lstate, index=index)
            x, lstate_new, aux = _apply_layer(
                x, pparams[i], cfg, policy, kind,
                positions=positions, layer_state=lstate, causal=causal,
            )
            aux_total = aux_total + aux
            if lstate_new is not None:
                if kind.mixer == "attn":
                    lstate_new = {"k": lstate_new["k"], "v": lstate_new["v"]}
                new_states.setdefault(key, []).append(lstate_new)
        ys_state = {
            k: jax.tree.map(lambda *ts: jnp.stack(ts), *v)
            for k, v in new_states.items()
        }
        return (x, positions, index), (ys_state, aux_total)

    if remat:
        # dots-saveable beats save-only-block-outputs: saving the post-
        # collective block outputs did NOT remove the backward AR replay
        # (the mixer's internals are recomputed anyway) and cost +19%
        # compute (§Perf hc5, refuted); activation CAPACITY is handled
        # by microbatching instead.
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return body


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    policy: QuantPolicy,
    *,
    tokens: Optional[jnp.ndarray] = None,        # [B, S] int32
    input_embeds: Optional[jnp.ndarray] = None,  # [B, S, D] (vlm/audio stub)
    state: Optional[dict] = None,                # streaming state (decode)
    remat: bool = False,
    causal: bool = True,
    logits_last_only: bool = False,              # prefill: skip S-1 logits
) -> tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (logits [B, S, V], new_state, aux_loss)."""
    _, norm = _norm_fns(cfg)
    if input_embeds is not None:
        x = norm(params["in_norm"], input_embeds.astype(cfg.dtype)) \
            if "in_norm" in params else input_embeds.astype(cfg.dtype)
        s = input_embeds.shape[1]
    else:
        x = embed(params["embed"], tokens, dtype=cfg.dtype)
        s = tokens.shape[1]

    index = state["index"] if state is not None else jnp.zeros((), jnp.int32)
    positions = index + jnp.arange(s)

    body = _period_fn(cfg, policy, causal=causal, remat=remat)
    np_ = num_periods(cfg)
    xs_state = _split_state_for_scan(cfg, state)
    if xs_state is None:
        def no_state_body(c, p):
            c, (_, aux) = body(c, (p, None))
            return c, (None, aux)

        (x, _, _), (_, auxs) = lax.scan(
            no_state_body, (x, positions, index), params["layers"],
            length=np_,
        )
        new_state = None
    else:
        (x, _, _), (ys_state, auxs) = lax.scan(
            body, (x, positions, index), (params["layers"], xs_state),
            length=np_,
        )
        new_state = _merge_state_from_scan(state, ys_state, index + s)

    if logits_last_only:
        x = x[:, -1:]
    x = norm(params["final_norm"], x)
    head = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), head.astype(jnp.float32)
    )
    logits = shard_rules.constrain(
        logits, shard_rules.DATA_AXES, None, shard_rules.MODEL_AXIS
    )
    return logits, new_state, jnp.sum(auxs)


# ------------------------------ entry points ---------------------------------


def lm_loss(params, batch: dict, cfg: ModelConfig, policy: QuantPolicy,
            *, remat: bool = True, aux_weight: float = 0.01):
    logits, _, aux = lm_forward(
        params, cfg, policy,
        tokens=batch.get("tokens"),
        input_embeds=batch.get("input_embeds"),
        remat=remat,
    )
    loss = softmax_cross_entropy(logits[..., : cfg.vocab_size], batch["labels"])
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def prefill(params, cfg: ModelConfig, policy: QuantPolicy, *, state: dict,
            tokens=None, input_embeds=None):
    """Fill the cache with a prompt; returns (last-token logits, state)."""
    logits, state, _ = lm_forward(
        params, cfg, policy, tokens=tokens, input_embeds=input_embeds,
        state=state, logits_last_only=True,
    )
    return logits[:, -1, : cfg.vocab_size], state


def decode_step(params, cfg: ModelConfig, policy: QuantPolicy, *, state: dict,
                tokens: jnp.ndarray):
    """One serving step: tokens [B, 1] -> (logits [B, V], new state)."""
    logits, state, _ = lm_forward(
        params, cfg, policy, tokens=tokens, state=state,
    )
    return logits[:, -1, : cfg.vocab_size], state
