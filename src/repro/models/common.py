"""Shared model components: norms, RoPE, quantization-aware projections.

Projection params are plain dicts ``{"w": [out, in], ("b": [out])}``;
after :func:`pack_projection_tree` they become ``{"w_packed": int32
[out, in/32], ("alpha", "b")}`` — the paper's §3.1 encoding applied to
every matmul in the network. A projection participates in packing iff
its key ends in ``_proj`` (embeddings, norms, routers, and the LM head
stay real-valued; see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.binarize import QuantMode
from repro.core.layers import BitLinearConfig, bit_linear, pack_linear_params

Params = dict[str, Any]

PROJ_SUFFIX = "_proj"


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """How the paper's technique applies to a whole model."""

    enabled: bool = True
    mode: QuantMode = QuantMode.FAKE_QUANT   # train: FAKE_QUANT; serve: PACKED
    binarize_acts: bool = False              # weight-only for LMs
    use_scale: bool = True                   # XNOR-Net alpha
    engine: str = "xla"                      # SPMD-safe engine

    def layer_cfg(self) -> BitLinearConfig:
        return BitLinearConfig(
            mode=self.mode if self.enabled else QuantMode.FLOAT,
            binarize_acts=self.binarize_acts,
            use_scale=self.use_scale,
            engine=self.engine,
        )

    @property
    def packed(self) -> bool:
        return self.enabled and self.mode == QuantMode.PACKED


def init_proj(key, d_in: int, d_out: int, *, bias: bool = False,
              dtype=jnp.float32) -> Params:
    std = d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_out, d_in)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def proj(params: Params, x: jnp.ndarray, policy: QuantPolicy) -> jnp.ndarray:
    """Quantization-aware y = x @ W^T (+ b)."""
    return bit_linear(params, x, policy.layer_cfg()).astype(x.dtype)


def pack_projection_tree(params, *, use_scale: bool = True):
    """Recursively replace every ``*_proj`` dict with packed params —
    turns a trained checkpoint into a 1-bit serving checkpoint."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if (
                k.endswith(PROJ_SUFFIX)
                and isinstance(v, dict)
                and "w" in v
            ):
                out[k] = pack_linear_params(v, use_scale=use_scale)
            else:
                out[k] = pack_projection_tree(v, use_scale=use_scale)
        return out
    if isinstance(params, (list, tuple)):
        return type(params)(
            pack_projection_tree(v, use_scale=use_scale) for v in params
        )
    return params


# ------------------------------- norms --------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (x32 * inv * p["scale"]).astype(dt)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ------------------------------- RoPE ---------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / theta ** (
        jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------- embeddings -----------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * d**-0.5).astype(dtype)}


def embed(p: Params, ids: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0).astype(dtype)


def logits_from_embedding(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(
        x.astype(jnp.float32), p["table"].astype(jnp.float32).T
    )


# ------------------------------ losses --------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits [..., V] fp32, labels [...] int. Mean loss."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    return -jnp.mean(ll)
