"""FFN blocks: dense (SwiGLU / GeLU) and mixture-of-experts.

MoE is GShard-style top-k with capacity, formulated as a *batched GEMM
over experts* so it lowers to one fused SPMD region:

  router -> top_k -> (sort-free) capacity assignment via cumsum-of-onehot
  -> gather tokens into [E, C, D] -> einsum against stacked expert
  weights [E, ...] -> weighted scatter-add back.

With experts sharded over the 'model' mesh axis and tokens over 'data',
the gather/scatter lower to the all-to-all dispatch/combine pattern the
roofline's collective term reads. Dropped tokens (over capacity) pass
through the residual only — standard GShard semantics.

Every expert / dense matmul is a ``*_proj`` -> binarizable (paper's
technique applied to the FFN bulk, DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shard_rules
from repro.models.common import Params, QuantPolicy, init_proj, proj

# --------------------------------- dense ------------------------------------


def init_dense_ffn(key, d_model: int, d_ff: int, act: str) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "up_proj": init_proj(ks[0], d_model, d_ff),
        "down_proj": init_proj(ks[1], d_ff, d_model),
    }
    if act == "swiglu":
        p["gate_proj"] = init_proj(ks[2], d_model, d_ff)
    return p


def dense_ffn(params: Params, x: jnp.ndarray, policy: QuantPolicy, act: str) -> jnp.ndarray:
    up = proj(params["up_proj"], x, policy)
    if act == "swiglu":
        gate = proj(params["gate_proj"], x, policy)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return proj(params["down_proj"], h, policy)


# ---------------------------------- MoE -------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, f ** -0.5
    p: Params = {
        # router stays real-valued (DESIGN.md §4: accuracy-critical, tiny)
        "router": {"w": jax.random.normal(ks[0], (e, d), jnp.float32) * std_in},
        # stacked expert weights; *_proj suffix => packable per expert row
        "up_proj": {"w": (jax.random.normal(ks[1], (e, f, d)) * std_in).astype(jnp.float32)},
        "gate_proj": {"w": (jax.random.normal(ks[2], (e, f, d)) * std_in).astype(jnp.float32)},
        "down_proj": {"w": (jax.random.normal(ks[3], (e, d, f)) * std_out).astype(jnp.float32)},
    }
    return p


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * num_tokens * cfg.experts_per_token / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def _expert_matmul(w, x, policy: QuantPolicy):
    """Batched-over-experts contraction. x: [B, E, C, K]; w['w'] or
    packed ['w_packed'] [E, M, K(/32)]. Returns [B, E, C, M]."""
    from repro.core import bitops
    from repro.core.binarize import QuantMode, binarize_weights

    if policy.packed and "w_packed" in w:
        # unpack happens in VMEM in the Pallas kernel (see bitops note)
        with jax.named_scope("vmem_fusible"):
            wv = bitops.unpack_bits(w["w_packed"], axis=-1, dtype=x.dtype)
            wv = wv[..., : x.shape[-1]]
            y = jnp.einsum("beck,emk->becm", x, wv,
                           preferred_element_type=jnp.float32)
        if "alpha" in w:
            y = y * w["alpha"][None, :, None, :]
        return y.astype(x.dtype)
    wv = w["w"]
    if policy.enabled and policy.mode == QuantMode.FAKE_QUANT:
        wq, alpha = binarize_weights(wv, scale_axis=-1 if policy.use_scale else None)
        y = jnp.einsum("beck,emk->becm", x, wq.astype(x.dtype),
                       preferred_element_type=jnp.float32)
        if alpha is not None:
            y = y * alpha[..., 0][None, :, None, :].astype(y.dtype)
        return y.astype(x.dtype)
    return jnp.einsum("beck,emk->becm", x, wv.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe_ffn(params: Params, x: jnp.ndarray, cfg: ModelConfig,
            policy: QuantPolicy, act: str = "swiglu") -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    PER-ROW capacity (GShard group = one sequence): dispatch/combine are
    local to each batch row, so with B sharded over (pod, data) and
    experts over model the expert einsum shards over BOTH axes with no
    partial-sum all-reduce and no redundant compute (§Perf hc7/hc8 —
    the global-capacity formulation forced either a [E,C,d] all-reduce
    per layer or 16x duplicated expert FLOPs). Capacity position is
    computed by sort-based ranking (O(P log P) per row) instead of a
    cumsum over a [P, E] one-hot (O(P*E) memory).

    Static shapes throughout; capacity overflow drops (residual passes).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(cfg, s)                                   # per row
    p = s * k

    logits = jnp.einsum(
        "bsd,ed->bse", x.astype(jnp.float32), params["router"]["w"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # [B, S, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard)
    me = jnp.mean(probs, axis=(0, 1))                         # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = e * jnp.sum(me * ce)

    # rank of each (token, slot) pair within its expert, per row:
    # stable argsort by expert id; rank = sorted position - expert start
    flat_e = expert_idx.reshape(b, p)                          # [B, P]
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_e)                                                # [B, E]
    rank_sorted = jnp.arange(p)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1
    )
    pos_in_e = jnp.zeros((b, p), jnp.int32).at[
        jnp.arange(b)[:, None], sort_idx
    ].set(rank_sorted.astype(jnp.int32))
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)   # [B, P]

    # dispatch (row-local): [B, S, D] pairs -> [B, E, C, D]
    rows = jnp.arange(b)[:, None]
    token_of_pair = jnp.repeat(jnp.arange(s), k)[None, :]      # [1, P]
    x_pairs = jnp.take_along_axis(
        x, jnp.broadcast_to(token_of_pair[..., None], (b, p, 1)), axis=1
    )                                                          # [B, P, D]
    # Sharding note (§Perf hc8-hc10): the dispatch scatter is left
    # UNPINNED. Explicitly pinning xe to (B:data, E:model) makes XLA
    # all-reduce the whole dispatch buffer (522s collective term);
    # pinning the buffer to data + slicing at xe makes the backward
    # pass pathological (714s). Unpinned, XLA replicates the (cheap,
    # bandwidth-light) expert einsum over the data axis — redundant
    # FLOPs, but compute is 80x away from the bottleneck and the
    # collective term drops 126s -> 73s. Chosen on measurement.
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype).at[rows, slot].set(
        x_pairs, mode="drop"
    )
    xe = buf[:, : e * cap].reshape(b, e, cap, d)

    # expert computation (batched GEMM — all binarizable projections)
    up = _expert_matmul(params["up_proj"], xe, policy)
    if act == "swiglu":
        gate = _expert_matmul(params["gate_proj"], xe, policy)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    ye = _expert_matmul(params["down_proj"], h, policy)        # [B, E, C, D]

    # combine (row-local): gather pair outputs, weight by gate, sum over k
    ye_flat = jnp.concatenate(
        [ye.reshape(b, e * cap, d), jnp.zeros((b, 1, d), ye.dtype)], axis=1
    )
    pair_out = jnp.take_along_axis(
        ye_flat, jnp.broadcast_to(slot[..., None], (b, p, 1)), axis=1
    )                                                          # [B, P, D]
    gates = (gate_vals.reshape(b, p) * keep).astype(pair_out.dtype)
    out = jnp.sum((pair_out * gates[..., None]).reshape(b, s, k, d), axis=2)
    return out, aux
