"""Fused binary-layer Pallas kernel: xnor-popcount GEMM with a
BN-fold + sign + repack epilogue (DESIGN.md §4).

Extends ``xnor_gemm``'s tiling: packed int32 operand tiles are staged
HBM->VMEM, ``popcount(~(w ^ x))`` accumulates in a VMEM scratch across
the K grid axis, and on the LAST K step the per-tile epilogue runs
entirely in VMEM:

    dot  = 2*acc - k_bits                     int32   [bm, bn]
    y    = a*dot + b                          float32 [bm, bn]
    bits = (y >= 0)  --shift-add over 32-row groups-->  int32 [bm/32, bn]

``a``/``b`` are per-output-row (= per output channel) affines holding
the folded inference BatchNorm (+ optional bias and XNOR-Net alpha, see
``repro.core.layers.fold_bn_params``). The packed [bm/32, bn] words are
the ONLY thing written back to HBM — the float activation tensor of the
unfused path never exists, and the next binary layer consumes the words
directly (one fewer ``pack_rows`` launch, ~32x less boundary traffic).

The popcount inner loop is BROADCAST-FREE (DESIGN.md §6): a
``lax.fori_loop`` over packed K-word groups accumulates one ``[bm, bn]``
popcount per word — the old ``[bm, bkw, bn]`` xnor intermediate never
exists. ``accum="broadcast"`` keeps the legacy formulation for A/B
benchmarking only.

VMEM budget per step (defaults bm=bn=128, bkw=16):
  w tile   128*16*4       =    8 KiB
  x tile   16*128*4       =    8 KiB
  a, b     128*1*4  x2    =    1 KiB
  xnor     128*128*4      =   64 KiB   (one 2-D word term; was 1024 KiB)
  acc      128*128*4      =   64 KiB
  y        128*128*4      =   64 KiB   (epilogue, last K step only)
  out      4*128*4        =    2 KiB
~211 KiB of ~16 MiB VMEM (was ~1.2 MiB) — the freed budget is what lets
``kernels/autotune.py`` pick much larger tiles with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitops import PACK_BITS
from repro.kernels import pallas_compat
from repro.kernels.popcount import (
    DEFAULT_WORD_GROUP,
    accum_popcount_km,
    sign_repack_m,
)


def _fused_xnor_gemm_kernel(
    w_ref, x_ref, a_ref, b_ref, o_ref, acc_ref, *,
    k_bits: int, nk: int, word_group: int, accum: str,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]  # [bm, bkw] int32 (packed)
    x = x_ref[...]  # [bkw, bn] int32 (packed)
    if accum == "broadcast":
        # Legacy formulation (A/B benchmarking only).
        xnor = ~(w[:, :, None] ^ x[None, :, :])  # [bm, bkw, bn]
        pc = lax.population_count(xnor).astype(jnp.int32)
        acc_ref[...] += jnp.sum(pc, axis=1)
    else:
        acc_ref[...] += accum_popcount_km(w, x, word_group=word_group)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        # ±1 dot product, then the folded-BN affine (same op order as
        # bitops.fused_xnor_layer so the two are bit-exact vs each other).
        dot = (2 * acc_ref[...] - jnp.int32(k_bits)).astype(jnp.float32)
        y = a_ref[...] * dot + b_ref[...]          # [bm, bn] float32
        o_ref[...] = sign_repack_m(y)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_bits", "block_m", "block_n", "block_kw", "word_group", "accum",
        "interpret",
    ),
)
def fused_xnor_gemm(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 16,
    word_group: int = DEFAULT_WORD_GROUP,
    accum: str = "loop",
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed [M, KW] x packed [KW, N] -> PACKED int32 [M/32, N].

    ``a``/``b``: float32 [M, 1] per-row affine. Operands must already be
    padded to tile multiples (see ``repro.kernels.ops.fused_xnor_gemm``
    for the padded wrapper); ``block_m`` must divide by 32 so each tile
    repacks to whole words.
    """
    m, kw = wp.shape
    kw2, n = xp.shape
    assert kw == kw2, (wp.shape, xp.shape)
    assert block_m % PACK_BITS == 0, block_m
    assert m % block_m == 0 and n % block_n == 0 and kw % block_kw == 0
    assert a.shape == (m, 1) and b.shape == (m, 1), (a.shape, b.shape, m)
    assert accum in ("loop", "broadcast"), accum
    nk = kw // block_kw

    kernel = functools.partial(
        _fused_xnor_gemm_kernel, k_bits=k_bits, nk=nk,
        word_group=word_group, accum=accum,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_kw, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_m // PACK_BITS, block_n), lambda i, j, k: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((m // PACK_BITS, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(wp, xp, a.astype(jnp.float32), b.astype(jnp.float32))
