"""Beyond-paper TPU-native binary GEMM: packed weights, MXU contraction.

Insight (DESIGN.md §2): on TPU the durable win of binarization is the
32x weight footprint / HBM-bandwidth reduction, not the instruction
count. So weights travel HBM->VMEM packed (int32 words), are unpacked
to ±1 inside the kernel, and the dot product runs on the MXU at full
systolic throughput against a real-valued (or ±1) activation tile.

This also covers *weight-only* binarization (activations bf16), the
mode the LM configs use for serving.

VMEM per step (bm=128, bn=128, bkw=8 -> bk=256):
  w packed 128*8*4      =   4 KiB
  w unpacked 128*256*4  = 128 KiB
  x tile   256*128*4    = 128 KiB
  acc      128*128*4    =  64 KiB
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.core.bitops import PACK_BITS


def _unpack_gemm_kernel(w_ref, x_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_words = w_ref[...]  # [bm, bkw] int32
    bm, bkw = w_words.shape
    shifts = jnp.arange(PACK_BITS, dtype=jnp.int32)
    bits = (w_words[:, :, None] >> shifts[None, None, :]) & 1  # [bm, bkw, 32]
    w = (2 * bits - 1).reshape(bm, bkw * PACK_BITS).astype(x_ref.dtype)
    # MXU contraction with fp32 accumulation.
    acc_ref[...] += jnp.dot(w, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_kw", "out_dtype", "interpret"),
)
def unpack_gemm(
    wp: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 8,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed weights [M, KW] x real input [KW*32, N] -> [M, N]."""
    m, kw = wp.shape
    k, n = x.shape
    assert k == kw * PACK_BITS, (wp.shape, x.shape)
    assert m % block_m == 0 and n % block_n == 0 and kw % block_kw == 0
    nk = kw // block_kw
    block_k = block_kw * PACK_BITS

    kernel = functools.partial(_unpack_gemm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k_: (i, k_)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k_: (k_, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(wp, x)
