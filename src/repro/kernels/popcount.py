"""Broadcast-free xnor-popcount accumulation (DESIGN.md §6).

The original kernels materialized the full 3-D broadcast
``~(w[:, :, None] ^ x[None, :, :])`` — a ``[bm, bkw, bn]`` int32
intermediate that dominated each grid step's VMEM budget (~85% at the
old 128/128/16 defaults) and capped how large the operand tiles could
grow. These helpers compute the identical ``sum_k popcount(xnor)``
reduction with only 2-D ``[bm, bn]`` intermediates: a ``lax.fori_loop``
walks the packed K-words in small static groups (``word_group`` words
per iteration, unrolled inside the loop body so the VPU always has a
full-tile op in flight), and a static tail handles
``k_words % word_group != 0`` exactly.

Both layouts the kernels use are covered:

* :func:`accum_popcount_km` — GEMM layout, ``w [M, KW]`` x ``x [KW, N]``
* :func:`accum_popcount_rows` — gathered-window layout, ``w [M, KW]`` x
  ``x [N, KW]`` (rows share the word axis; used by the direct conv)

``word_group`` trades loop trip count against code size; it never
affects results (asserted against the broadcast formulation in
``tests/test_kernels.py``), so the autotuner sweeps it like any other
block dimension. When ``word_group >= k_words`` the fori_loop (and its
traced-start dynamic slice) disappears entirely and the walk is a pure
static unroll — the form to prefer if Mosaic ever rejects or
pessimizes the dynamic minor-axis slice on a native TPU lowering
(untested off-interpret in this container; the autotune candidate grid
includes a full-unroll config so a measured sweep on real hardware
picks whichever actually wins).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.bitops import PACK_BITS

DEFAULT_WORD_GROUP = 8


def sign_repack_m(y: jnp.ndarray) -> jnp.ndarray:
    """The fused kernels' shared sign+repack epilogue tail:
    ``[M, N]`` (any real dtype) -> packed int32 ``[M/32, N]`` with
    ``bit = (y >= 0)``, LSB-first along M. ``M`` must divide by 32 —
    every fused kernel guarantees this by construction (``block_m`` /
    ``block_d`` / ``M_max`` are 32-multiples)."""
    m, n = y.shape
    bits = (y >= 0).astype(jnp.int32)
    bits = bits.reshape(m // PACK_BITS, PACK_BITS, n)
    shifts = jnp.arange(PACK_BITS, dtype=jnp.int32)
    return jnp.sum(bits << shifts[None, :, None], axis=1)


def _word_pc(w_col: jnp.ndarray, x_row: jnp.ndarray) -> jnp.ndarray:
    """One packed word's popcount contribution: [M, 1] x [1, N] -> [M, N]."""
    return lax.population_count(~(w_col ^ x_row)).astype(jnp.int32)


def accum_popcount_km(
    w: jnp.ndarray, x: jnp.ndarray, *, word_group: int = DEFAULT_WORD_GROUP
) -> jnp.ndarray:
    """``sum_k popcount(~(w[:, k, None] ^ x[None, k, :]))`` -> [M, N].

    w: [M, KW] packed int32; x: [KW, N] packed int32. Only 2-D
    intermediates exist: the loop body slices ``word_group`` words and
    adds one ``[M, N]`` popcount per word (statically unrolled).
    """
    m, kw = w.shape
    _, n = x.shape
    acc = jnp.zeros((m, n), jnp.int32)
    if word_group >= kw:  # fully static unroll: no loop, no dynamic slice
        for t in range(kw):
            acc = acc + _word_pc(w[:, t : t + 1], x[t : t + 1, :])
        return acc
    g = max(1, word_group)

    def body(t, acc):
        wg = lax.dynamic_slice_in_dim(w, t * g, g, axis=1)  # [M, g]
        xg = lax.dynamic_slice_in_dim(x, t * g, g, axis=0)  # [g, N]
        for i in range(g):
            acc = acc + _word_pc(wg[:, i : i + 1], xg[i : i + 1, :])
        return acc

    acc = lax.fori_loop(0, kw // g, body, acc)
    for t in range((kw // g) * g, kw):  # static ragged tail, still 2-D
        acc = acc + _word_pc(w[:, t : t + 1], x[t : t + 1, :])
    return acc


def accum_popcount_km_dyn(
    w: jnp.ndarray,
    x: jnp.ndarray,
    n_groups: jnp.ndarray,
    *,
    word_group: int = DEFAULT_WORD_GROUP,
) -> jnp.ndarray:
    """:func:`accum_popcount_km` with a TRACED trip count: walk only the
    first ``n_groups * word_group`` packed K-words of the operands.

    This is the megakernel-chain accumulator (DESIGN.md §8): layers of
    different true K share one padded ``[L, M_max, KW_max]`` weight
    stack, and a per-layer ``n_groups = ceil(ceil(k/32) / word_group)``
    keeps each ``lax.fori_loop`` layer iteration from paying the
    stack-wide KW_max — a ragged layer walks its own K only. Words
    between the true K and the group boundary must be xnor-neutral
    pairs (zero weight words against all-ones activation words — the
    stacking convention guarantees this), so the group-aligned
    overshoot contributes exactly zero. ``KW`` must divide by
    ``word_group`` and ``n_groups * word_group <= KW`` (else the
    clamped dynamic slice would double-count the tail).
    """
    m, kw = w.shape
    _, n = x.shape
    g = max(1, word_group)
    assert kw % g == 0, (kw, g)

    def body(t, acc):
        wg = lax.dynamic_slice_in_dim(w, t * g, g, axis=1)  # [M, g]
        xg = lax.dynamic_slice_in_dim(x, t * g, g, axis=0)  # [g, N]
        for i in range(g):
            acc = acc + _word_pc(wg[:, i : i + 1], xg[i : i + 1, :])
        return acc

    return lax.fori_loop(0, n_groups, body, jnp.zeros((m, n), jnp.int32))


def accum_popcount_rows(
    w: jnp.ndarray, x: jnp.ndarray, *, word_group: int = DEFAULT_WORD_GROUP
) -> jnp.ndarray:
    """Row-major sibling: w [M, KW] x x [N, KW] -> [M, N].

    Same reduction as :func:`accum_popcount_km` with the second operand
    carrying its word axis last (the layout the direct-conv window
    gather produces), so no transpose/relayout is needed in-kernel.
    """
    m, kw = w.shape
    n, _ = x.shape
    acc = jnp.zeros((m, n), jnp.int32)
    if word_group >= kw:  # fully static unroll: no loop, no dynamic slice
        for t in range(kw):
            acc = acc + _word_pc(w[:, t : t + 1], x[:, t][None, :])
        return acc
    g = max(1, word_group)

    def body(t, acc):
        wg = lax.dynamic_slice_in_dim(w, t * g, g, axis=1)  # [M, g]
        xg = lax.dynamic_slice_in_dim(x, t * g, g, axis=1)  # [N, g]
        for i in range(g):
            acc = acc + _word_pc(wg[:, i : i + 1], xg[:, i][None, :])
        return acc

    acc = lax.fori_loop(0, kw // g, body, acc)
    for t in range((kw // g) * g, kw):
        acc = acc + _word_pc(w[:, t : t + 1], x[:, t][None, :])
    return acc


__all__ = [
    "DEFAULT_WORD_GROUP",
    "accum_popcount_km",
    "accum_popcount_km_dyn",
    "accum_popcount_rows",
    "sign_repack_m",
]
