"""Direct packed-window binary conv Pallas kernel — no im2col
materialization (DESIGN.md §5).

The fused im2col path (PR 1) still writes the packed patch matrix
``[N*OH*OW, kH*kW*CW]`` to HBM before each GEMM — ~kH*kW times larger
than the packed activation map it was gathered from. This kernel
convolves the channel-packed map directly: the grid tiles the output
pixel space ``(N, OH)`` x output channels ``D``, each program holds the
whole (pre-padded) packed image ``[Hp, Wp, CW]`` in VMEM, gathers its
kH*kW window rows with strided in-VMEM slices, runs the xnor-popcount
accumulation against the tap-aligned packed filter tile, and finishes
with the PR-1 fused epilogue (folded-BN affine -> sign -> repack along
D). HBM sees: the packed map (read), the packed filters (read), the
packed output (write). The patch matrix never exists.

Two variants share the window gather:

* ``fused_direct_conv`` — full fused layer, packed words in AND out,
* ``direct_conv_dot``   — epilogue-free int32 ±1 dot ``[N,OH,OW,D]``
                          (the chain-boundary / unfused-PACKED variant).

The popcount accumulation is BROADCAST-FREE (DESIGN.md §6): a
``lax.fori_loop`` over the kH*kW*CW packed filter words accumulates one
``[bd, OW]`` popcount per word — the old ``[bd, OW, KW]`` broadcast
intermediate never exists. ``accum="broadcast"`` keeps the legacy
formulation for A/B benchmarking only.

VMEM budget per grid step (CIFAR BNN worst case, block_d=128):
  x map     1*34*34*16*4  =  72 KiB   (conv5: Hp=Wp=10 -> 6 KiB)
  w tile    128*144*4     =  72 KiB   (KW = 9*16 words max)
  a, b      128*1*4 x2    =   1 KiB
  xnor      128*32*4      =  16 KiB   (one 2-D word term; was 2304 KiB)
  out       32*4*4        = 0.5 KiB
~162 KiB of ~16 MiB VMEM (was ~2.4 MiB). The map block is revisited across the OH and
D grid axes (same block index), so the pipeline fetches it once per
image. When the packed map itself outgrows VMEM (or kH*kW is large and
C tiny, so the patch blow-up the kernel avoids is small), fall back to
``conv_impl="im2col"`` — the GEMM tiles arbitrarily large operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitops import PACK_BITS
from repro.kernels import pallas_compat
from repro.kernels.popcount import (
    DEFAULT_WORD_GROUP,
    accum_popcount_rows,
    sign_repack_m,
)


def _gather_windows(x_ref, oh_idx, *, kh: int, kw: int, stride: int, ow: int):
    """Gather one output row's windows from the padded map in VMEM.

    x_ref: [1, Hp, Wp, CW]. Returns [OW, kH*kW*CW] int32 — tap-major
    word order (i*kW + j)*CW + cw, matching pack_conv_aligned rows.
    """
    cw = x_ref.shape[-1]
    taps = []
    for i in range(kh):
        row = x_ref[0, pl.ds(oh_idx * stride + i, 1)][0]  # [Wp, CW]
        for j in range(kw):
            taps.append(
                lax.slice(row, (j, 0), (j + stride * (ow - 1) + 1, cw),
                          (stride, 1))
            )  # [OW, CW]
    return jnp.concatenate(taps, axis=-1)


def _popcount_dot(w, xmat, k_bits: int, *, word_group: int, accum: str):
    """w [bd, KW] x xmat [OW, KW] -> exact ±1 dot, int32 [bd, OW]."""
    if accum == "broadcast":
        # Legacy formulation (A/B benchmarking only).
        xnor = ~(w[:, None, :] ^ xmat[None, :, :])  # [bd, OW, KW]
        pc = lax.population_count(xnor).astype(jnp.int32)
        acc = jnp.sum(pc, axis=-1)
    else:
        acc = accum_popcount_rows(w, xmat, word_group=word_group)
    return 2 * acc - jnp.int32(k_bits)


def _fused_direct_conv_kernel(
    x_ref, w_ref, a_ref, b_ref, o_ref, *,
    kh: int, kw: int, stride: int, ow: int, k_bits: int,
    word_group: int, accum: str,
):
    xmat = _gather_windows(x_ref, pl.program_id(1), kh=kh, kw=kw,
                           stride=stride, ow=ow)
    dot = _popcount_dot(w_ref[...], xmat, k_bits, word_group=word_group,
                        accum=accum)
    # Same float op order as bitops.direct_conv_oracle / fused_xnor_layer
    # so every conv_impl x engine pair is bit-exact vs the others.
    y = a_ref[...] * dot.astype(jnp.float32) + b_ref[...]  # [bd, OW]
    words = sign_repack_m(y)  # [bd/32, OW]
    o_ref[...] = words.T[None, None]  # [1, 1, OW, bd/32]


def _direct_conv_dot_kernel(
    x_ref, w_ref, o_ref, *,
    kh: int, kw: int, stride: int, ow: int, k_bits: int,
    word_group: int, accum: str,
):
    xmat = _gather_windows(x_ref, pl.program_id(1), kh=kh, kw=kw,
                           stride=stride, ow=ow)
    dot = _popcount_dot(w_ref[...], xmat, k_bits, word_group=word_group,
                        accum=accum)
    o_ref[...] = dot.T[None, None]  # [1, 1, OW, bd]


def _grid_and_specs(n, hp, wp_sp, cw, oh, ow, d_pad, block_d, kwords):
    grid = (n, oh, d_pad // block_d)
    x_spec = pl.BlockSpec((1, hp, wp_sp, cw), lambda ni, oi, di: (ni, 0, 0, 0))
    w_spec = pl.BlockSpec((block_d, kwords), lambda ni, oi, di: (di, 0))
    return grid, x_spec, w_spec


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_bits", "kh", "kw", "stride", "block_d", "word_group", "accum",
        "interpret",
    ),
)
def fused_direct_conv(
    wp: jnp.ndarray,
    xpad: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    block_d: int = 128,
    word_group: int = DEFAULT_WORD_GROUP,
    accum: str = "loop",
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed map [N, Hp, Wp, CW] x tap-aligned filters [D_pad, kH*kW*CW]
    -> PACKED int32 [N, OH, OW, D_pad/32].

    ``xpad`` must already carry its spatial all-ones border (the wrapper
    ``repro.kernels.ops.fused_direct_conv`` pads); ``a``/``b``
    ``[D_pad, 1]`` f32 per-output-channel affine, rows past the true D
    padded ``a=0, b=+1`` to pin their bits. ``block_d`` must divide by
    32 so each tile repacks to whole words.
    """
    n, hp, wp_sp, cw = xpad.shape
    d_pad, kwords = wp.shape
    assert kwords == kh * kw * cw, (wp.shape, kh, kw, cw)
    assert block_d % PACK_BITS == 0 and d_pad % block_d == 0, (d_pad, block_d)
    assert a.shape == (d_pad, 1) and b.shape == (d_pad, 1), (a.shape, b.shape)
    oh = (hp - kh) // stride + 1
    ow = (wp_sp - kw) // stride + 1

    assert accum in ("loop", "broadcast"), accum
    kernel = functools.partial(
        _fused_direct_conv_kernel, kh=kh, kw=kw, stride=stride, ow=ow,
        k_bits=k_bits, word_group=word_group, accum=accum,
    )
    grid, x_spec, w_spec = _grid_and_specs(
        n, hp, wp_sp, cw, oh, ow, d_pad, block_d, kwords
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            x_spec,
            w_spec,
            pl.BlockSpec((block_d, 1), lambda ni, oi, di: (di, 0)),
            pl.BlockSpec((block_d, 1), lambda ni, oi, di: (di, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, ow, block_d // PACK_BITS),
            lambda ni, oi, di: (ni, oi, 0, di),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, oh, ow, d_pad // PACK_BITS), jnp.int32
        ),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(xpad, wp, a.astype(jnp.float32), b.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_bits", "kh", "kw", "stride", "block_d", "word_group", "accum",
        "interpret",
    ),
)
def direct_conv_dot(
    wp: jnp.ndarray,
    xpad: jnp.ndarray,
    k_bits: int,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    block_d: int = 128,
    word_group: int = DEFAULT_WORD_GROUP,
    accum: str = "loop",
    interpret: bool = False,
) -> jnp.ndarray:
    """Epilogue-free variant: int32 ±1 dot [N, OH, OW, D_pad].

    Same gather + popcount pipeline as :func:`fused_direct_conv`; used
    by the unfused PACKED path (bias/alpha/BN applied by the caller in
    float). Padded D rows produce garbage the wrapper slices off.
    """
    n, hp, wp_sp, cw = xpad.shape
    d_pad, kwords = wp.shape
    assert kwords == kh * kw * cw, (wp.shape, kh, kw, cw)
    assert d_pad % block_d == 0, (d_pad, block_d)
    oh = (hp - kh) // stride + 1
    ow = (wp_sp - kw) // stride + 1

    assert accum in ("loop", "broadcast"), accum
    kernel = functools.partial(
        _direct_conv_dot_kernel, kh=kh, kw=kw, stride=stride, ow=ow,
        k_bits=k_bits, word_group=word_group, accum=accum,
    )
    grid, x_spec, w_spec = _grid_and_specs(
        n, hp, wp_sp, cw, oh, ow, d_pad, block_d, kwords
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec],
        out_specs=pl.BlockSpec(
            (1, 1, ow, block_d), lambda ni, oi, di: (ni, oi, 0, di)
        ),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, d_pad), jnp.int32),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(xpad, wp)
