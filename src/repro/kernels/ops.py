"""Jit'd public wrappers around the Pallas kernels.

These handle: shape padding to tile multiples (K-padding uses the
``(w=0, x=~0)`` xnor-neutral trick from ``core.bitops``), dtype checks,
and backend dispatch — ``interpret=True`` everywhere except a real TPU,
so the same call sites validate on CPU and run native on TPU.

Block sizes default to ``"auto"`` (DESIGN.md §6): the autotuner's
per-shape cache entry when one is valid for this jax version + device,
else heuristic tiles from the VMEM-budget model. Explicit ints are
honored but clamped to the padded problem shape, so tiny/ragged layers
(the 10-output CIFAR head) never trip the kernels' divisibility
asserts. Block choice never changes results — only speed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitops import PACK_BITS, PACKED_DTYPE, pad_packed_operands
from repro.kernels import autotune
from repro.kernels import direct_conv as direct_kernel
from repro.kernels import fused_gemm as fused_kernel
from repro.kernels import megakernel as mega_kernel
from repro.kernels import pack as pack_kernel
from repro.kernels import unpack_gemm as unpack_kernel
from repro.kernels import xnor_gemm as xnor_kernel
from repro.kernels.autotune import AUTO


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def xnor_gemm(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    *,
    block_m: int | str = AUTO,
    block_n: int | str = AUTO,
    block_kw: int | str = AUTO,
    word_group: int | str = AUTO,
    accum: str = "loop",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching xnor-popcount GEMM. int32 [M, N] output."""
    if wp.dtype != PACKED_DTYPE or xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    block_m, block_n, block_kw, word_group = autotune.resolve_gemm_blocks(
        "xnor_gemm", wp.shape[0], wp.shape[1], xp.shape[1],
        block_m, block_n, block_kw, word_group,
    )
    wp_p, xp_p, m, n = pad_packed_operands(wp, xp, block_m, block_n, block_kw)
    out = xnor_kernel.xnor_gemm(
        wp_p, xp_p, k_bits,
        block_m=block_m, block_n=block_n, block_kw=block_kw,
        word_group=word_group, accum=accum,
        interpret=interpret,
    )
    return out[:m, :n]


def unpack_gemm(
    wp: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int | str = AUTO,
    block_n: int | str = AUTO,
    block_kw: int | str = AUTO,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Packed-weight x real-input GEMM (MXU variant). [M, N] output.

    Blocks default to ``"auto"`` like every other wrapper (tuned
    ``"unpack_gemm"`` cache entry, else the unpack-MXU VMEM-model
    heuristic — the in-VMEM unpacked ±1 tile makes its footprint much
    steeper in ``block_kw`` than the xnor kernels'), and explicit ints
    are clamped to the padded problem shape so ragged layers (the
    10-output CIFAR head) never trip the kernel's divisibility asserts.
    """
    if wp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed weights must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    m, kw = wp.shape
    k, n = x.shape
    block_m, block_n, block_kw, _ = autotune.resolve_gemm_blocks(
        "unpack_gemm", m, kw, n,
        block_m, block_n, block_kw, autotune.DEFAULT_WORD_GROUP,
        unpack=True,
    )
    pm = -m % block_m
    pn = -n % block_n
    pkw = -kw % block_kw
    wp_p = jnp.pad(wp, ((0, pm), (0, pkw))) if (pm or pkw) else wp
    # zero-padded weight words unpack to -1s; zero-pad x rows so the
    # padded K region contributes -1 * 0 = 0.
    x_p = jnp.pad(x, ((0, pkw * PACK_BITS), (0, pn))) if (pkw or pn) else x
    out = unpack_kernel.unpack_gemm(
        wp_p, x_p,
        block_m=block_m, block_n=block_n, block_kw=block_kw,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]


def fused_xnor_gemm(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int | str = AUTO,
    block_n: int | str = AUTO,
    block_kw: int | str = AUTO,
    word_group: int | str = AUTO,
    accum: str = "loop",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching fused binary layer (DESIGN.md §4).

    Packed [M, KW] x packed [KW, N] with per-row affine ``a, b [M]``
    -> packed int32 [ceil(M/32), N]: the epilogue computes
    ``sign(a*(2*popcount-k_bits) + b)`` and repacks along M in one
    launch. ``k_bits`` is the TRUE contraction length; bit-level K pads
    must be xnor-neutral (weight bits -1, activation bits +1). Output
    rows past M inside the last word are +1 bits (the next layer's
    weight-pad correction consumes them exactly).
    """
    if wp.dtype != PACKED_DTYPE or xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    m, kw = wp.shape
    _, n = xp.shape
    block_m, block_n, block_kw, word_group = autotune.resolve_gemm_blocks(
        "fused_xnor_gemm", m, kw, n,
        block_m, block_n, block_kw, word_group, fused=True,
    )
    wp_p, xp_p, _, _ = pad_packed_operands(wp, xp, block_m, block_n, block_kw)
    pm = wp_p.shape[0] - m
    # padded output rows: a=0 kills the garbage dot, b=+1 pins the bit to 1.
    a_p = jnp.pad(a.astype(jnp.float32), (0, pm))[:, None]
    b_p = jnp.pad(b.astype(jnp.float32), (0, pm), constant_values=1.0)[:, None]
    out = fused_kernel.fused_xnor_gemm(
        wp_p, xp_p, k_bits, a_p, b_p,
        block_m=block_m, block_n=block_n, block_kw=block_kw,
        word_group=word_group, accum=accum,
        interpret=interpret,
    )
    return out[: -(-m // PACK_BITS), :n]


def _pad_direct_conv_operands(wp, xp, pad, kh, kw, stride, block_d,
                              word_group, *, fused, kernel):
    """Spatial all-ones border + D padding for the direct-conv kernels.

    Returns (wp_p, xpad, d, block_d, word_group): ``block_d`` resolves
    via the autotuner when ``"auto"`` and is always clamped to the
    padded-D extent, so test-scale calls never tile a 128-row block for
    a 10-channel conv.
    """
    d = wp.shape[0]
    if pad:
        xp = jnp.pad(xp, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                     constant_values=-1)
    _, hp, wp_sp, cw = xp.shape
    ow = (wp_sp - kw) // stride + 1
    block_d, word_group = autotune.resolve_conv_block_d(
        kernel, d, hp, wp_sp, cw, kh, kw, ow, block_d, word_group,
        fused=fused,
    )
    pd = -d % block_d
    wp_p = jnp.pad(wp, ((0, pd), (0, 0))) if pd else wp
    return wp_p, xp, d, block_d, word_group


def fused_direct_conv(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    block_d: int | str = AUTO,
    word_group: int | str = AUTO,
    accum: str = "loop",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching fused direct conv (DESIGN.md §5).

    Channel-packed map ``[N, H, W, CW]`` x tap-aligned packed filters
    ``[D, kH*kW*CW]`` with per-output-channel affine ``a, b [D]`` ->
    packed ``[N, OH, OW, ceil(D/32)]``: window gather straight from the
    map in VMEM, xnor-popcount, ``sign(a*dot + b)``, repack along D —
    the im2col patch matrix never reaches HBM. Spatial borders pad with
    all-ones words; rows past the true D get ``a=0, b=+1`` pinning their
    bits to the activation-pad convention, as in ``fused_xnor_gemm``.
    """
    if wp.dtype != PACKED_DTYPE or xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    wp_p, xpad, d, block_d, word_group = _pad_direct_conv_operands(
        wp, xp, pad, kh, kw, stride, block_d, word_group,
        fused=True, kernel="fused_direct_conv",
    )
    pd = wp_p.shape[0] - d
    a_p = jnp.pad(a.astype(jnp.float32), (0, pd))[:, None]
    b_p = jnp.pad(b.astype(jnp.float32), (0, pd), constant_values=1.0)[:, None]
    out = direct_kernel.fused_direct_conv(
        wp_p, xpad, k_bits, a_p, b_p,
        kh=kh, kw=kw, stride=stride, block_d=block_d,
        word_group=word_group, accum=accum, interpret=interpret,
    )
    return out[..., : -(-d // PACK_BITS)]


def direct_conv(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    block_d: int | str = AUTO,
    word_group: int | str = AUTO,
    accum: str = "loop",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching direct-conv ±1 dot: int32 ``[N, OH, OW, D]``.

    The epilogue-free sibling of :func:`fused_direct_conv` for float-
    boundary call sites (unfused PACKED conv): bias/alpha/BN stay with
    the caller. Same operands and window-gather pipeline.
    """
    if wp.dtype != PACKED_DTYPE or xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    wp_p, xpad, d, block_d, word_group = _pad_direct_conv_operands(
        wp, xp, pad, kh, kw, stride, block_d, word_group,
        fused=False, kernel="direct_conv",
    )
    out = direct_kernel.direct_conv_dot(
        wp_p, xpad, k_bits,
        kh=kh, kw=kw, stride=stride, block_d=block_d,
        word_group=word_group, accum=accum, interpret=interpret,
    )
    return out[..., :d]


def pack_rows(
    x: jnp.ndarray,
    *,
    block_kw: int = 8,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """[K, N] -> [K/32, N] packed. K must be a multiple of 32; N padded."""
    interpret = _default_interpret() if interpret is None else interpret
    k, n = x.shape
    if k % PACK_BITS != 0:
        raise ValueError(f"K={k} must be a multiple of {PACK_BITS}")
    kw = k // PACK_BITS
    bkw = min(block_kw, kw) if kw % min(block_kw, kw) == 0 else 1
    while kw % bkw:
        bkw -= 1
    pn = -n % block_n
    x_p = jnp.pad(x, ((0, 0), (0, pn))) if pn else x
    out = pack_kernel.pack_rows(
        x_p, block_kw=bkw, block_n=block_n, interpret=interpret
    )
    return out[:, :n]


RAGGED_TILE_N = 8  # sublane-multiple batch tile of the ragged chain path


def megakernel_chain(
    w_stack: jnp.ndarray,
    a_stack: jnp.ndarray,
    b_stack: jnp.ndarray,
    k_bits: tuple[int, ...],
    xp: jnp.ndarray,
    m_out: int,
    *,
    final_wp: jnp.ndarray | None = None,
    final_k_bits: int = 0,
    block_n: int | str = AUTO,
    word_group: int | str = AUTO,
    ragged_tile: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching megakernel chain (DESIGN.md §8): ``L``
    stacked fused binary layers — plus an optional epilogue-free final
    GEMM — in ONE launch, weights VMEM-resident, packed activations
    ping-ponged in VMEM scratch.

    ``w_stack [L, M_max, KW_max]`` / ``a_stack`` / ``b_stack [L,
    M_max]`` come from ``repro.core.layers.stack_chain_layers`` (pad
    rows ``a=0, b=+1``; pad weight words zero). ``xp [KW_in, N]`` is
    the packed input (K pads +1, the PR-1 convention); this wrapper
    grows it to the scratch height ``KW_act = max(KW_max, M_max/32)``
    with all-ones words and pads N to the batch tile. ``k_bits`` are
    the TRUE per-layer contraction lengths. Returns packed
    ``[ceil(m_out/32), N]`` — or with ``final_wp [Mf, KWf]`` the exact
    int32 ±1 dot ``[Mf, N]`` of the float-boundary head (``m_out`` is
    then ignored). ``block_n`` resolves via the ``"bnn_megakernel"``
    autotune entry / weights-resident VMEM heuristic.

    ``ragged_tile`` (DESIGN.md §9) switches on the ragged/masked-tail
    batch path for variable-extent dispatch (continuous batching): the
    batch pads only to the given tile multiple — ``block_n`` clamps to
    that tile-padded extent when it covers it in one grid step — instead
    of a full ``block_n`` rung; when the extent needs several tiles, the
    tail grid step hangs past the true batch and the kernel zeroes the
    overhanging output columns against a traced ``n_real``. Real columns
    stay bit-identical to the non-ragged path (asserted vs the XLA
    oracle in ``tests/test_megakernel.py``).
    """
    if w_stack.dtype != PACKED_DTYPE or xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    l, m_max, kw_max = w_stack.shape
    kw_in, n = xp.shape
    has_final = final_wp is not None
    mf = final_wp.shape[0] if has_final else 0
    block_n, word_group = autotune.resolve_megakernel_block_n(
        l, m_max, kw_max, n, block_n, word_group, final_m=mf,
    )
    # Group-align the stacked K axis (extra zero weight words against
    # all-ones activation rows are xnor-neutral) so the dynamic-trip
    # accumulator's slices can never clamp-and-double-count.
    pg = -kw_max % max(1, word_group)
    if pg:
        w_stack = jnp.pad(w_stack, ((0, 0), (0, 0), (0, pg)))
        kw_max += pg
    kw_act = max(kw_max, m_max // PACK_BITS)
    masked_tail = ragged_tile is not None
    if masked_tail:
        # Ragged path: pad N only to the batch-tile multiple, not the
        # full block_n rung. When the tile-padded extent fits in one
        # grid step, clamp block_n down to it (exact single tile, no
        # masking work wasted); otherwise run full block_n tiles and
        # let the kernel zero the tail overhang past n_real.
        tile = max(1, int(ragged_tile))
        n_tile = -(-n // tile) * tile
        if n_tile <= block_n:
            block_n = n_tile
            n_pad = n_tile
        else:
            n_pad = -(-n // block_n) * block_n
    else:
        n_pad = -(-n // block_n) * block_n
    pn = n_pad - n
    pkw = kw_act - kw_in
    if pkw or pn:
        xp = jnp.pad(xp, ((0, pkw), (0, pn)), constant_values=-1)
    fin = None
    if has_final:
        # M rows need no 32-alignment here (no repack on the final dot);
        # pad to the 8-row sublane multiple with zero weight words — the
        # garbage rows are sliced off below.
        pmf = -mf % 8
        fin = jnp.pad(final_wp, ((0, pmf), (0, 0))) if pmf else final_wp
    # Per-layer dynamic trip counts: each stacked layer walks only ITS
    # ceil(ceil(k/32) / word_group) K-word groups of the shared KW_max.
    kw_true = [-(-k // PACK_BITS) for k in k_bits]
    n_groups = [-(-kw_l // word_group) for kw_l in kw_true]
    out = mega_kernel.megakernel_chain(
        w_stack, a_stack, b_stack,
        jnp.asarray(k_bits, jnp.int32)[:, None],
        jnp.asarray(n_groups, jnp.int32)[:, None], xp, fin,
        jnp.full((1, 1), n, jnp.int32) if masked_tail else None,
        block_n=block_n, word_group=word_group,
        final_k_bits=final_k_bits, interpret=interpret,
    )
    rows = mf if has_final else -(-m_out // PACK_BITS)
    return out[:rows, :n]


def megakernel_conv_stage(
    xp: jnp.ndarray,
    weights: tuple[jnp.ndarray, ...],
    a: tuple[jnp.ndarray, ...],
    b: tuple[jnp.ndarray, ...],
    k_bits: tuple[int, ...],
    *,
    kh: int = 3,
    kw: int = 3,
    pad: int = 1,
    pool: bool = True,
    word_group: int | str = AUTO,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Padded, dispatching conv-stage megakernel (DESIGN.md §8): the
    stage's fused direct convs + packed-OR maxpool in ONE launch, one
    program per image, intermediate maps never touching HBM.

    ``xp [N, H, W, CW]`` channel-packed; ``weights[l] [D_l, kH*kW*
    CW_l]`` tap-aligned TRUE-shape filters with 1-D ``a[l]``/``b[l]
    [D_l]`` folded affines (``pack_conv_fused`` layer dicts provide
    exactly these). This wrapper applies the all-ones spatial border
    and the ``a=0, b=+1`` D-padding to whole words; output channel
    words need no slicing — ``D_pad/32 == ceil(D/32)`` and the tail
    bits are +1, the activation-pad convention. Returns the stage's
    packed output map ``[N, OH', OW', ceil(D_last/32)]``.
    """
    if xp.dtype != PACKED_DTYPE:
        raise TypeError(f"packed operands must be {PACKED_DTYPE}")
    interpret = _default_interpret() if interpret is None else interpret
    if autotune._is_auto(word_group):
        word_group = autotune.DEFAULT_WORD_GROUP
    if pad:
        xp = jnp.pad(xp, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                     constant_values=-1)
    ws, aps, bps = [], [], []
    for wl, al, bl in zip(weights, a, b):
        d = wl.shape[0]
        pd = -d % PACK_BITS
        ws.append(jnp.pad(wl, ((0, pd), (0, 0))) if pd else wl)
        aps.append(jnp.pad(al.astype(jnp.float32), (0, pd))[:, None])
        bps.append(jnp.pad(bl.astype(jnp.float32), (0, pd),
                           constant_values=1.0)[:, None])
    return mega_kernel.megakernel_conv_stage(
        xp, tuple(ws), tuple(aps), tuple(bps),
        k_bits=tuple(k_bits), kh=kh, kw=kw, pool=pool,
        word_group=int(word_group), interpret=interpret,
    )


__all__ = [
    "xnor_gemm",
    "unpack_gemm",
    "pack_rows",
    "fused_xnor_gemm",
    "fused_direct_conv",
    "direct_conv",
    "megakernel_chain",
    "megakernel_conv_stage",
]
