"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.bitops import PACK_BITS, pack_bits, unpack_bits


def binary_matmul_ref(w_pm1: jnp.ndarray, x_pm1: jnp.ndarray) -> jnp.ndarray:
    """Ground truth: ±1 float matmul, int32 result."""
    return jnp.dot(
        w_pm1.astype(jnp.float32), x_pm1.astype(jnp.float32)
    ).astype(jnp.int32)


def xnor_gemm_ref(wp: jnp.ndarray, xp: jnp.ndarray, k_bits: int) -> jnp.ndarray:
    """Paper §3.2 formula, materialized broadcast (test-scale only)."""
    xnor = ~(wp[:, :, None] ^ xp[None, :, :])
    pc = lax.population_count(xnor).astype(jnp.int32)
    return 2 * jnp.sum(pc, axis=1) - jnp.int32(k_bits)


def unpack_gemm_ref(wp: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Packed-weight x real-input matmul, fp32 result."""
    w = unpack_bits(wp, axis=-1, dtype=jnp.float32)
    return jnp.dot(w, x.astype(jnp.float32))


def pack_ref(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    return pack_bits(x, axis=axis)


def fused_layer_ref(
    w_pm1: jnp.ndarray, x_pm1: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Ground truth for the fused layer, from ±1 floats: float GEMM ->
    per-row affine -> sign -> pack along M (pad rows with +1 bits)."""
    dot = binary_matmul_ref(w_pm1, x_pm1).astype(jnp.float32)
    y = a[:, None].astype(jnp.float32) * dot + b[:, None].astype(jnp.float32)
    pad = -y.shape[0] % PACK_BITS
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)), constant_values=1.0)
    return pack_bits(y, axis=0)


def conv2d_pm1_ref(
    w_pm1: jnp.ndarray, x_pm1: jnp.ndarray, *, stride: int = 1, pad: int = 0
) -> jnp.ndarray:
    """Ground truth for the binary convs, from ±1 floats: im2col + float
    GEMM, int32 [N, OH, OW, D]. Borders pad with +1 — the binarized
    image of zero-padding, since sign(0) := +1.

    w_pm1: [D, kH, kW, C] ±1 filters; x_pm1: [N, H, W, C] ±1 values.
    """
    from repro.core.im2col import col2im, filters_to_matrix, im2col

    d, kh, kw, _ = w_pm1.shape
    patches, (oh, ow) = im2col(
        x_pm1.astype(jnp.float32), kh, kw, stride=stride, pad=pad,
        pad_value=1.0,
    )
    y = jnp.einsum(
        "npk,dk->npd", patches, filters_to_matrix(w_pm1).astype(jnp.float32)
    )
    return col2im(y, oh, ow).astype(jnp.int32)


def fused_direct_conv_ref(
    w_pm1: jnp.ndarray,
    x_pm1: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    stride: int = 1,
    pad: int = 0,
) -> jnp.ndarray:
    """Ground truth for the fused direct conv: ±1 conv -> per-output-
    channel affine -> sign -> pack along D (pad channels +1 bits).
    Returns packed int32 [N, OH, OW, ceil(D/32)]."""
    dot = conv2d_pm1_ref(w_pm1, x_pm1, stride=stride, pad=pad)
    y = (a.astype(jnp.float32) * dot.astype(jnp.float32)
         + b.astype(jnp.float32))
    padd = -y.shape[-1] % PACK_BITS
    if padd:
        y = jnp.pad(
            y, [(0, 0)] * (y.ndim - 1) + [(0, padd)], constant_values=1.0
        )
    return pack_bits(y, axis=-1)


__all__ = [
    "PACK_BITS",
    "binary_matmul_ref",
    "xnor_gemm_ref",
    "unpack_gemm_ref",
    "pack_ref",
    "fused_layer_ref",
    "conv2d_pm1_ref",
    "fused_direct_conv_ref",
]
