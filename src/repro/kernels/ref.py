"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.bitops import PACK_BITS, pack_bits, unpack_bits


def binary_matmul_ref(w_pm1: jnp.ndarray, x_pm1: jnp.ndarray) -> jnp.ndarray:
    """Ground truth: ±1 float matmul, int32 result."""
    return jnp.dot(
        w_pm1.astype(jnp.float32), x_pm1.astype(jnp.float32)
    ).astype(jnp.int32)


def xnor_gemm_ref(wp: jnp.ndarray, xp: jnp.ndarray, k_bits: int) -> jnp.ndarray:
    """Paper §3.2 formula, materialized broadcast (test-scale only)."""
    xnor = ~(wp[:, :, None] ^ xp[None, :, :])
    pc = lax.population_count(xnor).astype(jnp.int32)
    return 2 * jnp.sum(pc, axis=1) - jnp.int32(k_bits)


def unpack_gemm_ref(wp: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Packed-weight x real-input matmul, fp32 result."""
    w = unpack_bits(wp, axis=-1, dtype=jnp.float32)
    return jnp.dot(w, x.astype(jnp.float32))


def pack_ref(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    return pack_bits(x, axis=axis)


def fused_layer_ref(
    w_pm1: jnp.ndarray, x_pm1: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Ground truth for the fused layer, from ±1 floats: float GEMM ->
    per-row affine -> sign -> pack along M (pad rows with +1 bits)."""
    dot = binary_matmul_ref(w_pm1, x_pm1).astype(jnp.float32)
    y = a[:, None].astype(jnp.float32) * dot + b[:, None].astype(jnp.float32)
    pad = -y.shape[0] % PACK_BITS
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)), constant_values=1.0)
    return pack_bits(y, axis=0)


__all__ = [
    "PACK_BITS",
    "binary_matmul_ref",
    "xnor_gemm_ref",
    "unpack_gemm_ref",
    "pack_ref",
    "fused_layer_ref",
]
