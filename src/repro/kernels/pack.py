"""Pallas bit-packing (encoding) kernel — paper §3.1.

Encodes a real-valued matrix into the packed int32 format along axis 0
(the contraction axis of the input operand): ``[K, N] -> [K/32, N]``.
Each program packs a ``[bkw*32, bn]`` VMEM tile into ``[bkw, bn]`` words
with a shift-and-add over the 32-bit sub-axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.core.bitops import PACK_BITS


def _pack_kernel(x_ref, o_ref):
    x = x_ref[...]  # [bkw*32, bn]
    bk, bn = x.shape
    bkw = bk // PACK_BITS
    bits = (x >= 0).astype(jnp.int32).reshape(bkw, PACK_BITS, bn)
    shifts = jnp.arange(PACK_BITS, dtype=jnp.int32)
    o_ref[...] = jnp.sum(bits << shifts[None, :, None], axis=1)


@functools.partial(jax.jit, static_argnames=("block_kw", "block_n", "interpret"))
def pack_rows(
    x: jnp.ndarray,
    *,
    block_kw: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """[K, N] real -> [K/32, N] packed int32 (sign encoding, LSB-first)."""
    k, n = x.shape
    assert k % (block_kw * PACK_BITS) == 0 and n % block_n == 0, (k, n)
    kw = k // PACK_BITS
    return pl.pallas_call(
        _pack_kernel,
        grid=(kw // block_kw, n // block_n),
        in_specs=[
            pl.BlockSpec((block_kw * PACK_BITS, block_n), lambda i, j: (i, j))
        ],
        out_specs=pl.BlockSpec((block_kw, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kw, n), jnp.int32),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x)
