"""Paper-faithful xnor-popcount GEMM as a Pallas TPU kernel.

The CUDA original assigns one thread per output element and loops over
packed words with ``__popc``. The TPU adaptation re-tiles the same
computation for the memory hierarchy: packed ``int32`` operand tiles are
staged HBM->VMEM by the Pallas pipeline, the popcount reduction runs on
the VPU's 8x128 int32 lanes, and partial sums accumulate in a VMEM
scratch across the K grid axis (innermost, so the accumulator stays
resident).

The inner loop is BROADCAST-FREE (DESIGN.md §6): a ``lax.fori_loop``
walks the packed K-words in small groups and accumulates one
``[bm, bn]`` popcount per word — the old ``[bm, bkw, bn]`` xnor
intermediate (~85% of each step's VMEM at the 128/128/16 defaults)
never exists. ``accum="broadcast"`` keeps the old formulation for A/B
benchmarking and equivalence tests only.

VMEM budget per step (defaults bm=bn=128, bkw=16):
  w tile  128*16*4   =   8 KiB
  x tile  16*128*4   =   8 KiB
  xnor    128*128*4  =  64 KiB   (one 2-D word term; was 1024 KiB 3-D)
  acc     128*128*4  =  64 KiB
~144 KiB of ~16 MiB VMEM (was ~1.1 MiB) — the freed budget is what lets
``kernels/autotune.py`` pick much larger tiles and real double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels.popcount import DEFAULT_WORD_GROUP, accum_popcount_km


def _xnor_gemm_kernel(
    w_ref, x_ref, o_ref, acc_ref, *,
    k_bits: int, nk: int, word_group: int, accum: str,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]  # [bm, bkw] int32 (packed)
    x = x_ref[...]  # [bkw, bn] int32 (packed)
    if accum == "broadcast":
        # Legacy formulation (A/B benchmarking only): materializes the
        # full [bm, bkw, bn] xnor intermediate.
        xnor = ~(w[:, :, None] ^ x[None, :, :])
        pc = lax.population_count(xnor).astype(jnp.int32)
        acc_ref[...] += jnp.sum(pc, axis=1)
    else:
        acc_ref[...] += accum_popcount_km(w, x, word_group=word_group)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        # 2*popcount - K maps bit-space back to the ±1 dot product.
        o_ref[...] = 2 * acc_ref[...] - jnp.int32(k_bits)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_bits", "block_m", "block_n", "block_kw", "word_group", "accum",
        "interpret",
    ),
)
def xnor_gemm(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 16,
    word_group: int = DEFAULT_WORD_GROUP,
    accum: str = "loop",
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed [M, KW] x packed [KW, N] -> int32 [M, N].

    Operands must already be padded to tile multiples
    (see ``repro.kernels.ops.xnor_gemm`` for the padded wrapper).
    ``accum`` selects the inner-loop formulation: ``"loop"`` (the
    broadcast-free fori_loop accumulator) or ``"broadcast"`` (legacy
    3-D intermediate, kept for A/B benchmarks and tests).
    """
    m, kw = wp.shape
    kw2, n = xp.shape
    assert kw == kw2, (wp.shape, xp.shape)
    assert m % block_m == 0 and n % block_n == 0 and kw % block_kw == 0
    assert accum in ("loop", "broadcast"), accum
    nk = kw // block_kw

    kernel = functools.partial(
        _xnor_gemm_kernel, k_bits=k_bits, nk=nk, word_group=word_group,
        accum=accum,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_kw, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(wp, xp)
