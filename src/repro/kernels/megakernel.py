"""VMEM-resident megakernel: a whole chain of fused binary layers in
ONE Pallas launch (DESIGN.md §8).

The PR-1 fused pipeline made each interior binary layer one launch, but
packed activations still round-trip through HBM at every layer
boundary, and every boundary costs a kernel launch. Taken to the
paper's conclusion on TPU: the *entire* packed CIFAR BNN (~1.7 MB of
int32 weight words) fits comfortably in one core's ~16 MiB VMEM, so a
whole network *stage* can execute in a single launch with every
inter-layer activation living in VMEM scratch. Launch count and
inter-layer HBM traffic then scale with network stages, not layers.

Two kernels share the PR-1 epilogue (`popcount.sign_repack_m`) and the
broadcast-free accumulators (`popcount.accum_popcount_*`):

* :func:`megakernel_chain` — a GEMM chain (the FC trunk). Layer weights
  are stacked into one padded ``[L, M_max, KW_max]`` tensor with
  per-layer folded affines ``[L, M_max]``, ALL resident in VMEM across
  the grid (their block index is constant, so the pipeline fetches them
  once). The grid tiles the batch (N) dimension only; a
  ``lax.fori_loop`` over layers runs xnor-popcount -> folded-BN affine
  -> sign -> repack, with a ping-pong pair of VMEM scratch buffers
  (``buf[l % 2]`` -> ``buf[(l+1) % 2]``) carrying the packed
  activations between layers — no inter-layer HBM write, no per-layer
  launch. An optional epilogue-free final GEMM (the float-boundary
  10-class head) runs after the loop in the same launch, emitting the
  exact int32 ±1 dot.

* :func:`megakernel_conv_stage` — a conv stage (conv [+ conv] +
  packed-OR maxpool) via the PR-2 direct-conv path: one program per
  image holds the whole spatially-pre-padded channel-packed map in
  VMEM, gathers every 3x3 tap of the FULL image with static slices
  (the im2col patch matrix never exists, not even in VMEM rows), and
  chains the per-layer epilogues on in-register maps; only the pooled
  packed map of the LAST conv is written back to HBM.

Padding conventions are exactly PR-1's, applied per stacked layer:
K-words past a layer's true ``kw`` are zero in the weights and
all-ones in the activations (xnor-neutral); output rows past a layer's
true ``m`` carry ``a=0, b=+1``, pinning their bits to the
activation-pad convention — so the next stacked layer consumes the
scratch buffer unchanged and every kernel takes TRUE ``k_bits``.

VMEM budget (CIFAR BNN FC trunk, block_n=128):
  w stack   2*1024*256*4   = 2 MiB    (resident across the whole grid)
  a, b      2*2*1024*4     = 16 KiB
  ping-pong 2*256*128*4    = 256 KiB
  acc/y     3*1024*128*4   = 1.5 MiB  (popcount word term, acc, f32 y)
  final     16*32*4 + out  = ~10 KiB
~3.8 MiB of ~16 MiB VMEM; conv stages peak lower (§8 table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitops import PACK_BITS
from repro.kernels import pallas_compat
from repro.kernels.popcount import (
    DEFAULT_WORD_GROUP,
    accum_popcount_km,
    accum_popcount_km_dyn,
    accum_popcount_rows,
    sign_repack_m,
)


def _chain_kernel(
    w_ref, a_ref, b_ref, kb_ref, ng_ref, x_ref, *rest,
    n_layers: int, kw_act: int, word_group: int, has_final: bool,
    final_k_bits: int, masked: bool,
):
    if masked:
        nr_ref, rest = rest[0], rest[1:]
    else:
        nr_ref = None
    if has_final:
        wf_ref, o_ref, buf_ref = rest
    else:
        wf_ref = None
        o_ref, buf_ref = rest
    m_max = w_ref.shape[1]

    # Stage the batch tile of packed input activations into ping-pong
    # slot 0; the loop alternates slots so layer l reads buf[l % 2] and
    # writes buf[(l+1) % 2] — packed activations never leave VMEM.
    buf_ref[0] = x_ref[...]

    def layer(l, carry):
        act = buf_ref[l % 2]                       # [kw_act, bn]
        w = w_ref[l]                               # [m_max, kw_max]
        # Dynamic trip count: a ragged layer walks ITS K-word groups,
        # not the stack-wide KW_max (pad groups would contribute zero
        # but still cost full-tile popcounts).
        acc = accum_popcount_km_dyn(
            w, act[: w.shape[1]], ng_ref[l, 0], word_group=word_group
        )
        dot = (2 * acc - kb_ref[l, 0]).astype(jnp.float32)
        y = a_ref[l][:, None] * dot + b_ref[l][:, None]
        words = sign_repack_m(y)                   # [m_max/32, bn]
        # Rows past m_max/32 must be all-ones (activation-pad words) for
        # the next layer's zero weight words to be xnor-neutral.
        nxt = jnp.full((kw_act, act.shape[1]), -1, jnp.int32)
        buf_ref[(l + 1) % 2] = lax.dynamic_update_slice(nxt, words, (0, 0))
        return carry

    lax.fori_loop(0, n_layers, layer, 0)
    act = buf_ref[n_layers % 2]
    if has_final:
        # Float-boundary head: epilogue-free exact ±1 dot, same int32
        # result as a standalone xnor_gemm on the chain's output.
        wf = wf_ref[...]                           # [mf_pad, kwf]
        acc = accum_popcount_km(wf, act[: wf.shape[1]], word_group=word_group)
        out = 2 * acc - jnp.int32(final_k_bits)
    else:
        out = act[: m_max // PACK_BITS]
    if masked:
        # Ragged masked tail (DESIGN.md §9): the batch extent is only
        # tile-padded, so the last grid step may hang past the true
        # batch — zero every column at/after n_real (columns are
        # per-sample independent, so the pad columns' garbage never
        # touched a real column; this just pins their output).
        bn = out.shape[1]
        cols = pl.program_id(0) * bn + lax.broadcasted_iota(
            jnp.int32, (1, bn), 1
        )
        out = jnp.where(cols < nr_ref[0, 0], out, 0)
    o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "word_group", "final_k_bits", "interpret"),
)
def megakernel_chain(
    w_stack: jnp.ndarray,
    a_stack: jnp.ndarray,
    b_stack: jnp.ndarray,
    k_bits: jnp.ndarray,
    n_groups: jnp.ndarray,
    xp: jnp.ndarray,
    final_wp: jnp.ndarray | None = None,
    n_real: jnp.ndarray | None = None,
    *,
    block_n: int = 128,
    word_group: int = DEFAULT_WORD_GROUP,
    final_k_bits: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Run ``L`` stacked fused binary layers (+ optional final GEMM) in
    one launch.

    ``w_stack``: packed int32 ``[L, M_max, KW_max]`` (M_max % 32 == 0,
    KW_max % word_group == 0; rows past a layer's true ``m`` zero,
    K-words past its true ``kw`` zero). ``a_stack``/``b_stack``: f32
    ``[L, M_max]`` folded affines (pad rows ``a=0, b=+1``). ``k_bits``:
    int32 ``[L, 1]`` TRUE contraction lengths; ``n_groups``: int32
    ``[L, 1]`` per-layer K-word-group trip counts
    (``ceil(ceil(k/32) / word_group)``). ``xp``: packed ``[KW_act, N]``
    activations, ``KW_act = max(KW_max, M_max/32)`` with all-ones pad
    rows; N must divide by ``block_n``. Returns packed ``[M_max/32,
    N]`` — or, when ``final_wp [Mf, KWf]`` is given, the final layer's
    int32 ±1 dot ``[Mf, N]`` (``KWf <= KW_act``; ``final_k_bits`` its
    true K).

    Weights/affines use constant-index BlockSpecs: fetched once,
    VMEM-resident across the whole batch grid.

    ``n_real`` (optional int32 ``[1, 1]``) enables the ragged
    masked-tail path (DESIGN.md §9): N is then a tile-padded extent
    rather than a bucket rung, and every output column at/after
    ``n_real`` is zeroed in-kernel by the tail grid step — the
    pad-column garbage (columns are per-sample independent) never
    leaves the launch. Real columns are bit-identical to the unmasked
    path.
    """
    l, m_max, kw_max = w_stack.shape
    kw_act, n = xp.shape
    assert m_max % PACK_BITS == 0, m_max
    assert kw_max % max(1, word_group) == 0, (kw_max, word_group)
    assert kw_act >= max(kw_max, m_max // PACK_BITS), (kw_act, kw_max, m_max)
    assert n % block_n == 0, (n, block_n)
    assert a_stack.shape == (l, m_max) and b_stack.shape == (l, m_max)
    assert k_bits.shape == (l, 1), k_bits.shape
    assert n_groups.shape == (l, 1), n_groups.shape

    has_final = final_wp is not None
    if has_final:
        mf, kwf = final_wp.shape
        assert kwf <= kw_act, (kwf, kw_act)
        out_rows = mf
    else:
        out_rows = m_max // PACK_BITS

    masked = n_real is not None
    kernel = functools.partial(
        _chain_kernel, n_layers=l, kw_act=kw_act, word_group=word_group,
        has_final=has_final, final_k_bits=final_k_bits, masked=masked,
    )
    in_specs = [
        pl.BlockSpec((l, m_max, kw_max), lambda i: (0, 0, 0)),
        pl.BlockSpec((l, m_max), lambda i: (0, 0)),
        pl.BlockSpec((l, m_max), lambda i: (0, 0)),
        pl.BlockSpec((l, 1), lambda i: (0, 0)),
        pl.BlockSpec((l, 1), lambda i: (0, 0)),
        pl.BlockSpec((kw_act, block_n), lambda i: (0, i)),
    ]
    operands = [
        w_stack,
        a_stack.astype(jnp.float32),
        b_stack.astype(jnp.float32),
        k_bits.astype(jnp.int32),
        n_groups.astype(jnp.int32),
        xp,
    ]
    if masked:
        assert n_real.shape == (1, 1), n_real.shape
        in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))
        operands.append(n_real.astype(jnp.int32))
    if has_final:
        in_specs.append(pl.BlockSpec((mf, kwf), lambda i: (0, 0)))
        operands.append(final_wp)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((out_rows, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((out_rows, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((2, kw_act, block_n), jnp.int32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*operands)


def _conv_stage_kernel(
    *refs,
    n_layers: int, kh: int, kw: int, k_bits: tuple[int, ...], pool: bool,
    word_group: int,
):
    x_ref = refs[0]
    o_ref = refs[-1]
    mp = x_ref[0]  # [Hp, Wp, CW] — the whole padded map, in VMEM
    for l in range(n_layers):
        w_ref, a_ref, b_ref = refs[1 + 3 * l : 4 + 3 * l]
        hp, wp_sp, cw = mp.shape
        cw_l = w_ref.shape[1] // (kh * kw)
        oh, ow = hp - kh + 1, wp_sp - kw + 1
        # Whole-image window gather: tap (i, j) of EVERY output pixel is
        # one static slice of the map — tap-major word order
        # (i*kW + j)*CW + cw, the pack_conv_aligned filter layout.
        taps = [
            lax.slice(mp, (i, j, 0), (i + oh, j + ow, cw_l))
            for i in range(kh) for j in range(kw)
        ]
        xmat = jnp.concatenate(taps, axis=-1)
        xmat = xmat.reshape(oh * ow, kh * kw * cw_l)
        acc = accum_popcount_rows(w_ref[...], xmat, word_group=word_group)
        dot = (2 * acc - jnp.int32(k_bits[l])).astype(jnp.float32)
        y = a_ref[...] * dot + b_ref[...]          # [d_pad, oh*ow]
        words = sign_repack_m(y)                   # [d_pad/32, oh*ow]
        mp = words.T.reshape(oh, ow, y.shape[0] // PACK_BITS)
        if l + 1 < n_layers:
            # Re-grow the all-ones spatial border for the next conv —
            # in VMEM, never via HBM.
            mp = jnp.pad(mp, ((1, 1), (1, 1), (0, 0)), constant_values=-1)
    if pool:
        # 2x2 packed maxpool = bitwise OR of the window words (§3).
        mp = (mp[0::2, 0::2] | mp[0::2, 1::2]
              | mp[1::2, 0::2] | mp[1::2, 1::2])
    o_ref[...] = mp[None]


@functools.partial(
    jax.jit,
    static_argnames=("k_bits", "kh", "kw", "pool", "word_group", "interpret"),
)
def megakernel_conv_stage(
    xpad: jnp.ndarray,
    weights: tuple[jnp.ndarray, ...],
    a: tuple[jnp.ndarray, ...],
    b: tuple[jnp.ndarray, ...],
    *,
    k_bits: tuple[int, ...],
    kh: int = 3,
    kw: int = 3,
    pool: bool = True,
    word_group: int = DEFAULT_WORD_GROUP,
    interpret: bool = False,
) -> jnp.ndarray:
    """One conv stage — ``len(weights)`` fused direct convs (+ optional
    packed-OR maxpool) — in one launch, one program per image.

    ``xpad``: channel-packed map ``[N, Hp, Wp, CW]`` with its spatial
    all-ones border already applied (stride 1; Hp = H + 2*pad).
    ``weights[l]``: tap-aligned packed filters ``[D_pad_l, kH*kW*CW_l]``
    with ``D_pad_l % 32 == 0`` and ``CW_l`` = words/pixel of that
    layer's input (``CW_0 = CW``; ``CW_{l+1} = D_pad_l/32``).
    ``a[l]``/``b[l]``: f32 ``[D_pad_l, 1]`` (pad rows ``a=0, b=+1``).
    ``k_bits[l]``: TRUE ``kH*kW*C_l``. Returns the stage's packed
    output map ``[N, OH', OW', D_pad_last/32]`` (halved spatially when
    ``pool``). Filters/affines are VMEM-resident across the batch grid.
    """
    n, hp, wp_sp, cw = xpad.shape
    n_layers = len(weights)
    assert n_layers >= 1 and len(a) == len(b) == len(k_bits) == n_layers
    cw_in = cw
    for l, wl in enumerate(weights):
        d_pad, kwords = wl.shape
        assert d_pad % PACK_BITS == 0, (l, d_pad)
        assert kwords == kh * kw * cw_in, (l, wl.shape, kh, kw, cw_in)
        assert a[l].shape == (d_pad, 1) and b[l].shape == (d_pad, 1)
        cw_in = d_pad // PACK_BITS
    d_pad_last = weights[-1].shape[0]
    oh, ow = hp - kh + 1, wp_sp - kw + 1
    out_h, out_w = (oh // 2, ow // 2) if pool else (oh, ow)

    in_specs = [pl.BlockSpec((1, hp, wp_sp, cw), lambda i: (i, 0, 0, 0))]
    operands: list = [xpad]
    for wl, al, bl in zip(weights, a, b):
        d_pad, kwords = wl.shape
        in_specs += [
            pl.BlockSpec((d_pad, kwords), lambda i: (0, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ]
        operands += [wl, al.astype(jnp.float32), bl.astype(jnp.float32)]
    kernel = functools.partial(
        _conv_stage_kernel, n_layers=n_layers, kh=kh, kw=kw,
        k_bits=tuple(k_bits), pool=pool, word_group=word_group,
    )
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, out_h, out_w, d_pad_last // PACK_BITS),
            lambda i: (i, 0, 0, 0),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, out_h, out_w, d_pad_last // PACK_BITS), jnp.int32
        ),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*operands)
