"""Block-size autotuning for the xnor kernels (DESIGN.md §6).

The broadcast-free accumulator (``kernels/popcount.py``) shrank each
grid step's VMEM footprint ~8-14x, which makes tile choice a real
degree of freedom instead of "whatever fits". This module owns that
choice, in three layers:

1. **VMEM model** — :func:`gemm_step_vmem` / :func:`conv_step_vmem`
   compute the per-grid-step VMEM bytes of each kernel from its block
   shape (both the legacy ``broadcast`` and the ``loop`` formulation,
   so benchmarks can report the reduction).
2. **Heuristic defaults** — :func:`heuristic_gemm_blocks` /
   :func:`heuristic_conv_block_d` pick the largest aligned tiles whose
   double-buffered footprint fits a conservative VMEM budget, clamped
   to the (padded) problem shape. This is what ``block_*="auto"``
   resolves to when no tuned entry exists.
3. **Measured tuning** — :func:`tune` times a kernel wrapper across a
   candidate grid and persists the winner in a JSON cache keyed by
   kernel name + shape. Entries record the jax version and device kind
   and are IGNORED on mismatch (a stale cache can never poison a new
   runtime — the invalidation guard of ISSUE 3).

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``$XDG_CACHE_HOME/repro/autotune.json``, else
``~/.cache/repro/autotune.json``. Set ``REPRO_AUTOTUNE=0`` to bypass
the cache entirely (heuristics only). Cache format (entry keys join
the shape dims in sorted-name order)::

    {"version": 1,
     "entries": {
       "fused_xnor_gemm|kw=128|m=512|n=512": {
         "jax": "0.4.37", "device": "cpu",
         "block_m": 256, "block_n": 256, "block_kw": 32,
         "word_group": 8, "wall_s": 0.0123}}}

Every config this module emits is exact by construction: block shape
never changes results (asserted across the candidate grid in
``tests/test_autotune.py``), only speed.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import tempfile
import time
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.bitops import PACK_BITS
from repro.kernels.popcount import DEFAULT_WORD_GROUP

AUTO = "auto"
CACHE_VERSION = 1
# Target per-step footprint: ~16 MiB VMEM per TPU core, halved for
# double buffering of the streamed operand/output tiles, halved again
# as headroom for the compiler's own temporaries.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024
_I32 = 4


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One kernel tiling. ``block_m`` doubles as ``block_d`` (the
    output-channel tile) for the direct-conv kernels, which have no
    N/KW tiling of their own."""

    block_m: int = 128
    block_n: int = 128
    block_kw: int = 16
    word_group: int = DEFAULT_WORD_GROUP

    def gemm_kwargs(self) -> dict:
        return {
            "block_m": self.block_m,
            "block_n": self.block_n,
            "block_kw": self.block_kw,
            "word_group": self.word_group,
        }

    def conv_kwargs(self) -> dict:
        return {"block_d": self.block_m, "word_group": self.word_group}


# ---------------------------------------------------------------------------
# VMEM-per-step model
# ---------------------------------------------------------------------------

def gemm_step_vmem(
    bm: int, bn: int, bkw: int, *, fused: bool = False,
    accum: str = "loop", unpack: bool = False,
) -> int:
    """Per-grid-step VMEM bytes of (fused_)xnor_gemm at one tiling.

    ``accum="broadcast"`` models the legacy 3-D ``[bm, bkw, bn]`` xnor
    intermediate; ``"loop"`` models the fori_loop accumulator whose
    only intermediate is one 2-D ``[bm, bn]`` word term.
    ``unpack=True`` models ``unpack_gemm`` instead: the packed weight
    tile unpacks to a ±1 ``[bm, bkw*32]`` tile in VMEM and contracts a
    real f32 activation tile on the MXU — a different (and much
    steeper-in-``bkw``) footprint than the xnor kernels.
    """
    if unpack:
        w = bm * bkw * _I32                        # packed words
        wu = bm * bkw * PACK_BITS * _I32           # unpacked ±1 tile
        x = bkw * PACK_BITS * bn * _I32            # f32 activation tile
        acc = bm * bn * _I32                       # f32 accumulator
        out = bm * bn * _I32
        return w + wu + x + acc + out
    w = bm * bkw * _I32
    x = bkw * bn * _I32
    acc = bm * bn * _I32
    interm = bm * bkw * bn * _I32 if accum == "broadcast" else bm * bn * _I32
    total = w + x + acc + interm
    if fused:
        y = bm * bn * _I32                      # epilogue f32 affine
        out = (bm // PACK_BITS) * bn * _I32     # packed out tile
        ab = 2 * bm * _I32
        total += y + out + ab
    else:
        total += bm * bn * _I32                 # int32 out tile
    return total


def conv_step_vmem(
    hp: int, wp: int, cw: int, block_d: int, kh: int, kw: int, ow: int,
    *, fused: bool = True, accum: str = "loop",
) -> int:
    """Per-grid-step VMEM bytes of the direct-conv kernels."""
    kwords = kh * kw * cw
    xmap = hp * wp * cw * _I32
    w = block_d * kwords * _I32
    xmat = ow * kwords * _I32  # gathered window rows
    interm = (
        block_d * ow * kwords * _I32 if accum == "broadcast"
        else block_d * ow * _I32
    )
    total = xmap + w + xmat + interm
    if fused:
        total += block_d * ow * _I32 + (block_d // PACK_BITS) * ow * _I32
        total += 2 * block_d * _I32
    else:
        total += block_d * ow * _I32
    return total


# ---------------------------------------------------------------------------
# Heuristic defaults (used whenever no tuned cache entry applies)
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def heuristic_gemm_blocks(
    m: int, kw: int, n: int, *, fused: bool = False, unpack: bool = False,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> BlockConfig:
    """Largest aligned tiles fitting ``vmem_budget``, clamped to shape.

    Starts from the loop-formulation ceiling (bm=bn=512, bkw=64 — ~9x
    the old broadcast default's work per step at ~2.6 MiB) and halves
    the largest contributor until the model fits. Floors: bm >= 32
    (whole packed output words when fused), bn >= 128 (one lane tile),
    bkw >= 1. With ``unpack=True`` the model charges the in-VMEM
    unpacked ±1 weight tile, so ``bkw`` lands much smaller (each packed
    K-word is 32 real rows of the MXU contraction).
    """
    m_mult = PACK_BITS if fused else 8
    bm = min(512, _round_up(max(m, 1), m_mult))
    bn = min(512, _round_up(max(n, 1), 128))
    bkw = min(64, max(kw, 1))
    while gemm_step_vmem(bm, bn, bkw, fused=fused, unpack=unpack) > vmem_budget:
        if bm >= bn and bm > m_mult:
            bm = max(m_mult, bm // 2)
        elif bn > 128:
            bn = max(128, bn // 2)
        elif bkw > 1:
            bkw = max(1, bkw // 2)
        else:
            break  # floors reached; nothing left to shrink
    return BlockConfig(block_m=bm, block_n=bn, block_kw=bkw)


def heuristic_conv_block_d(
    d: int, hp: int, wp: int, cw: int, kh: int, kw: int, ow: int,
    *, fused: bool = True, vmem_budget: int = VMEM_BUDGET_BYTES,
) -> BlockConfig:
    """Output-channel tile for the direct-conv kernels."""
    bd = min(256, _round_up(max(d, 1), PACK_BITS))
    while (
        conv_step_vmem(hp, wp, cw, bd, kh, kw, ow, fused=fused) > vmem_budget
        and bd > PACK_BITS
    ):
        bd = max(PACK_BITS, bd // 2)
    return BlockConfig(block_m=bd)


# ---------------------------------------------------------------------------
# Persistent tuning cache
# ---------------------------------------------------------------------------

def cache_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return pathlib.Path(env)
    base = os.environ.get("XDG_CACHE_HOME")
    root = pathlib.Path(base) if base else pathlib.Path.home() / ".cache"
    return root / "repro" / "autotune.json"


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no devices at all
        return "unknown"


def _entry_key(kernel: str, shape: dict) -> str:
    parts = "|".join(f"{k}={shape[k]}" for k in sorted(shape))
    return f"{kernel}|{parts}"


# In-process memo of parsed cache files keyed by (path, mtime_ns, size)
# — load_entry runs on every "auto"-resolved kernel call, and re-reading
# the JSON from disk each time would put file I/O inside timed regions.
_read_memo: dict = {}


def _load_raw(path: Optional[pathlib.Path] = None) -> dict:
    path = path or cache_path()
    empty = {"version": CACHE_VERSION, "entries": {}}
    try:
        stat = path.stat()
        memo_key = (str(path), stat.st_mtime_ns, stat.st_size)
        cached = _read_memo.get(memo_key)
        if cached is not None:
            return cached
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return empty
    if (
        not isinstance(data, dict)
        or data.get("version") != CACHE_VERSION
        or not isinstance(data.get("entries"), dict)
    ):
        data = empty  # malformed file: ignored, overwritten on next save
    _read_memo.clear()  # only the latest file version is worth keeping
    _read_memo[memo_key] = data
    return data


def save_entry(
    kernel: str, shape: dict, config: BlockConfig, *,
    wall_s: Optional[float] = None, path: Optional[pathlib.Path] = None,
) -> None:
    """Persist one tuned config (stamped with jax version + device)."""
    path = path or cache_path()
    data = _load_raw(path)
    data["entries"][_entry_key(kernel, shape)] = {
        "jax": jax.__version__,
        "device": _device_kind(),
        "block_m": config.block_m,
        "block_n": config.block_n,
        "block_kw": config.block_kw,
        "word_group": config.word_group,
        **({"wall_s": wall_s} if wall_s is not None else {}),
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    # Atomic publish: a UNIQUE temp file in the same directory, fsync'd,
    # then os.replace — concurrent CI/benchmark runs each stage their
    # own temp (a shared fixed ".tmp" name lets two writers interleave
    # into one file), and a reader can never observe a torn write: it
    # sees either the old cache or the new one. A crash mid-write
    # leaves at most a stray temp file, never a corrupt cache (and a
    # corrupt cache would be IGNORED by ``_load_raw``, not fatal).
    fd, tmp_name = tempfile.mkstemp(
        dir=path.parent, prefix=path.name + ".", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps(data, indent=2, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, path)
    except BaseException:
        try:
            os.unlink(tmp_name)
        except OSError:
            pass
        raise


def load_entry(
    kernel: str, shape: dict, *, path: Optional[pathlib.Path] = None
) -> Optional[BlockConfig]:
    """Look up a tuned config. Returns None when absent OR stale —
    entries recorded under a different jax version or device kind are
    ignored (the cache-invalidation guard), never re-served.
    """
    entry = _load_raw(path)["entries"].get(_entry_key(kernel, shape))
    if not isinstance(entry, dict):
        return None
    if entry.get("jax") != jax.__version__:
        return None
    if entry.get("device") != _device_kind():
        return None
    try:
        return BlockConfig(
            block_m=int(entry["block_m"]),
            block_n=int(entry["block_n"]),
            block_kw=int(entry["block_kw"]),
            word_group=int(entry.get("word_group", DEFAULT_WORD_GROUP)),
        )
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# Measured block-size search
# ---------------------------------------------------------------------------

def default_gemm_candidates(
    m: int, kw: int, n: int, *, fused: bool = False
) -> list[BlockConfig]:
    """A small, shape-clamped candidate grid around the heuristic.

    ``word_group`` is swept alongside the tile dims: the mid-size tile
    appears with a smaller and a full-unroll group (``group >= bkw``
    compiles to a pure static walk with no fori_loop / dynamic slice —
    see ``kernels/popcount.py``).
    """
    seen, out = set(), []
    base = [
        (128, 128, 16, DEFAULT_WORD_GROUP),
        (256, 128, 16, DEFAULT_WORD_GROUP),
        (128, 256, 16, DEFAULT_WORD_GROUP),
        (256, 256, 32, DEFAULT_WORD_GROUP),
        (256, 256, 32, 4),
        (256, 256, 32, 32),   # full unroll: no fori_loop in-kernel
        (512, 256, 64, DEFAULT_WORD_GROUP),
        (256, 512, 64, DEFAULT_WORD_GROUP),
    ]
    m_mult = PACK_BITS if fused else 8
    for bm, bn, bkw, grp in base:
        cfg = BlockConfig(
            block_m=min(bm, _round_up(max(m, 1), m_mult)),
            block_n=min(bn, _round_up(max(n, 1), 128)),
            block_kw=min(bkw, max(kw, 1)),
            word_group=grp,
        )
        if cfg not in seen:
            seen.add(cfg)
            out.append(cfg)
    return out


def time_call(fn: Callable[[], jnp.ndarray], repeats: int) -> float:
    """Mean wall time of ``fn()`` over ``repeats`` after one warmup
    (compile) call. The one timing protocol shared by :func:`tune` and
    the benchmark sweeps."""
    jax.block_until_ready(fn())  # compile / warm up
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def rand_packed(key, shape: tuple[int, ...]) -> jnp.ndarray:
    """Uniform random packed int32 words (benchmark/tuning operands)."""
    info = jnp.iinfo(jnp.int32)
    return jax.random.randint(key, shape, info.min, info.max,
                              dtype=jnp.int32)


def tune(
    fn: Callable[..., jnp.ndarray],
    shapes: tuple[int, int, int],
    *,
    fused: bool = False,
    candidates: Optional[Iterable[BlockConfig]] = None,
    repeats: int = 2,
    cache: bool = True,
    kernel: Optional[str] = None,
    timings: Optional[dict] = None,
) -> BlockConfig:
    """Measure ``fn`` across block configs and return the fastest.

    ``fn`` is a padded GEMM wrapper with the ``kernels.ops`` signature:
    ``fn(wp, xp, k_bits, *, block_m, block_n, block_kw, word_group)``
    (plus ``(a, b)`` positionals when ``fused=True``). ``shapes`` is the
    UNPACKED problem ``(m, k, n)``; operands are synthesized here. The
    winner is persisted to the JSON cache (unless ``cache=False`` or
    ``REPRO_AUTOTUNE=0``) so later ``block_*="auto"`` calls on the same
    shape, device and jax version reuse it without re-measuring. Pass a
    dict as ``timings`` to receive the per-candidate wall times.
    """
    m, k, n = shapes
    kw = -(-k // PACK_BITS)
    kernel = kernel or getattr(fn, "__name__", "gemm")
    key = jax.random.PRNGKey(m * 131 + k * 31 + n)
    wp = rand_packed(jax.random.fold_in(key, 0), (m, kw))
    xp = rand_packed(jax.random.fold_in(key, 1), (kw, n))
    extra = ()
    if fused:
        a = jax.random.normal(jax.random.fold_in(key, 2), (m,))
        b = jax.random.normal(jax.random.fold_in(key, 3), (m,))
        extra = (a, b)

    cands = list(candidates) if candidates is not None else (
        default_gemm_candidates(m, kw, n, fused=fused)
    )
    best_cfg, best_t = None, float("inf")
    for cfg in cands:
        t = time_call(
            lambda cfg=cfg: fn(wp, xp, k, *extra, **cfg.gemm_kwargs()),
            repeats,
        )
        if timings is not None:
            timings[cfg] = t
        if t < best_t:
            best_cfg, best_t = cfg, t
    assert best_cfg is not None, "empty candidate list"
    if cache and cache_enabled():
        save_entry(
            kernel, {"m": m, "kw": kw, "n": n}, best_cfg, wall_s=best_t
        )
    return best_cfg


# ---------------------------------------------------------------------------
# Megakernel: weights-resident VMEM model + joint batch-tile search
# ---------------------------------------------------------------------------

MEGAKERNEL_KERNEL = "bnn_megakernel"
# The megakernel's weights are fetched ONCE and stay resident (constant
# block index) — they are not double-buffered, so only the streamed
# batch tiles pay the 2x. Budget: 16 MiB VMEM minus ~4 MiB compiler
# headroom for the whole residency (weights + scratch + intermediates).
MEGAKERNEL_VMEM_BUDGET = 12 * 1024 * 1024


def megakernel_vmem(
    l: int, m_max: int, kw_max: int, block_n: int, *, final_m: int = 0
) -> int:
    """Whole-launch VMEM bytes of ``megakernel_chain`` at one batch
    tile: resident stacked weights/affines + the ping-pong scratch pair
    + the per-layer intermediates (popcount word term, int32 acc, f32
    epilogue) + the in/out batch tiles."""
    kw_act = max(kw_max, m_max // PACK_BITS)
    weights = l * m_max * kw_max * _I32 + 2 * l * m_max * _I32
    scratch = 2 * kw_act * block_n * _I32          # ping-pong pair
    interm = 3 * m_max * block_n * _I32            # word term + acc + y
    x_tile = kw_act * block_n * _I32
    fin = final_m * kw_act * _I32 if final_m else 0
    out = max(final_m, m_max // PACK_BITS) * block_n * _I32
    return weights + scratch + interm + x_tile + fin + out


def heuristic_megakernel_block_n(
    l: int, m_max: int, kw_max: int, n: int, *, final_m: int = 0,
    vmem_budget: int = MEGAKERNEL_VMEM_BUDGET,
) -> int:
    """Largest lane-aligned batch tile whose modeled whole-launch
    residency fits ``vmem_budget`` (floor: one 128-lane tile — the
    weights are resident regardless, so shrinking below a lane tile
    buys nothing)."""
    bn = min(512, _round_up(max(n, 1), 128))
    while (
        megakernel_vmem(l, m_max, kw_max, bn, final_m=final_m) > vmem_budget
        and bn > 128
    ):
        bn = max(128, bn // 2)
    return bn


def megakernel_shape(
    l: int, m_max: int, kw_max: int, n: int, final_m: int = 0
) -> dict:
    """The autotune-cache shape key for one megakernel chain."""
    return {"l": l, "m": m_max, "kw": kw_max, "n": n, "mf": final_m}


def resolve_megakernel_block_n(
    l: int, m_max: int, kw_max: int, n: int,
    block_n, word_group, *, final_m: int = 0,
) -> tuple[int, int]:
    """``"auto"`` -> tuned ``bnn_megakernel`` cache entry (same
    jax-version/device staleness guard as every other kernel) ->
    weights-resident heuristic; then clamp to the padded batch."""
    if _is_auto(block_n) or _is_auto(word_group):
        cfg = None
        if cache_enabled():
            cfg = load_entry(
                MEGAKERNEL_KERNEL, megakernel_shape(l, m_max, kw_max, n,
                                                    final_m)
            )
        if cfg is not None:
            block_n = cfg.block_n if _is_auto(block_n) else block_n
            word_group = (
                cfg.word_group if _is_auto(word_group) else word_group
            )
        else:
            if _is_auto(block_n):
                block_n = heuristic_megakernel_block_n(
                    l, m_max, kw_max, n, final_m=final_m
                )
            if _is_auto(word_group):
                word_group = DEFAULT_WORD_GROUP
    block_n = max(1, min(int(block_n), _round_up(max(n, 1), 128)))
    return block_n, int(word_group)


def tune_block_n(
    kernel: str,
    shape: dict,
    fn: Callable[[int], jnp.ndarray],
    candidates: Sequence[int] = (128, 256, 512),
    *,
    repeats: int = 2,
    cache: bool = True,
    timings: Optional[dict] = None,
) -> int:
    """Joint batch-tile search for grid-tiles-the-batch kernels
    (megakernel chains): time ``fn(block_n)`` across ``candidates``,
    persist the winner under ``kernel``/``shape`` in the existing JSON
    cache (``block_n`` field of the entry; the staleness stamps and
    atomic write are shared with every other kernel), return it.
    """
    best_bn, best_t = None, float("inf")
    for bn in candidates:
        t = time_call(lambda bn=bn: fn(bn), repeats)
        if timings is not None:
            timings[bn] = t
        if t < best_t:
            best_bn, best_t = bn, t
    assert best_bn is not None, "empty candidate list"
    if cache and cache_enabled():
        save_entry(kernel, shape, BlockConfig(block_n=best_bn),
                   wall_s=best_t)
    return best_bn


def megakernel_block_kwargs(blocks) -> dict:
    """Config-surface helper for the megakernel wrappers: a ``blocks``
    value (``"auto"`` or a :class:`BlockConfig`) -> the keyword
    arguments ``ops.megakernel_chain`` / ``ops.megakernel_conv_stage``
    understand (``block_n`` tiles the batch; ``word_group`` is shared
    with every popcount kernel)."""
    if _is_auto(blocks) or blocks is None:
        return {}
    if isinstance(blocks, BlockConfig):
        return {"block_n": blocks.block_n, "word_group": blocks.word_group}
    raise TypeError(f"blocks must be 'auto' or BlockConfig, got {blocks!r}")


# ---------------------------------------------------------------------------
# "auto" resolution for the kernels.ops wrappers
# ---------------------------------------------------------------------------

def _is_auto(v) -> bool:
    return isinstance(v, str) and v == AUTO


def resolve_gemm_blocks(
    kernel: str, m: int, kw: int, n: int,
    block_m, block_n, block_kw, word_group,
    *, fused: bool = False, unpack: bool = False,
) -> tuple[int, int, int, int]:
    """Turn possibly-``"auto"`` block requests into concrete ints.

    Order: tuned cache entry (if valid for this jax/device) -> heuristic
    VMEM-budget defaults. Every resolved (and every explicitly
    requested) block is then clamped to the padded problem shape, so
    tiny or ragged layers never trip the kernels' divisibility asserts
    — a 10-output CIFAR head runs with bm=32, not a 128-row tile.
    ``unpack=True`` selects the unpack-MXU VMEM model for the heuristic.
    """
    if any(_is_auto(v) for v in (block_m, block_n, block_kw, word_group)):
        cfg = None
        if cache_enabled():
            cfg = load_entry(kernel, {"m": m, "kw": kw, "n": n})
        if cfg is None:
            cfg = heuristic_gemm_blocks(m, kw, n, fused=fused, unpack=unpack)
        block_m = cfg.block_m if _is_auto(block_m) else block_m
        block_n = cfg.block_n if _is_auto(block_n) else block_n
        block_kw = cfg.block_kw if _is_auto(block_kw) else block_kw
        word_group = cfg.word_group if _is_auto(word_group) else word_group
    m_mult = PACK_BITS if fused else 8
    block_m = max(m_mult, min(int(block_m), _round_up(max(m, 1), m_mult)))
    if fused:
        block_m = _round_up(block_m, PACK_BITS)
    block_n = max(1, min(int(block_n), _round_up(max(n, 1), 128)))
    block_kw = max(1, min(int(block_kw), max(kw, 1)))
    return block_m, block_n, block_kw, int(word_group)


def resolve_conv_block_d(
    kernel: str, d: int, hp: int, wp: int, cw: int, kh: int, kw: int,
    ow: int, block_d, word_group, *, fused: bool = True,
) -> tuple[int, int]:
    """Conv sibling of :func:`resolve_gemm_blocks` (block_d only).

    No conv tuner exists yet (``tune`` speaks the GEMM wrapper
    signature), so the cache lookup here serves hand-seeded or
    future-tuner entries; ``ow`` is part of the key because it folds in
    stride — two convs differing only in stride have different window
    counts and VMEM footprints and must not share an entry.
    """
    if _is_auto(block_d) or _is_auto(word_group):
        cfg = None
        if cache_enabled():
            cfg = load_entry(
                kernel,
                {"d": d, "hp": hp, "wp": wp, "cw": cw, "kh": kh, "kw": kw,
                 "ow": ow},
            )
        if cfg is None:
            cfg = heuristic_conv_block_d(
                d, hp, wp, cw, kh, kw, ow, fused=fused
            )
        block_d = cfg.block_m if _is_auto(block_d) else block_d
        word_group = cfg.word_group if _is_auto(word_group) else word_group
    block_d = max(
        PACK_BITS, min(int(block_d), _round_up(max(d, 1), PACK_BITS))
    )
    return block_d, int(word_group)


def block_kwargs(blocks, *, conv: bool = False) -> dict:
    """Config-surface helper: a ``BitLinearConfig.blocks`` /
    ``BNNConfig.blocks`` value (``"auto"`` or a :class:`BlockConfig`)
    -> keyword arguments for the ``kernels.ops`` wrappers."""
    if _is_auto(blocks) or blocks is None:
        return {}
    if isinstance(blocks, BlockConfig):
        return blocks.conv_kwargs() if conv else blocks.gemm_kwargs()
    raise TypeError(f"blocks must be 'auto' or BlockConfig, got {blocks!r}")


__all__ = [
    "AUTO",
    "BlockConfig",
    "VMEM_BUDGET_BYTES",
    "MEGAKERNEL_KERNEL",
    "MEGAKERNEL_VMEM_BUDGET",
    "gemm_step_vmem",
    "conv_step_vmem",
    "megakernel_vmem",
    "heuristic_gemm_blocks",
    "heuristic_conv_block_d",
    "heuristic_megakernel_block_n",
    "megakernel_shape",
    "cache_enabled",
    "cache_path",
    "save_entry",
    "load_entry",
    "default_gemm_candidates",
    "time_call",
    "rand_packed",
    "tune",
    "tune_block_n",
    "resolve_gemm_blocks",
    "resolve_conv_block_d",
    "resolve_megakernel_block_n",
    "block_kwargs",
    "megakernel_block_kwargs",
]
