"""Version compatibility shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in
newer jax releases; resolve whichever this jax provides so the kernels
import cleanly on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
