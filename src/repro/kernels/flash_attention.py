"""Flash attention as a Pallas TPU kernel (online softmax, VMEM tiles).

This is the kernel that justifies the roofline's "vmem_fusible" credit
(roofline/hlo_cost.py): on TPU the [Sq, Skv] score matrix never touches
HBM — each grid step stages a [bq, dh] query tile and a [bkv, dh] KV
tile into VMEM, runs QK^T -> masked online softmax -> PV on the MXU/VPU,
and carries (acc, running-max, denom) in VMEM scratch across the KV grid
axis. HBM traffic is exactly Q + O + nq*(K+V) — what the roofline's
fused memory term models.

Grid: (batch*heads, num_q_blocks, num_kv_blocks), KV innermost so the
scratch accumulator stays resident. Causal masking via per-tile position
iota against absolute q/kv offsets.

VMEM per step (bq=512, bkv=512, dh=128, fp32):
  q 512*128*4 = 256 KiB, k/v 2x256 KiB, scores 512*512*4 = 1 MiB,
  acc 256 KiB + m/l 4 KiB  ~= 2 MiB of ~16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  nkv: int, bq: int, bkv: int, causal: bool, scale: float):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]                     # [bq, dh]
    k = k_ref[...]                     # [bkv, dh]
    v = v_ref[...]                     # [bkv, dh]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                          # [bq, bkv]

    if causal:
        q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bkv), 0)
        k_pos = kv_idx * bkv + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bkv), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]                # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)             # [bq, bkv]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_idx == nkv - 1)
    def _done():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,    # [BH, Sq, Dh]  (batch*heads flattened)
    k: jnp.ndarray,    # [BH, Skv, Dh]
    v: jnp.ndarray,    # [BH, Skv, Dh]
    *,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, dh = q.shape
    _, skv, _ = k.shape
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    nkv = skv // bkv

    kernel = functools.partial(
        _flash_kernel, nkv=nkv, bq=bq, bkv=bkv, causal=causal,
        scale=dh ** -0.5,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, nkv),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
