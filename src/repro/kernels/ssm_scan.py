"""Selective-scan (Mamba S6) chunk kernel in Pallas.

The recurrence h_t = exp(dt_t*A) h_{t-1} + (dt_t x_t) B_t ; y_t = C_t.h
is the hot spot of the hybrid (jamba) layers. The XLA fallback
(models/mamba.py) runs it as an associative scan whose [B, C, di, ds]
state tensor is HBM-visible; this kernel keeps the state in VMEM — one
[bd, ds] register-resident h per grid cell, sequential over the chunk —
which is what the roofline's vmem_fusible credit for "SSM scan states"
models.

Grid: (batch, di/bd). Per grid step the kernel holds:
  dt, xh [C, bd]; B, C [C, ds]; A [bd, ds]; h [bd, ds]; y [C, bd]
VMEM (C=256, bd=128, ds=16, f32): 2*128KB + 2*16KB + 8KB + 8KB + 128KB
~= 0.4 MiB.

The sequential chunk walk trades MXU-parallelism for O(C) latency — on
TPU the di/bd grid axis provides the parallelism (di = 16384 for jamba
-> 128 parallel cells per batch element).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _ssm_scan_kernel(dt_ref, xh_ref, b_ref, c_ref, a_ref, h0_ref,
                     y_ref, h_out_ref):
    a = a_ref[...]                       # [bd, ds]
    chunk = dt_ref.shape[0]

    def step(t, h):
        dt_t = dt_ref[t, :]              # [bd]
        da = jnp.exp(dt_t[:, None] * a)  # [bd, ds]
        dbx = (dt_t * xh_ref[t, :])[:, None] * b_ref[t, :][None, :]
        h = h * da + dbx
        y_ref[t, :] = jnp.sum(h * c_ref[t, :][None, :], axis=1)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h0_ref[...])
    h_out_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "interpret"),
)
def ssm_scan_chunk(
    dt: jnp.ndarray,     # [B, C, di] f32
    xh: jnp.ndarray,     # [B, C, di] f32
    bmat: jnp.ndarray,   # [B, C, ds] f32
    cmat: jnp.ndarray,   # [B, C, ds] f32
    a: jnp.ndarray,      # [di, ds]   f32 (negative)
    h0: jnp.ndarray,     # [B, di, ds] f32
    *,
    block_d: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B, C, di], h_last [B, di, ds])."""
    b, c, di = dt.shape
    ds = a.shape[1]
    bd = min(block_d, di)
    assert di % bd == 0, (di, bd)

    return pl.pallas_call(
        _ssm_scan_kernel,
        grid=(b, di // bd),
        in_specs=[
            pl.BlockSpec((None, c, bd), lambda i, j: (i, 0, j)),   # dt
            pl.BlockSpec((None, c, bd), lambda i, j: (i, 0, j)),   # xh
            pl.BlockSpec((None, c, ds), lambda i, j: (i, 0, 0)),   # B
            pl.BlockSpec((None, c, ds), lambda i, j: (i, 0, 0)),   # C
            pl.BlockSpec((bd, ds), lambda i, j: (j, 0)),           # A
            pl.BlockSpec((None, bd, ds), lambda i, j: (i, j, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((None, c, bd), lambda i, j: (i, 0, j)),   # y
            pl.BlockSpec((None, bd, ds), lambda i, j: (i, j, 0)),  # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, ds), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(dt, xh, bmat, cmat, a, h0)
