"""Chunkwise mLSTM (xLSTM matrix-memory cell) as a Pallas TPU kernel.

Linear attention with exponential input gating and a matrix memory
C [dk, dv]: within a chunk the kernel runs the quadratic masked form in
VMEM; across chunks it carries (C, n, m) in VMEM scratch along the
innermost (sequential) grid axis — same scratch-accumulator pattern as
the flash-attention kernel. Exponentials are max-stabilized with the
carried stabilizer m (the exact scheme of models/xlstm.py, which is the
oracle this kernel is tested against).

Grid: (batch*heads, num_chunks). VMEM per step (L=128, dh=512, f32):
  q/k/v 3 x 256 KiB, scores [L,L] 64 KiB, C [dh,dh] 1 MiB, y 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, logi_ref, logf_ref,
                  y_ref, c_out_ref, n_out_ref, m_out_ref,
                  c_ref, n_ref, m_ref, *, nc: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[...]                      # [L, dk]
    k = k_ref[...]
    v = v_ref[...]                      # [L, dv]
    logi = logi_ref[...][:, 0]          # [L]
    logf = logf_ref[...][:, 0]

    b_cum = jnp.cumsum(logf)            # [L]
    g = logi - b_cum
    big_m = jax.lax.cummax(g)           # running max_{j<=t} g_j
    m_prev = m_ref[0, 0]
    m_loc = jnp.maximum(big_m, m_prev)  # [L]
    inter_scale = jnp.exp(m_prev - m_loc)

    # intra-chunk: S[t, j] = exp(g_j - m_loc_t), j <= t
    w_intra = jnp.exp(g[None, :] - m_loc[:, None])
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w_intra = jnp.where(j_idx <= t_idx, w_intra, 0.0)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    sw = qk * w_intra                    # [L, L]
    num = jax.lax.dot(sw, v, preferred_element_type=jnp.float32)
    num += jax.lax.dot(q, c_ref[...],
                       preferred_element_type=jnp.float32) \
        * inter_scale[:, None]
    den = jnp.sum(sw, axis=1)
    den_inter = jnp.sum(q * jnp.broadcast_to(n_ref[0:1, :], q.shape),
                        axis=1) * inter_scale
    den = den + den_inter
    y_ref[...] = (num / jnp.maximum(jnp.abs(den), 1.0)[:, None]).astype(
        y_ref.dtype)

    # advance carry: m' = b_L + max(M_L, m_prev)
    bL = b_cum[chunk - 1]
    m_loc_l = jnp.maximum(big_m[chunk - 1], m_prev)
    wk = jnp.exp(g - m_loc_l)            # [L]
    decay = jnp.exp(m_prev - m_loc_l)
    c_ref[...] = decay * c_ref[...] + jax.lax.dot_general(
        k * wk[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = decay * n_ref[...] + jnp.sum(
        k * wk[:, None], axis=0, keepdims=True)
    m_ref[...] = jnp.full_like(m_ref, bL + m_loc_l)

    @pl.when(ci == nc - 1)
    def _emit_state():   # final (C, n, m) for prefill -> decode handoff
        c_out_ref[...] = c_ref[...]
        n_out_ref[...] = n_ref[...]
        m_out_ref[...] = m_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked(
    q: jnp.ndarray,      # [BH, S, dk]  (pre-scaled by dk**-0.5)
    k: jnp.ndarray,      # [BH, S, dk]
    v: jnp.ndarray,      # [BH, S, dv]
    logi: jnp.ndarray,   # [BH, S]
    logf: jnp.ndarray,   # [BH, S]  (log-sigmoid forget pre-activations)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (y [BH,S,dv], C [BH,dk,dv], n [BH,1,dk], m [BH,1,1])."""
    bh, s, dk = q.shape
    dv = v.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l

    kernel = functools.partial(_mlstm_kernel, nc=nc, chunk=l)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((None, l, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, l, dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, l, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, l, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, l, 1), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, l, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, dk, dv), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, 1, dk), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1, dk), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),   # C
            pltpu.VMEM((1, dk), jnp.float32),    # n
            pltpu.VMEM((1, 1), jnp.float32),     # m
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, logi[..., None], logf[..., None])
