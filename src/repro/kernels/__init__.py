"""Pallas TPU kernels for 1-bit xnor/bitcount computation.

``xnor_gemm``         — paper-faithful packed xnor-popcount GEMM (VPU).
``unpack_gemm``       — TPU-native packed-weight MXU GEMM (beyond-paper).
``pack_rows``         — the paper's encoding operation as a kernel.
``fused_xnor_gemm``   — xnor GEMM + BN-fold/sign/repack epilogue: packed
                        activations in AND out (DESIGN.md §4).
``fused_direct_conv`` — direct packed-window conv + the same epilogue:
                        no im2col patch matrix in HBM (DESIGN.md §5).
``direct_conv``       — epilogue-free direct conv (int32 ±1 dot out).
``megakernel_chain``  — a whole chain of fused binary layers in ONE
                        launch: weights VMEM-resident, packed
                        activations ping-ponged in scratch (§8).
``megakernel_conv_stage`` — conv(+conv)+packed-OR-maxpool per launch,
                        one program per image (§8).

All xnor kernels share the broadcast-free popcount accumulator in
:mod:`repro.kernels.popcount` and resolve ``block_*="auto"`` tile
sizes via :mod:`repro.kernels.autotune` (DESIGN.md §6).

Import the padded/dispatching wrappers from :mod:`repro.kernels.ops`;
oracles live in :mod:`repro.kernels.ref` and :mod:`repro.core.bitops`.
"""

from repro.kernels.ops import (  # noqa: F401
    direct_conv,
    fused_direct_conv,
    fused_xnor_gemm,
    megakernel_chain,
    megakernel_conv_stage,
    pack_rows,
    unpack_gemm,
    xnor_gemm,
)
