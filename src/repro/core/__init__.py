"""Core binarization library — the paper's contribution as JAX modules."""

from repro.core.binarize import (  # noqa: F401
    QuantMode,
    binarize_activations,
    binarize_weights,
    ste_sign,
    weight_scale,
)
from repro.core.bitops import (  # noqa: F401
    PACK_BITS,
    PACKED_DTYPE,
    direct_conv_dot,
    direct_conv_oracle,
    pack_bits,
    pack_channels,
    packed_matmul_unpack,
    unpack_bits,
    xnor_popcount_matmul,
)
from repro.core.layers import (  # noqa: F401
    BitLinearConfig,
    bit_conv2d,
    bit_linear,
    init_conv,
    init_linear,
    pack_conv_aligned,
    pack_conv_params,
    pack_linear_params,
)
