"""The paper's evaluation model: Courbariaux-style Binarized Neural
Network for CIFAR-10 (paper §4.2), plus the float32 control group (§4.3).

Architecture (the BNN paper's CIFAR-10 ConvNet, VGG-like):

    2x(128C3) - MaxPool2 - 2x(256C3) - MaxPool2 - 2x(512C3) - MaxPool2
    - 1024FC - 1024FC - 10FC

BatchNorm after every conv/FC; Htanh+Sign activations between binary
layers. The first conv consumes real-valued images (standard BNN
practice); every other layer is binarized. All three execution modes
share this one graph:

  * ``QuantMode.FLOAT``      — the paper's control group: identical
    im2col->Gemm-Accumulation->bias forward graph, float32, no vendor-
    tuned conv (exactly the paper's "no cuDNN/MKL" control).
  * ``QuantMode.FAKE_QUANT`` — training / the "simulation" released
    PyTorch BNNs run (±1 in float math, STE backward).
  * ``QuantMode.PACKED``     — the paper's kernel: 1-bit packed weights,
    xnor-popcount (engine="xnor") or unpack->MXU (engine="unpack").
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.binarize import QuantMode, binarize_activations
from repro.core.layers import (
    BitLinearConfig,
    bit_conv2d,
    bit_linear,
    init_conv,
    init_linear,
    pack_conv_params,
    pack_linear_params,
)

CONV_CHANNELS = [(3, 128), (128, 128), (128, 256), (256, 256), (256, 512), (512, 512)]
POOL_AFTER = {1, 3, 5}  # maxpool after conv index
FC_SIZES = [(512 * 4 * 4, 1024), (1024, 1024), (1024, 10)]


@dataclasses.dataclass(frozen=True)
class BNNConfig:
    mode: QuantMode = QuantMode.FAKE_QUANT
    engine: str = "xnor"
    use_scale: bool = False
    num_classes: int = 10

    def layer_cfg(self, *, binarize_acts: bool) -> BitLinearConfig:
        return BitLinearConfig(
            mode=self.mode,
            engine=self.engine,
            use_scale=self.use_scale,
            binarize_acts=binarize_acts,
        )


def _init_bn(width: int) -> dict:
    return {
        "gamma": jnp.ones((width,)),
        "beta": jnp.zeros((width,)),
        "mean": jnp.zeros((width,)),
        "var": jnp.ones((width,)),
    }


def init_bnn_params(key) -> dict[str, Any]:
    params: dict[str, Any] = {"conv": [], "bn_conv": [], "fc": [], "bn_fc": []}
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        key, sub = jax.random.split(key)
        params["conv"].append(init_conv(sub, 3, 3, cin, cout, bias=True))
        params["bn_conv"].append(_init_bn(cout))
    for i, (fin, fout) in enumerate(FC_SIZES):
        key, sub = jax.random.split(key)
        params["fc"].append(init_linear(sub, fin, fout, bias=True))
        params["bn_fc"].append(_init_bn(fout))
    return params


def pack_bnn_params(params: dict, *, use_scale: bool = False) -> dict:
    """Latent float params -> packed 1-bit inference params (paper §3.1).

    The first conv stays float (real-valued image input), matching BNN
    practice and the paper's "kernel is only for convolution computation"
    scoping — we keep its float weights alongside the packed rest.
    """
    packed: dict[str, Any] = {
        "conv": [params["conv"][0]]
        + [pack_conv_params(p, use_scale=use_scale) for p in params["conv"][1:]],
        "fc": [pack_linear_params(p, use_scale=use_scale) for p in params["fc"]],
        "bn_conv": params["bn_conv"],
        "bn_fc": params["bn_fc"],
    }
    return packed


def _batchnorm(p: dict, x: jnp.ndarray, training: bool) -> jnp.ndarray:
    axes = tuple(range(x.ndim - 1))
    if training:
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
    else:
        mean, var = p["mean"], p["var"]
    inv = lax.rsqrt(var + 1e-4)
    return (x - mean) * inv * p["gamma"] + p["beta"]


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def bnn_apply(
    params: dict,
    images: jnp.ndarray,
    cfg: BNNConfig,
    *,
    training: bool = False,
) -> jnp.ndarray:
    """images [N, 32, 32, 3] -> logits [N, 10]."""
    x = images
    packed = cfg.mode == QuantMode.PACKED
    for i in range(len(CONV_CHANNELS)):
        first = i == 0
        if first and packed:
            # First conv consumes real-valued images, so it cannot use the
            # packed-activation kernel; its weights are still binarized
            # (fake-quant math on the retained float params) — the BNN
            # convention and the paper's "kernel is only for the
            # binary-input convolutions" scoping.
            lcfg = BitLinearConfig(
                mode=QuantMode.FAKE_QUANT,
                binarize_acts=False,
                use_scale=cfg.use_scale,
            )
        else:
            lcfg = cfg.layer_cfg(binarize_acts=not first)
        x = bit_conv2d(
            params["conv"][i], x, lcfg, stride=1, pad=1,
            kh=3 if packed else None, kw=3 if packed else None,
        )
        x = _batchnorm(params["bn_conv"][i], x, training)
        if i in POOL_AFTER:
            x = _maxpool2(x)
        x = binarize_activations(x) if not packed else jnp.clip(x, -1, 1)
        # (in packed mode the next layer's engine re-binarizes/encodes,
        #  mirroring the paper's encode-on-the-fly input path)
    n = x.shape[0]
    x = x.reshape(n, -1)
    for j in range(len(FC_SIZES)):
        last = j == len(FC_SIZES) - 1
        lcfg = cfg.layer_cfg(binarize_acts=True)
        x = bit_linear(params["fc"][j], x, lcfg)
        x = _batchnorm(params["bn_fc"][j], x, training)
        if not last:
            x = binarize_activations(x) if not packed else jnp.clip(x, -1, 1)
    return x


def bnn_loss(params, images, labels, cfg: BNNConfig):
    logits = bnn_apply(params, images, cfg, training=True)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, acc
