"""The paper's evaluation model: Courbariaux-style Binarized Neural
Network for CIFAR-10 (paper §4.2), plus the float32 control group (§4.3).

Architecture (the BNN paper's CIFAR-10 ConvNet, VGG-like):

    2x(128C3) - MaxPool2 - 2x(256C3) - MaxPool2 - 2x(512C3) - MaxPool2
    - 1024FC - 1024FC - 10FC

BatchNorm after every conv/FC; Htanh+Sign activations between binary
layers. The first conv consumes real-valued images (standard BNN
practice); every other layer is binarized. All three execution modes
share this one graph:

  * ``QuantMode.FLOAT``      — the paper's control group: identical
    im2col->Gemm-Accumulation->bias forward graph, float32, no vendor-
    tuned conv (exactly the paper's "no cuDNN/MKL" control).
  * ``QuantMode.FAKE_QUANT`` — training / the "simulation" released
    PyTorch BNNs run (±1 in float math, STE backward).
  * ``QuantMode.PACKED``     — the paper's kernel: 1-bit packed weights,
    xnor-popcount (engine="xnor") or unpack->MXU (engine="unpack").

``bnn_apply_fused`` is the fourth execution path: same function as
PACKED (bit-identical logits) but interior layer boundaries carry
packed int32 activations — BN folds into the fused kernel's epilogue
and maxpool becomes a bitwise OR on words (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitops
from repro.core.binarize import QuantMode, binarize_activations
from repro.core.layers import (
    BN_EPS,
    BitLinearConfig,
    bit_conv2d,
    bit_linear,
    fused_bit_conv2d,
    fused_bit_linear,
    init_conv,
    init_linear,
    megakernel_conv_stage,
    megakernel_fc_chain,
    pack_conv_fused,
    pack_conv_params,
    pack_linear_fused,
    pack_linear_params,
    packed_act_linear,
    stack_chain_layers,
)

CONV_CHANNELS = [(3, 128), (128, 128), (128, 256), (256, 256), (256, 512), (512, 512)]
POOL_AFTER = {1, 3, 5}  # maxpool after conv index
FC_SIZES = [(512 * 4 * 4, 1024), (1024, 1024), (1024, 10)]


def _conv_stages() -> tuple[tuple[int, ...], ...]:
    """Interior binary convs grouped into pool-terminated stages —
    ((1,), (2, 3), (4, 5)) for the CIFAR net: the megakernel's launch
    granularity (DESIGN.md §8). Derived from POOL_AFTER so it can never
    drift from the architecture constants."""
    stages, cur = [], []
    for i in range(1, len(CONV_CHANNELS)):
        cur.append(i)
        if i in POOL_AFTER:
            stages.append(tuple(cur))
            cur = []
    if cur:
        stages.append(tuple(cur))
    return tuple(stages)


CONV_STAGES = _conv_stages()


@dataclasses.dataclass(frozen=True)
class BNNConfig:
    mode: QuantMode = QuantMode.FAKE_QUANT
    engine: str = "xnor"
    conv_impl: str = "im2col"  # "im2col" | "direct" (PACKED convs only)
    use_scale: bool = False
    num_classes: int = 10
    # "auto" (autotune cache / VMEM heuristic) or a kernels.autotune
    # BlockConfig; forwarded to every Pallas kernel launch.
    blocks: object = "auto"

    def layer_cfg(self, *, binarize_acts: bool) -> BitLinearConfig:
        return BitLinearConfig(
            mode=self.mode,
            engine=self.engine,
            conv_impl=self.conv_impl,
            use_scale=self.use_scale,
            binarize_acts=binarize_acts,
            blocks=self.blocks,
        )


def _init_bn(width: int) -> dict:
    return {
        "gamma": jnp.ones((width,)),
        "beta": jnp.zeros((width,)),
        "mean": jnp.zeros((width,)),
        "var": jnp.ones((width,)),
    }


def init_bnn_params(key) -> dict[str, Any]:
    params: dict[str, Any] = {"conv": [], "bn_conv": [], "fc": [], "bn_fc": []}
    for i, (cin, cout) in enumerate(CONV_CHANNELS):
        key, sub = jax.random.split(key)
        params["conv"].append(init_conv(sub, 3, 3, cin, cout, bias=True))
        params["bn_conv"].append(_init_bn(cout))
    for i, (fin, fout) in enumerate(FC_SIZES):
        key, sub = jax.random.split(key)
        params["fc"].append(init_linear(sub, fin, fout, bias=True))
        params["bn_fc"].append(_init_bn(fout))
    return params


def pack_bnn_params(params: dict, *, use_scale: bool = False) -> dict:
    """Latent float params -> packed 1-bit inference params (paper §3.1).

    The first conv stays float (real-valued image input), matching BNN
    practice and the paper's "kernel is only for convolution computation"
    scoping — we keep its float weights alongside the packed rest.
    """
    packed: dict[str, Any] = {
        "conv": [params["conv"][0]]
        + [pack_conv_params(p, use_scale=use_scale) for p in params["conv"][1:]],
        "fc": [pack_linear_params(p, use_scale=use_scale) for p in params["fc"]],
        "bn_conv": params["bn_conv"],
        "bn_fc": params["bn_fc"],
    }
    return packed


def pack_bnn_params_fused(params: dict, *, use_scale: bool = False) -> dict:
    """Latent float params -> fused-pipeline inference params.

    Like :func:`pack_bnn_params`, but every *interior* binary layer also
    folds its inference BatchNorm (+ bias + optional alpha) into the
    ``(a, b)`` epilogue affine (``fold_bn_params``), so the fused kernel
    can emit packed ±1 activations directly. Float boundaries survive at
    the two ends only: the first conv (real-valued images in) and the
    last FC (real-valued logits out, BN kept separate).
    """
    n_fc = len(FC_SIZES)
    return {
        "conv": [params["conv"][0]]
        + [
            pack_conv_fused(p, bn, use_scale=use_scale)
            for p, bn in zip(params["conv"][1:], params["bn_conv"][1:])
        ],
        "bn_conv0": params["bn_conv"][0],
        "fc": [
            pack_linear_fused(
                params["fc"][j], params["bn_fc"][j], use_scale=use_scale
            )
            for j in range(n_fc - 1)
        ]
        + [pack_linear_params(params["fc"][-1], use_scale=use_scale)],
        "bn_fc_last": params["bn_fc"][-1],
    }


def _batchnorm(
    p: dict, x: jnp.ndarray, training: bool,
    stats: Optional[list] = None,
) -> jnp.ndarray:
    axes = tuple(range(x.ndim - 1))
    if training:
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
        if stats is not None:
            # batch statistics the trainer folds into the running
            # mean/var buffers (update_bn_stats) — collected as aux so
            # the packed eval path sees trained statistics.
            stats.append({"mean": mean, "var": var})
    else:
        mean, var = p["mean"], p["var"]
    inv = lax.rsqrt(var + BN_EPS)  # BN_EPS shared with fold_bn_params
    return (x - mean) * inv * p["gamma"] + p["beta"]


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def bnn_apply(
    params: dict,
    images: jnp.ndarray,
    cfg: BNNConfig,
    *,
    training: bool = False,
    return_stats: bool = False,
) -> jnp.ndarray:
    """images [N, 32, 32, 3] -> logits [N, 10].

    ``training=True`` uses batch BatchNorm statistics (and the STE
    binarization is differentiable end to end — ``core.binarize``).
    ``return_stats=True`` additionally returns the per-layer batch
    (mean, var) as ``{"bn_conv": [...], "bn_fc": [...]}`` so the
    trainer can maintain the running statistics packed inference uses
    (``update_bn_stats``); only meaningful with ``training=True``.
    """
    stats_conv: Optional[list] = [] if return_stats else None
    stats_fc: Optional[list] = [] if return_stats else None
    x = images
    packed = cfg.mode == QuantMode.PACKED
    for i in range(len(CONV_CHANNELS)):
        first = i == 0
        if first and packed:
            # First conv consumes real-valued images, so it cannot use the
            # packed-activation kernel; its weights are still binarized
            # (fake-quant math on the retained float params) — the BNN
            # convention and the paper's "kernel is only for the
            # binary-input convolutions" scoping.
            lcfg = BitLinearConfig(
                mode=QuantMode.FAKE_QUANT,
                binarize_acts=False,
                use_scale=cfg.use_scale,
            )
        else:
            lcfg = cfg.layer_cfg(binarize_acts=not first)
        x = bit_conv2d(
            params["conv"][i], x, lcfg, stride=1, pad=1,
            kh=3 if packed else None, kw=3 if packed else None,
        )
        x = _batchnorm(params["bn_conv"][i], x, training, stats_conv)
        if i in POOL_AFTER:
            x = _maxpool2(x)
        x = binarize_activations(x) if not packed else jnp.clip(x, -1, 1)
        # (in packed mode the next layer's engine re-binarizes/encodes,
        #  mirroring the paper's encode-on-the-fly input path)
    n = x.shape[0]
    x = x.reshape(n, -1)
    for j in range(len(FC_SIZES)):
        last = j == len(FC_SIZES) - 1
        lcfg = cfg.layer_cfg(binarize_acts=True)
        x = bit_linear(params["fc"][j], x, lcfg)
        x = _batchnorm(params["bn_fc"][j], x, training, stats_fc)
        if not last:
            x = binarize_activations(x) if not packed else jnp.clip(x, -1, 1)
    if return_stats:
        return x, {"bn_conv": stats_conv, "bn_fc": stats_fc}
    return x


# 2x2 maxpool on channel-packed ±1 maps = bitwise OR of the window
# words (max over {-1,+1} is +1 iff any bit is set; valid because sign
# is monotone, so sign∘max == max∘sign). Lives in bitops so the
# megakernel oracle shares the exact same op.
_maxpool2_packed = bitops.maxpool2_packed


def bnn_apply_fused(
    packed: dict,
    images: jnp.ndarray,
    *,
    engine: str = "xnor",
    conv_impl: str = "im2col",
    use_scale: bool = False,
    blocks: object = "auto",
) -> jnp.ndarray:
    """Fused packed inference: layer boundaries carry PACKED int32 words.

    Computes the same logits as ``bnn_apply(pack_bnn_params(p), x,
    BNNConfig(mode=PACKED))`` but between binary layers only
    ``[.., C/32]`` int32 activations exist: each interior layer is ONE
    fused launch (popcount GEMM -> folded-BN affine -> sign -> repack),
    maxpool is a bitwise OR on words, and the float tensor + standalone
    ``pack_rows`` launch of the unfused path disappear (~32x less
    boundary HBM traffic, DESIGN.md §4). ``packed`` comes from
    :func:`pack_bnn_params_fused`; ``engine`` is "xnor" (Pallas fused
    kernel) or "xla" (``bitops.fused_xnor_layer``, SPMD-safe).
    ``conv_impl`` picks the conv lowering for the interior binary convs:
    ``"im2col"`` (patch-matrix GEMM) or ``"direct"`` (packed-window
    kernel, no patch matrix in HBM — DESIGN.md §5); ``blocks`` is
    ``"auto"`` or a ``kernels.autotune.BlockConfig`` forwarded to every
    Pallas launch (DESIGN.md §6). Logits are bit-identical across all
    engine x conv_impl x block-config combinations.
    """
    # First conv keeps its float boundary (real-valued images), exactly
    # as in the unfused packed path; its BN output is then binarized and
    # channel-packed ONCE, and everything stays packed from here on.
    lcfg = BitLinearConfig(
        mode=QuantMode.FAKE_QUANT, binarize_acts=False, use_scale=use_scale
    )
    x = bit_conv2d(packed["conv"][0], images, lcfg, stride=1, pad=1)
    x = _batchnorm(packed["bn_conv0"], x, training=False)
    xp = bitops.pack_bits(x, axis=-1)  # [N, H, W, C/32]

    for i in range(1, len(CONV_CHANNELS)):
        c_in = CONV_CHANNELS[i][0]
        xp = fused_bit_conv2d(
            packed["conv"][i], xp, 3 * 3 * c_in,
            kh=3, kw=3, stride=1, pad=1, engine=engine,
            conv_impl=conv_impl, blocks=blocks,
        )
        if i in POOL_AFTER:
            xp = _maxpool2_packed(xp)

    n = xp.shape[0]
    xp = xp.reshape(n, -1)  # word order matches pack_linear's K order
    for j in range(len(FC_SIZES) - 1):
        xp = fused_bit_linear(packed["fc"][j], xp, FC_SIZES[j][0],
                              engine=engine, blocks=blocks)
    # Last FC: float logits boundary — plain packed GEMM + bias, then
    # the un-folded BatchNorm (same float ops as the unfused path).
    y = packed_act_linear(packed["fc"][-1], xp, FC_SIZES[-1][0],
                          engine=engine, blocks=blocks)
    return _batchnorm(packed["bn_fc_last"], y, training=False)


def pack_bnn_params_megakernel(params: dict, *, use_scale: bool = False) -> dict:
    """Latent float params -> megakernel inference params.

    Same per-layer packing/folding as :func:`pack_bnn_params_fused`,
    plus the FC trunk's interior layers pre-stacked at PACK TIME into
    the megakernel chain's padded ``[L, M_max, KW_max]`` operands
    (``fc_stack``) — the forward then ships the stacked tensor straight
    to the launch with zero per-forward stacking work, keeping the
    weights-resident contract honest. Conv stages keep per-layer
    tap-aligned params (their true shapes differ per conv; the stage
    kernel consumes them directly).
    """
    fused = pack_bnn_params_fused(params, use_scale=use_scale)
    return {
        "conv": fused["conv"],
        "bn_conv0": fused["bn_conv0"],
        "fc_stack": stack_chain_layers(fused["fc"][:-1]),
        "fc_final": fused["fc"][-1],
        "bn_fc_last": fused["bn_fc_last"],
    }


def bnn_apply_megakernel(
    packed: dict,
    images: jnp.ndarray,
    *,
    engine: str = "xnor",
    use_scale: bool = False,
    blocks: object = "auto",
    ragged: bool = False,
) -> jnp.ndarray:
    """Megakernel inference: ONE launch per network stage, packed
    activations never touching HBM inside a stage (DESIGN.md §8).

    Computes logits bit-identical to :func:`bnn_apply_fused` (hence to
    the unfused PACKED path) from :func:`pack_bnn_params_megakernel`
    params, but the launch structure is per-STAGE, not per-layer:

      float first conv (XLA) -> pack          (unchanged boundary)
      conv stage 1: conv1 + OR-pool           1 launch
      conv stage 2: conv2 + conv3 + OR-pool   1 launch
      conv stage 3: conv4 + conv5 + OR-pool   1 launch
      FC trunk: fc0 + fc1 (fused) + fc2 dot   1 launch
      bias + unfolded BN on [N, 10] floats    (unchanged boundary)

    4 launches where the per-layer fused chain takes 8, and 4 of its 7
    interior packed boundaries (conv2, conv4, fc0, fc1 outputs) now
    live in VMEM — only the three pooled stage-output maps still cross
    HBM. ``engine="xnor"`` runs the Pallas megakernels (interpret mode
    off-TPU); ``engine="xla"`` the pure-XLA oracles (SPMD-safe, and the
    parity reference). ``blocks`` forwards ``block_n``/``word_group``.

    ``ragged`` (DESIGN.md §9) routes the FC-trunk launch through the
    masked-tail batch path for variable-extent continuous-batching
    dispatch — batch pads only to the sublane tile, not a ``block_n``
    rung. Conv stages run one program per image and already scale
    exactly with N, so only the trunk changes; logits stay
    bit-identical either way.
    """
    lcfg = BitLinearConfig(
        mode=QuantMode.FAKE_QUANT, binarize_acts=False, use_scale=use_scale
    )
    x = bit_conv2d(packed["conv"][0], images, lcfg, stride=1, pad=1)
    x = _batchnorm(packed["bn_conv0"], x, training=False)
    xp = bitops.pack_bits(x, axis=-1)  # [N, H, W, C/32]

    for stage in CONV_STAGES:
        xp = megakernel_conv_stage(
            [packed["conv"][i] for i in stage],
            xp,
            tuple(3 * 3 * CONV_CHANNELS[i][0] for i in stage),
            pool=stage[-1] in POOL_AFTER,
            engine=engine, blocks=blocks,
        )

    n = xp.shape[0]
    xp = xp.reshape(n, -1)  # word order matches pack_linear's K order
    y = megakernel_fc_chain(
        packed["fc_stack"], xp,
        tuple(fin for fin, _ in FC_SIZES[:-1]),
        FC_SIZES[-2][1],
        final=packed["fc_final"], final_k=FC_SIZES[-1][0],
        engine=engine, blocks=blocks, ragged=ragged,
    )
    return _batchnorm(packed["bn_fc_last"], y, training=False)


# Engines bnn_serve_fn (and thus the serving executor cache) accepts.
# "xla"/"xnor" dispatch the per-layer fused chain on
# pack_bnn_params_fused params; "megakernel"/"megakernel_xla" dispatch
# one-launch-per-stage forwards on pack_bnn_params_megakernel params.
SERVE_ENGINES = ("xla", "xnor", "megakernel", "megakernel_xla")

# Failover demotion ladder (DESIGN.md §11): on repeated kernel failure
# a serving engine walks down its ladder, most-specialized first, each
# rung strictly more conservative than the last.  Every rung is
# bit-identical to the primary (the repo's bedrock invariant), so
# failover is logit-exact.  The megakernel rungs need
# pack_bnn_params_megakernel params, the fused rungs
# pack_bnn_params_fused — FallbackPolicy skips rungs it holds no
# params for.
SERVE_FALLBACKS = {
    "megakernel": ("xnor", "xla"),
    "megakernel_xla": ("xla",),
    "xnor": ("xla",),
    "xla": (),
}


def bnn_serve_fn(
    *,
    engine: str = "xla",
    conv_impl: str = "im2col",
    blocks: object = "auto",
    ragged: bool = False,
    mesh: object = None,
):
    """The serving entry point: a jit-compiled ``(packed, images) ->
    logits`` callable over :func:`bnn_apply_fused` — or, for the
    megakernel engines, :func:`bnn_apply_megakernel`.

    ``engine`` is ``"xla"``/``"xnor"`` (per-layer fused chain; params =
    ``pack_bnn_params_fused``) or ``"megakernel"``/``"megakernel_xla"``
    (one launch per stage via the Pallas megakernels / their pure-XLA
    oracles; params = ``pack_bnn_params_megakernel``; ``conv_impl`` is
    ignored — conv stages are direct-path by construction).

    The kernel-path knobs are bound at closure time (they select traced
    program structure, not runtime values), so each returned callable
    compiles once per input shape — exactly the contract the serving
    executor cache (``repro.serve.executor``) builds on: one executable
    per ``(bucket, engine, conv_impl, blocks)`` key. The ``images``
    buffer is donated: a serving batch is consumed by its dispatch, so
    on accelerators XLA may reuse its pages for intermediates instead
    of holding both alive. (The CPU backend cannot use donations and
    warns on every compile, so the annotation is applied only where it
    can take effect.)

    ``ragged=True`` (the continuous scheduler's executors) routes the
    megakernel FC trunk through the masked-tail batch path so variable
    tile-padded extents pad to the sublane tile, not a ``block_n`` rung
    (DESIGN.md §9); it is a no-op for the exact-shape XLA engines and
    the per-layer fused chain.

    ``mesh`` (DESIGN.md §10) is a 1-D ``("data",)`` serving mesh from
    ``launch.mesh.make_serving_mesh``: the forward is wrapped in
    ``shard_map`` with the packed params REPLICATED (the whole packed
    model is ~1.75 MB, so every device holds it and the forward needs
    no collectives) and the batch dim sharded over ``data`` — each
    device runs the identical per-shard program the single-device path
    runs, which is why sharded logits are bit-identical to unsharded
    ones (asserted per engine x conv_impl x device-count in
    ``tests/test_sharded_serve.py``). The caller must dispatch batches
    whose leading dim divides the mesh (the serving executors round
    their ladders to ``tile x n_devices`` and zero-pad bit-neutrally —
    never this function's concern).
    """
    if engine not in SERVE_ENGINES:
        raise ValueError(f"unknown serving engine {engine!r}; "
                         f"expected one of {SERVE_ENGINES}")
    donate = (1,) if jax.default_backend() != "cpu" else ()

    if engine in ("megakernel", "megakernel_xla"):
        inner = "xnor" if engine == "megakernel" else "xla"

        def apply_fn(packed: dict, images: jnp.ndarray) -> jnp.ndarray:
            return bnn_apply_megakernel(
                packed, images, engine=inner, blocks=blocks, ragged=ragged,
            )
    else:

        def apply_fn(packed: dict, images: jnp.ndarray) -> jnp.ndarray:
            return bnn_apply_fused(
                packed, images, engine=engine, conv_impl=conv_impl,
                blocks=blocks,
            )

    if mesh is not None:
        from jax.experimental.shard_map import shard_map

        from repro.distributed.sharding import serve_specs

        p_spec, x_spec, y_spec = serve_specs(mesh)
        # check_rep=False: the Pallas kernel calls inside the per-shard
        # program carry no replication rules; correctness rests on the
        # per-sample independence of the forward, asserted bit-exactly
        # in the sharded test matrix.
        apply_fn = shard_map(
            apply_fn, mesh=mesh,
            in_specs=(p_spec, x_spec), out_specs=y_spec,
            check_rep=False,
        )

    return functools.partial(jax.jit, donate_argnums=donate)(apply_fn)


def bnn_loss(params, images, labels, cfg: BNNConfig):
    logits = bnn_apply(params, images, cfg, training=True)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, acc


# ---------------------------------------------------------------------------
# Train-to-serve (DESIGN.md §12): STE training loss with BN statistics,
# trained-checkpoint export, and the packed-format exporter that feeds
# every serving engine.
# ---------------------------------------------------------------------------


def bnn_train_loss(params, images, labels, cfg: BNNConfig):
    """Training loss whose aux carries everything the trainer needs:
    ``(loss, {"acc", "bn_stats"})``.

    Identical math to :func:`bnn_loss`, but the BatchNorm batch
    statistics come back as aux so the train step can fold them into
    the running ``mean``/``var`` buffers (:func:`update_bn_stats`) —
    packed inference runs in eval mode and reads exactly those buffers,
    so without this the exported model would normalize with the init
    stats (mean 0 / var 1) and serve garbage.
    """
    (logits, stats) = bnn_apply(
        params, images, cfg, training=True, return_stats=True
    )
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"acc": acc, "bn_stats": stats}


def update_bn_stats(params: dict, bn_stats: dict, *,
                    momentum: float = 0.9) -> dict:
    """EMA the collected batch statistics into the BN buffers:
    ``new = momentum * old + (1 - momentum) * batch`` — the standard
    running-stat update, applied OUTSIDE the gradient path (mean/var are
    buffers, not trainable params; AdamW never touches them because
    their gradient is zero and the trainer runs with weight_decay only
    on weights)."""
    out = dict(params)
    for group, key in (("bn_conv", "bn_conv"), ("bn_fc", "bn_fc")):
        out[key] = [
            {
                **bn,
                "mean": momentum * bn["mean"] + (1 - momentum) * s["mean"],
                "var": momentum * bn["var"] + (1 - momentum) * s["var"],
            }
            for bn, s in zip(params[key], bn_stats[group])
        ]
    return out


def bnn_eval_logits(params: dict, images: jnp.ndarray, *,
                    use_scale: bool = False) -> jnp.ndarray:
    """The trained model's float-boundary forward: FAKE_QUANT math in
    eval mode (running BN stats, ±1 values held in float). This is the
    reference the packed engines must reproduce BIT-IDENTICALLY: every
    dot product of ±1 vectors is integer-valued (exact in float32 up to
    K = 2^24), sign conventions agree (``sign(0) := +1`` on both
    paths), and eval BatchNorm applies the very same ``_batchnorm``
    expression — so float-boundary and packed logits are equal floats,
    not approximately equal ones."""
    return bnn_apply(
        params, images,
        BNNConfig(mode=QuantMode.FAKE_QUANT, use_scale=use_scale),
        training=False,
    )


def pack_trained_params(
    params: dict,
    *,
    use_scale: bool = False,
    probe_images: Optional[jnp.ndarray] = None,
    probe_conv_impls: tuple[str, ...] = ("im2col", "direct"),
) -> dict:
    """Export a trained checkpoint into the packed formats every serving
    engine consumes:

      * ``"packed"``     — :func:`pack_bnn_params` (unfused float-boundary
        PACKED path, engines xla/xnor/unpack),
      * ``"fused"``      — :func:`pack_bnn_params_fused` (serving engines
        ``"xla"``/``"xnor"``),
      * ``"megakernel"`` — :func:`pack_bnn_params_megakernel` (serving
        engines ``"megakernel"``/``"megakernel_xla"``).

    With ``probe_images`` the export is VERIFIED before it ships: the
    trained model's float-boundary logits (:func:`bnn_eval_logits`) must
    be bit-identical to the packed logits of all four serving engines
    (x conv_impl for the per-layer fused chain) on the probe batch, per
    the repo's bit-identity contract. A mismatch raises ValueError
    naming the diverging engine — a trained checkpoint that does not
    serve exactly is a bug, not a tolerance.
    """
    import numpy as np

    out = {
        "packed": pack_bnn_params(params, use_scale=use_scale),
        "fused": pack_bnn_params_fused(params, use_scale=use_scale),
        "megakernel": pack_bnn_params_megakernel(params, use_scale=use_scale),
    }
    if probe_images is None:
        return out

    want = np.asarray(bnn_eval_logits(params, probe_images,
                                      use_scale=use_scale))
    got = {
        "packed/xla": np.asarray(bnn_apply(
            out["packed"], probe_images,
            BNNConfig(mode=QuantMode.PACKED, engine="xla",
                      use_scale=use_scale),
        )),
    }
    for engine in ("xla", "xnor"):
        for conv_impl in probe_conv_impls:
            got[f"fused/{engine}/{conv_impl}"] = np.asarray(bnn_apply_fused(
                out["fused"], probe_images, engine=engine,
                conv_impl=conv_impl, use_scale=use_scale,
            ))
    for engine, inner in (("megakernel", "xnor"), ("megakernel_xla", "xla")):
        got[engine] = np.asarray(bnn_apply_megakernel(
            out["megakernel"], probe_images, engine=inner,
            use_scale=use_scale,
        ))
    bad = {k: int((v != want).sum()) for k, v in got.items()
           if not np.array_equal(v, want)}
    if bad:
        raise ValueError(
            "pack_trained_params bit-identity check failed — packed "
            "logits diverge from the trained float-boundary forward on "
            f"the probe batch: {bad} (engine -> #differing logits). "
            "The exported model would not serve what was trained."
        )
    return out


# --- compact sign-form checkpoint (the committable trained artifact) -------
#
# A trained BNN's forward depends on its latent weights ONLY through
# their sign (FAKE_QUANT binarizes every weight matrix, first conv
# included), so a checkpoint meant for SERVING can store 1 bit per
# weight: ~32x smaller than the float latents (the CIFAR net drops from
# ~56 MB to ~1.8 MB — small enough to commit as the golden fixture's
# source of truth). Biases and BatchNorm buffers stay exact float32.
# Loading reconstructs latent weights as ±1.0 floats: since
# sign(sign(w)) == sign(w) (with the sign(0) := +1 convention shared by
# ste_sign and pack_bits), the loaded model's float-boundary AND packed
# forwards are bit-identical to the trained model's. Not for resuming
# training (latent magnitudes and alpha scales are gone); use
# checkpoint/manager.py for that.

BINARY_CKPT_FORMAT = "bnn-sign-v1"


def save_binary_checkpoint(path: str, params: dict) -> None:
    """Write the sign-form checkpoint (.npz). See module note above."""
    import numpy as np

    arrays: dict[str, Any] = {"format": np.asarray(BINARY_CKPT_FORMAT)}
    for group in ("conv", "fc"):
        for i, p in enumerate(params[group]):
            w = np.asarray(p["w"])
            arrays[f"{group}{i}/w_bits"] = np.packbits(
                (w >= 0).reshape(-1)
            )
            arrays[f"{group}{i}/w_shape"] = np.asarray(w.shape)
            if "b" in p:
                arrays[f"{group}{i}/b"] = np.asarray(p["b"], np.float32)
    for group in ("bn_conv", "bn_fc"):
        for i, bn in enumerate(params[group]):
            for k, v in bn.items():
                arrays[f"{group}{i}/{k}"] = np.asarray(v, np.float32)
    np.savez_compressed(path, **arrays)


def load_binary_checkpoint(path: str) -> dict:
    """Load a :func:`save_binary_checkpoint` file back into a params
    pytree with ±1.0 latent weights (see the sign-form note above)."""
    import numpy as np

    with np.load(path) as z:
        if str(z["format"]) != BINARY_CKPT_FORMAT:
            raise ValueError(
                f"{path}: unknown binary checkpoint format {z['format']!r}"
                f" (expected {BINARY_CKPT_FORMAT!r})"
            )
        data = {k: z[k] for k in z.files}

    params: dict[str, Any] = {"conv": [], "bn_conv": [], "fc": [], "bn_fc": []}
    for group in ("conv", "fc"):
        i = 0
        while f"{group}{i}/w_bits" in data:
            shape = tuple(int(s) for s in data[f"{group}{i}/w_shape"])
            n = int(np.prod(shape))
            bits = np.unpackbits(data[f"{group}{i}/w_bits"])[:n]
            w = (bits.astype(np.float32) * 2.0 - 1.0).reshape(shape)
            p = {"w": jnp.asarray(w)}
            if f"{group}{i}/b" in data:
                p["b"] = jnp.asarray(data[f"{group}{i}/b"])
            params[group].append(p)
            i += 1
    for group in ("bn_conv", "bn_fc"):
        i = 0
        while f"{group}{i}/gamma" in data:
            params[group].append({
                k: jnp.asarray(data[f"{group}{i}/{k}"])
                for k in ("gamma", "beta", "mean", "var")
            })
            i += 1
    return params
