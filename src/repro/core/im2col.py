"""im2col / col2im — the paper's §2.1 convolution lowering, in JAX.

Layout convention — THE single source of truth for patch shapes:

Paper Figure 1 draws the GEMM as filter matrix ``[D, kH*kW*C]`` times
patch matrix ``[kH*kW*C, N*OH*OW]``. This module does NOT return that
orientation: :func:`im2col` returns batch-major patches
``[N, OH*OW, kH*kW*C]`` (patch-index leading), which is the natural
layout for XLA to fuse the window slices and for reshaping back through
:func:`col2im`. The paper's orientation appears only at the GEMM call
site: executors in ``repro.core.layers`` flatten to
``x2d = patches.reshape(N*OH*OW, kH*kW*C)`` and transpose THERE
(``x2d.T``) when a kernel wants the ``[K, N*OH*OW]`` operand — that
``.T`` is the one and only transpose point between this module and the
paper's Figure 1.

Within a patch, element index is ``(h*kW + w)*C + c`` — the same
ordering ``filters_to_matrix`` uses, so the two always agree. The same
function handles channel-packed ``int32`` maps (``C`` word columns,
``pad_value=-1``): word index within a patch is then
``(h*kW + w)*CW + cw``, the tap-aligned filter layout of
``repro.core.layers.pack_conv_aligned``.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0,
           pad_value=0):
    """[N, H, W, C] -> patches [N, OH*OW, kH*kW*C] (see module docstring
    for how this maps onto the paper's [kH*kW*C, N*OH*OW] Figure 1
    orientation — callers transpose at the GEMM, not here).

    Static python loop over the (small) kernel window keeps the ordering
    explicit and lets XLA fuse the slices. ``pad_value`` is the border
    fill: 0 for real-valued maps, int32 ``-1`` (all bits set = +1 in the
    sign encoding) when ``x`` holds channel-packed words — the packed
    counterpart of "zero-pad then binarize", since sign(0) := +1.
    """
    n, h, w, c = x.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                    constant_values=pad_value)
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            cols.append(patch)
    # [N, OH, OW, kH*kW, C] -> [N, OH*OW, kH*kW*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(n, oh * ow, kh * kw * c), (oh, ow)


def filters_to_matrix(w: jnp.ndarray) -> jnp.ndarray:
    """[D, kH, kW, C] -> [D, kH*kW*C] matching :func:`im2col` ordering."""
    d = w.shape[0]
    return w.reshape(d, -1)


def col2im(y: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    """GEMM output [N, OH*OW, D] -> feature map [N, OH, OW, D]."""
    n, _, d = y.shape
    return y.reshape(n, oh, ow, d)
