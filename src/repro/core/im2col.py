"""im2col / col2im — the paper's §2.1 convolution lowering, in JAX.

Layout convention (paper Figure 1): a conv between input feature map
``[N, H, W, C]`` and filters ``[D, kH, kW, C]`` becomes a GEMM between
the filter matrix ``[D, kH*kW*C]`` and the patch matrix
``[kH*kW*C, N*OH*OW]``. Row index ``(h*kW + w)*C + c`` — the same
ordering ``filters_to_matrix`` uses, so the two always agree.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0,
           pad_value=0):
    """[N, H, W, C] -> patches [N, OH*OW, kH*kW*C].

    Static python loop over the (small) kernel window keeps the ordering
    explicit and lets XLA fuse the slices. ``pad_value`` is the border
    fill: 0 for real-valued maps, int32 ``-1`` (all bits set = +1 in the
    sign encoding) when ``x`` holds channel-packed words — the packed
    counterpart of "zero-pad then binarize", since sign(0) := +1.
    """
    n, h, w, c = x.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                    constant_values=pad_value)
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            cols.append(patch)
    # [N, OH, OW, kH*kW, C] -> [N, OH*OW, kH*kW*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(n, oh * ow, kh * kw * c), (oh, ow)


def filters_to_matrix(w: jnp.ndarray) -> jnp.ndarray:
    """[D, kH, kW, C] -> [D, kH*kW*C] matching :func:`im2col` ordering."""
    d = w.shape[0]
    return w.reshape(d, -1)


def col2im(y: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    """GEMM output [N, OH*OW, D] -> feature map [N, OH, OW, D]."""
    n, _, d = y.shape
    return y.reshape(n, oh, ow, d)
