"""Binarization math: deterministic sign, straight-through estimator,
XNOR-Net scale factors, and quantization-mode plumbing.

The paper (and BNN [Courbariaux et al. 2016], which it reproduces)
binarizes with ``Sign(x)`` forward and a hard-tanh straight-through
estimator backward; weights keep a latent real value during training and
only the packed 1-bit form is used at inference (paper §4.2, §3.1).
"""

from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp


class QuantMode(str, enum.Enum):
    """How a Bit* layer executes.

    FLOAT        — plain matmul on the latent real weights (control group).
    FAKE_QUANT   — training / "simulation": ±1 values held in float,
                   STE gradients (what released PyTorch BNNs do, §1).
    PACKED       — inference: 1-bit packed int32 weights, xnor-popcount
                   or unpack->MXU contraction (the paper's kernel).
    """

    FLOAT = "float"
    FAKE_QUANT = "fake_quant"
    PACKED = "packed"


@jax.custom_vjp
def ste_sign(x: jnp.ndarray) -> jnp.ndarray:
    """Sign with sign(0) := +1 and hard-tanh STE gradient."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_fwd(x):
    return ste_sign(x), x


def _ste_bwd(x, g):
    # Htanh STE: pass gradient where |x| <= 1 (BNN eq. 4).
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)


def weight_scale(w: jnp.ndarray, axis=-1, keepdims: bool = True) -> jnp.ndarray:
    """XNOR-Net per-output-channel scale: alpha = mean(|W|) along the
    contraction axis. Beyond-paper accuracy refinement; the faithful
    BNN path uses scale == 1."""
    return jnp.mean(jnp.abs(w), axis=axis, keepdims=keepdims)


def binarize_weights(
    w: jnp.ndarray, *, scale_axis: Optional[int] = None
) -> tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Latent weights -> (±1 fake-quant weights, optional alpha scale)."""
    wb = ste_sign(w)
    if scale_axis is None:
        return wb, None
    alpha = jax.lax.stop_gradient(weight_scale(w, axis=scale_axis))
    return wb, alpha


def binarize_activations(x: jnp.ndarray, clip: float = 1.0) -> jnp.ndarray:
    """Htanh then sign, the BNN activation binarization."""
    return ste_sign(jnp.clip(x, -clip, clip))
