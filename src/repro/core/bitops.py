"""Bit-packing and xnor-popcount primitives (pure-JAX reference semantics).

This module is the *semantic* definition of the paper's encoding:

* binary "values" are {-1, +1}; binary "encodings" are {0, 1} with
  ``1 <-> +1`` (paper §3.1),
* 32 one-bit encodings pack into one ``int32`` word, LSB-first along the
  contraction (K) axis,
* ``a_ij = sum_k 2*popcount(xnor(w_ik, x_kj)) - K`` reproduces the exact
  ±1 dot product (paper §3.2).

The Pallas kernels in ``repro.kernels`` implement the same contract for
TPU; everything here is the oracle they are tested against, and the
XLA fallback used inside large jit'd programs (the interpreter-mode
Pallas path cannot live inside a 512-way SPMD program on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

PACK_BITS = 32
PACKED_DTYPE = jnp.int32

__all__ = [
    "PACK_BITS",
    "PACKED_DTYPE",
    "pack_bits",
    "pack_channels",
    "unpack_bits",
    "popcount",
    "xnor_popcount_matmul",
    "packed_matmul_unpack",
    "pad_packed_operands",
    "fused_xnor_layer",
    "direct_conv_dot",
    "direct_conv_oracle",
    "maxpool2_packed",
    "megakernel_chain_xla",
    "conv_stage_xla",
]


def _shift_vector(dtype=PACKED_DTYPE) -> jnp.ndarray:
    return jnp.arange(PACK_BITS, dtype=dtype)


def pack_bits(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pack the sign bits of ``x`` along ``axis`` into int32 words.

    ``x`` holds real numbers; the binarization convention is
    ``bit = 1 if x >= 0 else 0`` (sign(0) := +1, as in BNN training).
    ``x.shape[axis]`` must be a multiple of 32. Bit ``b`` of word ``w``
    encodes element ``w * 32 + b`` (LSB-first).
    """
    axis = axis % x.ndim
    k = x.shape[axis]
    if k % PACK_BITS != 0:
        raise ValueError(f"pack axis length {k} not a multiple of {PACK_BITS}")
    x = jnp.moveaxis(x, axis, -1)
    bits = (x >= 0).astype(PACKED_DTYPE)
    bits = bits.reshape(*x.shape[:-1], k // PACK_BITS, PACK_BITS)
    words = jnp.sum(bits << _shift_vector(), axis=-1).astype(PACKED_DTYPE)
    return jnp.moveaxis(words, -1, axis)


def pack_channels(x: jnp.ndarray, *, pad_value: float = 1.0) -> jnp.ndarray:
    """Channel-pack ``[..., C]`` real values into ``[..., ceil(C/32)]`` words.

    Unlike :func:`pack_bits` this tolerates ``C % 32 != 0``: the tail of
    the last word is filled with the sign bit of ``pad_value`` — ``+1``
    by default, the activation-pad half of the xnor-neutral convention
    (tap-aligned packed weights carry ``-1`` there, see
    ``repro.core.layers.pack_conv_aligned``).
    """
    c = x.shape[-1]
    pad = -c % PACK_BITS
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths, constant_values=pad_value)
    return pack_bits(x, axis=-1)


def unpack_bits(words: jnp.ndarray, axis: int = -1, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`: int32 words -> ±1 values along ``axis``."""
    axis = axis % words.ndim
    w = jnp.moveaxis(words, axis, -1)
    bits = (w[..., None] >> _shift_vector()) & 1
    vals = (2 * bits - 1).astype(dtype)
    vals = vals.reshape(*w.shape[:-1], w.shape[-1] * PACK_BITS)
    return jnp.moveaxis(vals, -1, axis)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Population count on the raw bit pattern (int32-safe)."""
    return lax.population_count(x)


@functools.partial(jax.jit, static_argnames=("k_bits", "block_kw"))
def xnor_popcount_matmul(
    wp: jnp.ndarray, xp: jnp.ndarray, k_bits: int, block_kw: int = 64
) -> jnp.ndarray:
    """Paper §3.2: packed [M, KW] x [KW, N] -> int32 [M, N].

    ``a_ij = 2 * sum_k popcount(~(w_ik ^ x_kj)) - k_bits``.

    Blocked over KW to bound the [M, bkw, N] broadcast intermediate;
    this is the XLA fallback — the Pallas kernel does the same with
    explicit VMEM tiles.
    """
    m, kw = wp.shape
    kw2, n = xp.shape
    assert kw == kw2, (wp.shape, xp.shape)

    nblk = -(-kw // block_kw)
    pad = nblk * block_kw - kw
    if pad:
        # pad pairs (w=0x0, x=~0) xnor to 0 -> contribute zero popcount.
        wp = jnp.pad(wp, ((0, 0), (0, pad)))
        xp = jnp.pad(xp, ((0, pad), (0, 0)), constant_values=-1)

    def body(i, acc):
        wblk = lax.dynamic_slice_in_dim(wp, i * block_kw, block_kw, axis=1)
        xblk = lax.dynamic_slice_in_dim(xp, i * block_kw, block_kw, axis=0)
        xnor = ~(wblk[:, :, None] ^ xblk[None, :, :])
        return acc + jnp.sum(popcount(xnor).astype(jnp.int32), axis=1)

    acc = lax.fori_loop(0, nblk, body, jnp.zeros((m, n), jnp.int32))
    return 2 * acc - jnp.int32(k_bits)


def packed_matmul_unpack(
    wp: jnp.ndarray,
    x: jnp.ndarray,
    *,
    compute_dtype=jnp.bfloat16,
    accum_dtype=jnp.float32,
) -> jnp.ndarray:
    """TPU-native variant: packed weights [M, KW] x real/±1 input [K, N].

    Weights stay packed in HBM (32x footprint win); unpack happens
    on-chip and the contraction runs on the MXU (kernels/unpack_gemm.py
    is the Pallas implementation — packed words are staged HBM->VMEM and
    the unpacked ±1 tile never exists in HBM). The XLA fallback here
    necessarily materializes the unpacked weight, so that traffic is
    scoped vmem_fusible for the roofline: the packed-word reads (the
    REAL HBM traffic) are counted via the w_packed slice reads.
    """
    with jax.named_scope("vmem_fusible"):
        w = unpack_bits(wp, axis=-1, dtype=compute_dtype)
        out = jnp.dot(w, x.astype(compute_dtype),
                      preferred_element_type=accum_dtype)
    return out


@functools.partial(jax.jit, static_argnames=("k_bits", "block_kw"))
def fused_xnor_layer(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    block_kw: int = 64,
) -> jnp.ndarray:
    """Whole fused binary layer, pure-XLA (the oracle for the Pallas
    fused kernel, and the SPMD-safe fallback engine).

    Packed ``wp [M, KW]`` x packed ``xp [KW, N]`` -> packed ``[ceil(M/32), N]``:

        dot  = 2*popcount(xnor) - k_bits        (exact ±1 dot product)
        y    = a*dot + b                         (folded BN/bias/alpha affine)
        bits = y >= 0, repacked along M (LSB-first)

    ``k_bits`` is the TRUE contraction length: bit-level K padding must
    follow the xnor-neutral convention (weight pad bits 0/-1, activation
    pad bits 1/+1 -> zero popcount), so no post-hoc correction is needed.
    M rows beyond ``M`` inside the last output word are padded with +1
    bits — exactly what the next layer's weight-pad correction expects.
    """
    dot = xnor_popcount_matmul(wp, xp, k_bits, block_kw=block_kw)
    y = a[:, None] * dot.astype(a.dtype) + b[:, None]
    pad = -y.shape[0] % PACK_BITS
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)), constant_values=1.0)
    return pack_bits(y, axis=0)


@functools.partial(
    jax.jit, static_argnames=("k_bits", "kh", "kw", "stride", "pad")
)
def direct_conv_dot(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
) -> jnp.ndarray:
    """Direct binary convolution, pure XLA: the ±1 conv dot product
    WITHOUT building the im2col patch matrix.

    ``xp``: channel-packed activations ``[N, H, W, CW]`` (CW words per
    pixel, tail bits +1 when C % 32 != 0 — see :func:`pack_channels`).
    ``wp``: tap-aligned packed filters ``[D, kH*kW*CW]`` (word
    ``(i*kW + j)*CW + cw`` holds tap ``(i, j)``'s channel word ``cw``;
    ``repro.core.layers.pack_conv_aligned`` produces this, and it
    coincides with the flat ``pack_conv_params`` layout when C % 32 == 0).

    Spatial borders pad with all-ones words (``sign(0) := +1``). The
    static loop runs over the kH*kW taps only; each tap contributes a
    strided window slice of the map — the ``[N*OH*OW, kH*kW*CW]`` patch
    matrix of the im2col lowering never exists. ``k_bits`` is the TRUE
    contraction length kH*kW*C. Returns int32 ``[N, OH, OW, D]``.
    """
    from repro.core.im2col import conv_out_size

    n, h, w, cw = xp.shape
    d, kwords = wp.shape
    if kwords != kh * kw * cw:
        raise ValueError(
            f"filter words {kwords} != kh*kw*CW = {kh}*{kw}*{cw} — direct "
            "conv needs tap-aligned packed filters (pack_conv_aligned)"
        )
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    if pad:
        xp = jnp.pad(xp, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                     constant_values=-1)
    wr = wp.reshape(d, kh * kw, cw)
    acc = jnp.zeros((n, oh, ow, d), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            win = lax.slice(
                xp,
                (0, i, j, 0),
                (n, i + stride * (oh - 1) + 1, j + stride * (ow - 1) + 1, cw),
                (1, stride, stride, 1),
            )  # [N, OH, OW, CW]
            tap = wr[:, i * kw + j, :]  # [D, CW]
            xnor = ~(win[..., None, :] ^ tap[None, None, None, :, :])
            acc = acc + jnp.sum(popcount(xnor).astype(jnp.int32), axis=-1)
    return 2 * acc - jnp.int32(k_bits)


@functools.partial(
    jax.jit, static_argnames=("k_bits", "kh", "kw", "stride", "pad")
)
def direct_conv_oracle(
    wp: jnp.ndarray,
    xp: jnp.ndarray,
    k_bits: int,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
) -> jnp.ndarray:
    """Whole fused direct-conv layer, pure XLA (the oracle for the
    Pallas direct kernel, and the SPMD-safe fallback engine).

    :func:`direct_conv_dot` then the PR-1 fused epilogue: per-output-
    channel affine ``a*dot + b`` (folded BN/bias/alpha), sign, repack
    along D (pad channels past D get +1 bits — the next layer's
    activation-pad convention). Same int32 dot and same float op order
    as ``fused_xnor_layer`` on im2col patches, so the two conv_impls
    are bit-identical. Returns packed ``[N, OH, OW, ceil(D/32)]``.
    """
    dot = direct_conv_dot(wp, xp, k_bits, kh=kh, kw=kw, stride=stride,
                          pad=pad)
    y = a.astype(jnp.float32) * dot.astype(jnp.float32) + b.astype(jnp.float32)
    return pack_channels(y)


def maxpool2_packed(xp: jnp.ndarray) -> jnp.ndarray:
    """2x2/stride-2 maxpool on a channel-packed ±1 map ``[N, H, W, CW]``
    = bitwise OR of the four window words: max over {-1, +1} is +1 iff
    any bit is set, and sign is monotone so sign∘max == max∘sign."""
    return (
        xp[:, 0::2, 0::2] | xp[:, 0::2, 1::2]
        | xp[:, 1::2, 0::2] | xp[:, 1::2, 1::2]
    )


def megakernel_chain_xla(
    w_stack: jnp.ndarray,
    a_stack: jnp.ndarray,
    b_stack: jnp.ndarray,
    k_bits: tuple[int, ...],
    xp: jnp.ndarray,
    m_out: int,
    *,
    final_wp: jnp.ndarray = None,
    final_k_bits: int = 0,
) -> jnp.ndarray:
    """Pure-XLA megakernel chain: the oracle for (and SPMD-safe fallback
    of) ``repro.kernels.megakernel.megakernel_chain``.

    Consumes the SAME stacked operands — packed ``w_stack [L, M_max,
    KW_max]`` (pad rows/words zero), folded affines ``a_stack``/
    ``b_stack [L, M_max]`` (pad rows ``a=0, b=+1``), packed ``xp
    [KW_in, N]`` — and runs the layers as a sequence of
    :func:`fused_xnor_layer` calls, re-padding the inter-layer
    activations to all-ones exactly as the kernel's ping-pong scratch
    does, so the stacking/padding conventions themselves are under
    test. Returns packed ``[ceil(m_out/32), N]``, or — when ``final_wp
    [Mf, KWf]`` is given — the final epilogue-free int32 ±1 dot
    ``[Mf, N]`` (:func:`xnor_popcount_matmul` with ``final_k_bits``).
    """
    l, m_max, kw_max = w_stack.shape
    kw_act = max(kw_max, m_max // PACK_BITS)
    pad = kw_act - xp.shape[0]
    act = jnp.pad(xp, ((0, pad), (0, 0)), constant_values=-1) if pad else xp
    for i in range(l):
        # Slice each stacked layer back to its TRUE K words (static —
        # k_bits are python ints): the pad region is xnor-neutral by
        # the stacking convention, so dropping it changes nothing but
        # the op count — mirroring the kernel's dynamic trip counts.
        kw_i = min(kw_max, -(-int(k_bits[i]) // PACK_BITS))
        out = fused_xnor_layer(
            w_stack[i, :, :kw_i], act[:kw_i], int(k_bits[i]),
            a_stack[i], b_stack[i],
        )  # [m_max/32, n]
        fill = kw_act - out.shape[0]
        act = (
            jnp.pad(out, ((0, fill), (0, 0)), constant_values=-1)
            if fill else out
        )
    if final_wp is not None:
        return xnor_popcount_matmul(
            final_wp, act[: final_wp.shape[1]], final_k_bits
        )
    return act[: -(-m_out // PACK_BITS)]


def megakernel_chain_ragged_xla(
    w_stack: jnp.ndarray,
    a_stack: jnp.ndarray,
    b_stack: jnp.ndarray,
    k_bits: tuple[int, ...],
    xp: jnp.ndarray,
    m_out: int,
    n_real: int,
    *,
    final_wp: jnp.ndarray = None,
    final_k_bits: int = 0,
) -> jnp.ndarray:
    """Ragged/masked-tail oracle (DESIGN.md §9): the reference for the
    megakernel's variable-extent batch path.

    ``xp [KW_in, N_pad]`` is a TILE-padded batch (N_pad only rounds the
    true extent ``n_real`` up to the batch-tile multiple, not a bucket
    rung). Runs :func:`megakernel_chain_xla` on the padded batch — pad
    columns are all-ones packed activations, per-sample independent, so
    real columns are untouched — then zeroes every output column at or
    after ``n_real``, exactly as the kernel's tail grid step masks its
    overhang. ``tests/test_megakernel.py`` asserts the kernel against
    this, pad columns included.
    """
    out = megakernel_chain_xla(
        w_stack, a_stack, b_stack, k_bits, xp, m_out,
        final_wp=final_wp, final_k_bits=final_k_bits,
    )
    cols = jnp.arange(out.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(cols < jnp.int32(n_real), out, 0)


def conv_stage_xla(
    xp: jnp.ndarray,
    weights: tuple[jnp.ndarray, ...],
    a: tuple[jnp.ndarray, ...],
    b: tuple[jnp.ndarray, ...],
    k_bits: tuple[int, ...],
    *,
    kh: int = 3,
    kw: int = 3,
    pad: int = 1,
    pool: bool = True,
) -> jnp.ndarray:
    """Pure-XLA conv stage: the oracle for (and SPMD-safe fallback of)
    ``repro.kernels.megakernel.megakernel_conv_stage``.

    Chains :func:`direct_conv_oracle` over the stage's convs (per-layer
    TRUE shapes: tap-aligned ``weights[l] [D_l, kH*kW*CW_l]``, 1-D
    ``a[l]``/``b[l] [D_l]``) and finishes with the packed-OR maxpool.
    Channel-word counts chain exactly: each oracle layer emits
    ``ceil(D_l/32)`` words/pixel with +1 tail bits — the next layer's
    activation-pad convention.
    """
    act = xp
    for wl, al, bl, k in zip(weights, a, b, k_bits):
        act = direct_conv_oracle(
            wl, act, int(k), al, bl, kh=kh, kw=kw, stride=1, pad=pad
        )
    return maxpool2_packed(act) if pool else act


def pad_packed_operands(wp, xp, block_m, block_n, block_kw):
    """Pad packed GEMM operands so every dim tiles evenly.

    K-padding uses the (w=0, x=all-ones) trick so padded words contribute
    zero popcount; M/N padding is sliced off by the caller.
    """
    m, kw = wp.shape
    _, n = xp.shape
    pm = -m % block_m
    pn = -n % block_n
    pk = -kw % block_kw
    if pm or pk:
        wp = jnp.pad(wp, ((0, pm), (0, pk)))
    if pk or pn:
        xp = jnp.pad(xp, ((0, pk), (0, pn)), constant_values=-1)
    return wp, xp, m, n
