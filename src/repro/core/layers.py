"""Functional Bit layers: the paper's kernel as a composable module.

Everything is functional (params are plain pytrees) so the layers nest
into pjit'd programs without a framework dependency. Three execution
modes per layer (``QuantMode``): FLOAT control group, FAKE_QUANT
training with STE, PACKED 1-bit inference.

The PACKED path has two engines:
  * ``engine="xnor"``   — paper-faithful Pallas xnor-popcount kernel
                          (activations binarized + packed on the fly),
  * ``engine="unpack"`` — TPU-native MXU kernel, weight-only packing,
  * ``engine="xla"``    — pure-XLA unpack+dot with packed storage; the
                          only engine usable inside large SPMD programs
                          on this CPU container (HLO still reflects
                          int32 weight traffic, which the roofline reads).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitops
from repro.core.binarize import QuantMode, binarize_activations, binarize_weights
from repro.core.im2col import col2im, filters_to_matrix, im2col
from repro.kernels import ops as kops
from repro.kernels.autotune import AUTO, block_kwargs


@dataclasses.dataclass(frozen=True)
class BitLinearConfig:
    mode: QuantMode = QuantMode.FAKE_QUANT
    binarize_acts: bool = True          # False => weight-only (LM serving)
    use_scale: bool = False             # XNOR-Net alpha (beyond-paper)
    engine: str = "xla"                 # "xnor" | "unpack" | "xla"
    conv_impl: str = "im2col"           # "im2col" | "direct" (PACKED convs)
    compute_dtype: object = jnp.float32
    # "auto" (autotune cache / VMEM heuristic) or a kernels.autotune
    # BlockConfig; forwarded to every Pallas kernel this layer launches.
    blocks: object = AUTO


def init_linear(key, in_features: int, out_features: int, *, bias: bool = True,
                dtype=jnp.float32) -> dict:
    std = (2.0 / in_features) ** 0.5
    p = {"w": jax.random.normal(key, (out_features, in_features), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((out_features,), dtype)
    return p


def pack_linear_params(params: dict, *, use_scale: bool = False) -> dict:
    """Latent float params -> packed inference params (paper §3.1)."""
    w = params["w"]  # [out, in] (or stacked [..., out, in] for MoE experts)
    k = w.shape[-1]
    pad = -k % bitops.PACK_BITS
    widths = [(0, 0)] * (w.ndim - 1) + [(0, pad)]
    wm = jnp.pad(w, widths, constant_values=-1.0) if pad else w
    packed = {"w_packed": bitops.pack_bits(wm, axis=-1)}
    if use_scale:
        packed["alpha"] = jnp.mean(jnp.abs(w), axis=-1)  # [out]
    if "b" in params:
        packed["b"] = params["b"]
    return packed


def _packed_matmul(wp, x2d, k_orig, cfg: BitLinearConfig):
    """x2d: [B, K_orig] real, wp: [out, K_pad/32]. Returns [B, out] float.

    When K_orig isn't a multiple of 32 the packed weights carry
    ``n_pad = K_pad - K_orig`` trailing -1 bits. The xnor engine pads the
    activations with +1 there (each padded position then contributes
    exactly -1 to the ±1 dot product) and adds ``n_pad`` back — an exact
    correction. The unpack engines pad activations with 0 instead, which
    contributes nothing.
    """
    k_pad = wp.shape[1] * bitops.PACK_BITS
    n_pad = k_pad - k_orig
    if cfg.engine == "xnor":
        # Paper path: binarize + pack activations, xnor-popcount GEMM.
        xin = jnp.clip(x2d, -1, 1)
        if n_pad:
            xin = jnp.pad(xin, ((0, 0), (0, n_pad)), constant_values=1.0)
        xp = kops.pack_rows(xin.T)                        # [K_pad/32, B]
        out = kops.xnor_gemm(
            wp, xp, k_pad, **block_kwargs(cfg.blocks)
        )                                                 # [out, B] int32
        out = out + jnp.int32(n_pad)
        return out.T.astype(cfg.compute_dtype)
    # unpack engines: binarize FIRST, then zero-pad — padded positions
    # must stay exactly 0 so the -1 pad weights contribute nothing.
    xin = x2d.astype(cfg.compute_dtype)
    if cfg.binarize_acts:
        xin = jnp.sign(xin) + (xin == 0).astype(cfg.compute_dtype)
    if n_pad:
        xin = jnp.pad(xin, ((0, 0), (0, n_pad)))
    if cfg.engine == "unpack":
        return kops.unpack_gemm(wp, xin.T).T.astype(cfg.compute_dtype)
    # "xla": packed storage, unpack+dot lowered by XLA (SPMD-safe).
    return bitops.packed_matmul_unpack(
        wp, xin.T, compute_dtype=cfg.compute_dtype
    ).T.astype(cfg.compute_dtype)


def bit_linear(params: dict, x: jnp.ndarray, cfg: BitLinearConfig) -> jnp.ndarray:
    """y = x @ W^T (+ b), under the configured quantization mode.

    x: [..., in_features].
    """
    lead = x.shape[:-1]
    k = x.shape[-1]

    if cfg.mode == QuantMode.PACKED:
        wp = params["w_packed"]
        x2d = x.reshape(-1, k)
        y = _packed_matmul(wp, x2d, k, cfg)
        if "alpha" in params:
            y = y * params["alpha"][None, :].astype(y.dtype)
        y = y.reshape(*lead, -1)
    else:
        w = params["w"]
        if cfg.mode == QuantMode.FAKE_QUANT:
            wq, alpha = binarize_weights(
                w, scale_axis=-1 if cfg.use_scale else None
            )
            xq = binarize_activations(x) if cfg.binarize_acts else x
            y = xq @ wq.astype(x.dtype).T
            if alpha is not None:
                y = y * alpha.reshape(1, -1).astype(y.dtype)
        else:  # FLOAT control group
            y = x @ w.astype(x.dtype).T
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Fused packed pipeline — BN-fold + sign + repack epilogue (DESIGN.md §3-4).
# ---------------------------------------------------------------------------

BN_EPS = 1e-4  # the ONE BatchNorm eps; core.bnn._batchnorm imports it


def fold_bn_params(
    bn: dict,
    *,
    bias: Optional[jnp.ndarray] = None,
    alpha: Optional[jnp.ndarray] = None,
    eps: float = BN_EPS,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Collapse inference BatchNorm (+ bias + XNOR-Net alpha) into the
    per-output-channel affine ``(a, b)`` the fused epilogue applies to
    the raw ±1 dot product (DESIGN.md §3):

        y  = alpha*dot + bias                      (layer output)
        z  = (y - mean) * gamma/sqrt(var+eps) + beta   (inference BN)
           = a*dot + b,   a = s*alpha,  b = s*(bias - mean) + beta,
                          s = gamma/sqrt(var+eps).

    ``sign(z)`` only needs ``a*dot + b``, so the float activation never
    has to exist. All inputs/outputs are per-channel vectors [out].
    """
    s = bn["gamma"] * lax.rsqrt(bn["var"] + eps)
    a = s * alpha if alpha is not None else s
    y0 = bias if bias is not None else jnp.zeros_like(s)
    b = s * (y0 - bn["mean"]) + bn["beta"]
    return a.astype(jnp.float32), b.astype(jnp.float32)


def pack_linear_fused(params: dict, bn: dict, *, use_scale: bool = False,
                      eps: float = BN_EPS) -> dict:
    """Pack weights AND fold the layer's BN/bias/alpha into ``(a, b)``."""
    packed = pack_linear_params(params, use_scale=use_scale)
    a, b = fold_bn_params(
        bn, bias=packed.pop("b", None), alpha=packed.pop("alpha", None),
        eps=eps,
    )
    packed["a"], packed["b"] = a, b
    return packed


def pack_conv_fused(params: dict, bn: dict, *, use_scale: bool = False,
                    eps: float = BN_EPS) -> dict:
    """Conv variant of :func:`pack_linear_fused` (same (a, b) math)."""
    packed = pack_conv_params(params, use_scale=use_scale)
    a, b = fold_bn_params(
        bn, bias=packed.pop("b", None), alpha=packed.pop("alpha", None),
        eps=eps,
    )
    packed["a"], packed["b"] = a, b
    return packed


def _fused_dispatch(wp, xpT, k_orig: int, a, b, engine: str,
                    blocks: object = AUTO):
    """[KW, N] packed acts -> [ceil(M/32), N] packed outputs."""
    if engine == "xnor":
        return kops.fused_xnor_gemm(
            wp, xpT, k_orig, a, b, **block_kwargs(blocks)
        )
    if engine == "xla":
        return bitops.fused_xnor_layer(wp, xpT, k_orig, a, b)
    raise ValueError(f"fused path has no engine {engine!r}")


def fused_bit_linear(packed: dict, xp: jnp.ndarray, k_orig: int,
                     *, engine: str = "xnor",
                     blocks: object = AUTO) -> jnp.ndarray:
    """Fused binary FC: packed acts in, packed acts out.

    xp: [batch, KW] int32 words (K-pad bits must be +1, the fused-output
    convention). Returns [batch, ceil(out/32)] int32 words of
    ``sign(a*(x·w) + b)`` — BN already applied via the folded affine.
    ``blocks``: "auto" or a ``kernels.autotune.BlockConfig``.
    """
    out = _fused_dispatch(
        packed["w_packed"], xp.T, k_orig, packed["a"], packed["b"], engine,
        blocks,
    )
    return out.T


def fused_bit_conv2d(
    packed: dict,
    xp: jnp.ndarray,
    k_orig: int,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    engine: str = "xnor",
    conv_impl: str = "im2col",
    blocks: object = AUTO,
) -> jnp.ndarray:
    """Fused binary conv: channel-packed maps in, channel-packed maps out.

    xp: [N, H, W, CW] int32 channel-packed words (CW = ceil(C/32); for
    C % 32 != 0 the tail bits must be +1 and the filters packed
    tap-aligned, see :func:`pack_conv_aligned` — with C % 32 == 0 the
    flat ``pack_conv_params`` layout is already tap-aligned). Spatial
    borders pad with all-ones words — the packed image of "zero-pad then
    sign" since sign(0) := +1. Returns [N, OH, OW, ceil(D/32)].

    ``conv_impl="im2col"`` lowers to the patch-matrix GEMM (paper §2.1);
    ``"direct"`` convolves the packed map in place (DESIGN.md §5) — the
    two are bit-identical on both engines.
    """
    if conv_impl == "direct":
        if engine == "xnor":
            return kops.fused_direct_conv(
                packed["w_packed"], xp, k_orig, packed["a"], packed["b"],
                kh=kh, kw=kw, stride=stride, pad=pad,
                **block_kwargs(blocks, conv=True),
            )
        if engine == "xla":
            return bitops.direct_conv_oracle(
                packed["w_packed"], xp, k_orig, packed["a"], packed["b"],
                kh=kh, kw=kw, stride=stride, pad=pad,
            )
        raise ValueError(f"direct conv has no engine {engine!r}")
    if conv_impl != "im2col":
        raise ValueError(f"unknown conv_impl {conv_impl!r}")
    patches, (oh, ow) = im2col(
        xp, kh, kw, stride=stride, pad=pad, pad_value=jnp.int32(-1)
    )
    n = patches.shape[0]
    kwords = patches.shape[-1]
    x2d = patches.reshape(n * oh * ow, kwords)
    out = _fused_dispatch(
        packed["w_packed"], x2d.T, k_orig, packed["a"], packed["b"], engine,
        blocks,
    )  # [DW, N*OH*OW]
    return col2im(out.T.reshape(n, oh * ow, -1), oh, ow)


# ---------------------------------------------------------------------------
# Megakernel executors — whole stages in one launch (DESIGN.md §8).
# ---------------------------------------------------------------------------

def stack_chain_layers(layers: list[dict]) -> dict:
    """Stack fused-layer params (``{"w_packed" [m, kw], "a", "b" [m]}``)
    into the megakernel chain's padded operands:

    ``{"w": [L, M_max, KW_max], "a": [L, M_max], "b": [L, M_max]}``

    with ``M_max = round_up(max m, 32)`` and ``KW_max = max kw``. Pad
    weight rows/words are zero; pad affine rows are ``a=0, b=+1`` — the
    epilogue then pins the padded output bits to +1, the activation-pad
    convention the next stacked layer's zero weight words consume
    xnor-neutrally (round-trip property-tested in
    ``tests/test_properties.py``).
    """
    m_max = max(
        -(-p["w_packed"].shape[0] // bitops.PACK_BITS) * bitops.PACK_BITS
        for p in layers
    )
    kw_max = max(p["w_packed"].shape[1] for p in layers)
    ws, as_, bs = [], [], []
    for p in layers:
        m, kw = p["w_packed"].shape
        ws.append(jnp.pad(p["w_packed"], ((0, m_max - m), (0, kw_max - kw))))
        as_.append(jnp.pad(p["a"].astype(jnp.float32), (0, m_max - m)))
        bs.append(jnp.pad(p["b"].astype(jnp.float32), (0, m_max - m),
                          constant_values=1.0))
    return {"w": jnp.stack(ws), "a": jnp.stack(as_), "b": jnp.stack(bs)}


def megakernel_fc_chain(
    stack: dict,
    xp: jnp.ndarray,
    k_bits: tuple[int, ...],
    m_out: int,
    *,
    final: Optional[dict] = None,
    final_k: int = 0,
    engine: str = "xnor",
    blocks: object = AUTO,
    ragged: bool = False,
) -> jnp.ndarray:
    """Run a whole FC trunk — stacked fused layers plus (optionally)
    the float-boundary head's GEMM — in one launch.

    ``stack`` comes from :func:`stack_chain_layers`; ``xp`` is
    ``[batch, KW_in]`` packed activations (K-pad bits +1). Without
    ``final``: returns ``[batch, ceil(m_out/32)]`` packed words. With
    ``final`` (a ``pack_linear_params`` dict): returns the head's
    float ``[batch, out]`` — exact int32 ±1 dot computed IN the launch,
    bias/alpha applied here in float, identical math (and identical
    int32 dot) to :func:`packed_act_linear`, so logits stay
    bit-identical to the per-layer chain.

    ``ragged`` (DESIGN.md §9) routes the xnor launch through the
    masked-tail batch path: N pads only to the ``RAGGED_TILE_N``
    sublane tile instead of a full ``block_n`` rung — the variable
    batch extents of continuous-batching dispatch then cost pad work
    proportional to the tile, not the rung. The XLA engine is already
    exact-N, so ``ragged`` is a no-op there; outputs stay bit-identical
    either way.
    """
    from repro.kernels.autotune import megakernel_block_kwargs

    fin_wp = final["w_packed"] if final is not None else None
    if engine == "xnor":
        out = kops.megakernel_chain(
            stack["w"], stack["a"], stack["b"], tuple(k_bits), xp.T, m_out,
            final_wp=fin_wp, final_k_bits=final_k,
            ragged_tile=kops.RAGGED_TILE_N if ragged else None,
            **megakernel_block_kwargs(blocks),
        )
    elif engine == "xla":
        out = bitops.megakernel_chain_xla(
            stack["w"], stack["a"], stack["b"], tuple(k_bits), xp.T, m_out,
            final_wp=fin_wp, final_k_bits=final_k,
        )
    else:
        raise ValueError(f"megakernel has no engine {engine!r}")
    if final is None:
        return out.T
    y = out.T.astype(jnp.float32)
    if "alpha" in final:
        y = y * final["alpha"][None, :].astype(y.dtype)
    if "b" in final:
        y = y + final["b"].astype(y.dtype)
    return y


def megakernel_conv_stage(
    layers: list[dict],
    xp: jnp.ndarray,
    k_bits: tuple[int, ...],
    *,
    kh: int = 3,
    kw: int = 3,
    pad: int = 1,
    pool: bool = True,
    engine: str = "xnor",
    blocks: object = AUTO,
) -> jnp.ndarray:
    """Run one conv stage — the stage's fused binary convs + packed-OR
    maxpool — in one launch (``engine="xnor"``) or via the chained
    pure-XLA direct-conv oracle (``engine="xla"``, SPMD-safe).

    ``layers``: ``pack_conv_fused`` dicts (tap-aligned ``w_packed``,
    folded ``a``/``b``); ``xp``: ``[N, H, W, CW]`` channel-packed map.
    Bit-identical to running :func:`fused_bit_conv2d` per layer and
    ``maxpool2_packed`` — the intermediate maps just never reach HBM.
    """
    from repro.kernels.autotune import megakernel_block_kwargs

    weights = tuple(p["w_packed"] for p in layers)
    a = tuple(p["a"] for p in layers)
    b = tuple(p["b"] for p in layers)
    if engine == "xnor":
        kwargs = megakernel_block_kwargs(blocks)
        kwargs.pop("block_n", None)  # batch grid is per-image already
        return kops.megakernel_conv_stage(
            xp, weights, a, b, tuple(k_bits), kh=kh, kw=kw, pad=pad,
            pool=pool, **kwargs,
        )
    if engine == "xla":
        return bitops.conv_stage_xla(
            xp, weights, a, b, tuple(k_bits), kh=kh, kw=kw, pad=pad,
            pool=pool,
        )
    raise ValueError(f"megakernel has no engine {engine!r}")


def packed_act_linear(packed: dict, xp: jnp.ndarray, k_orig: int,
                      *, engine: str = "xnor",
                      blocks: object = AUTO,
                      compute_dtype=jnp.float32) -> jnp.ndarray:
    """Float-boundary epilogue-free layer for pre-packed activations:
    the chain's LAST layer, whose output (logits) stays float.

    xp: [batch, KW] int32 words. Returns float [batch, out] =
    ``x·w (*alpha) (+bias)`` — identical math (and identical int32 dot)
    to the unfused PACKED path, so logits stay bit-identical.
    """
    wp = packed["w_packed"]
    if engine == "xnor":
        dot = kops.xnor_gemm(wp, xp.T, k_orig, **block_kwargs(blocks))
    elif engine == "xla":
        dot = bitops.xnor_popcount_matmul(wp, xp.T, k_orig)
    else:
        raise ValueError(f"fused path has no engine {engine!r}")
    y = dot.T.astype(compute_dtype)
    if "alpha" in packed:
        y = y * packed["alpha"][None, :].astype(y.dtype)
    if "b" in packed:
        y = y + packed["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Convolution — the paper's actual target layer (im2col forward graph, §2).
# ---------------------------------------------------------------------------

def init_conv(key, kh: int, kw: int, c_in: int, c_out: int, *, bias: bool = True,
              dtype=jnp.float32) -> dict:
    fan_in = kh * kw * c_in
    std = (2.0 / fan_in) ** 0.5
    p = {"w": jax.random.normal(key, (c_out, kh, kw, c_in), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def pack_conv_params(params: dict, *, use_scale: bool = False) -> dict:
    """Filters [D, kH, kW, C] -> bitwise matrix [D, kH*kW*C/32] (§3.1:
    the weight 'manually skips im2col' and is stored packed)."""
    wm = filters_to_matrix(params["w"])
    k = wm.shape[1]
    pad = -k % bitops.PACK_BITS
    if pad:
        # -1-valued pad weights; _packed_matmul compensates exactly.
        wm = jnp.pad(wm, ((0, 0), (0, pad)), constant_values=-1.0)
    packed = {"w_packed": bitops.pack_bits(wm, axis=-1)}
    if use_scale:
        packed["alpha"] = jnp.mean(jnp.abs(wm[:, :k]), axis=-1)
    if "b" in params:
        packed["b"] = params["b"]
    return packed


def _direct_bit_conv2d(params, x, cfg, *, kh, kw, stride, pad):
    """PACKED conv without the im2col lowering (``conv_impl="direct"``).

    Binarizes + channel-packs the input ONCE (``[N, H, W, C/32]``) and
    convolves the packed map directly — the ``[N*OH*OW, kH*kW*C]`` patch
    matrix of the im2col path never exists. Requires C % 32 == 0 so the
    flat ``pack_conv_params`` filter layout coincides with the
    tap-aligned one the window gather walks (for ragged C, pack with
    :func:`pack_conv_aligned` and call the fused executor directly).
    """
    c = x.shape[-1]
    if c % bitops.PACK_BITS != 0:
        raise ValueError(
            f"conv_impl='direct' via bit_conv2d needs C % 32 == 0, got "
            f"C={c}; use conv_impl='im2col' (or pack_conv_aligned + "
            "fused_bit_conv2d)"
        )
    if cfg.engine not in ("xnor", "xla"):
        raise ValueError(
            f"conv_impl='direct' has no engine {cfg.engine!r} "
            "(packed-activation path: 'xnor' | 'xla')"
        )
    xp = bitops.pack_bits(jnp.clip(x, -1, 1), axis=-1)
    k_orig = kh * kw * c
    if cfg.engine == "xnor":
        dot = kops.direct_conv(
            params["w_packed"], xp, k_orig, kh=kh, kw=kw, stride=stride,
            pad=pad, **block_kwargs(cfg.blocks, conv=True),
        )
    else:
        dot = bitops.direct_conv_dot(
            params["w_packed"], xp, k_orig, kh=kh, kw=kw, stride=stride,
            pad=pad,
        )
    y = dot.astype(cfg.compute_dtype)
    if "alpha" in params:
        y = y * params["alpha"].astype(y.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def pack_conv_aligned(params: dict, *, use_scale: bool = False) -> dict:
    """Tap-aligned variant of :func:`pack_conv_params` for C % 32 != 0.

    Each tap's channel block is padded to whole words with -1 weights
    BEFORE packing, so filter word ``(h*kW + w)*ceil(C/32) + cw`` lines
    up with the channel-packed activation words of
    :func:`repro.core.bitops.pack_channels` (tail bits +1 — the pad
    pairs are xnor-neutral, so kernels still take the TRUE
    ``k_bits = kH*kW*C``). Identical to :func:`pack_conv_params` when
    C % 32 == 0. This is the layout the direct-conv kernels and the
    packed-im2col path both consume.
    """
    w = params["w"]  # [D, kH, kW, C]
    d, _, _, c = w.shape
    pad = -c % bitops.PACK_BITS
    wm = (
        jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pad)), constant_values=-1.0)
        if pad else w
    )
    packed = {"w_packed": bitops.pack_bits(wm.reshape(d, -1), axis=-1)}
    if use_scale:
        packed["alpha"] = jnp.mean(jnp.abs(w.reshape(d, -1)), axis=-1)
    if "b" in params:
        packed["b"] = params["b"]
    return packed


def bit_conv2d(
    params: dict,
    x: jnp.ndarray,
    cfg: BitLinearConfig,
    *,
    stride: int = 1,
    pad: int = 0,
    kh: Optional[int] = None,
    kw: Optional[int] = None,
) -> jnp.ndarray:
    """Conv via the paper's forward graph: im2col -> GEMM -> (+bias) -> col2im
    (``cfg.conv_impl="im2col"``), or the direct packed-window kernel that
    skips the patch matrix (``"direct"``, PACKED mode only).

    x: [N, H, W, C]. Returns [N, OH, OW, D].
    """
    if cfg.mode == QuantMode.PACKED:
        assert kh is not None and kw is not None
        if cfg.conv_impl == "direct":
            return _direct_bit_conv2d(
                params, x, cfg, kh=kh, kw=kw, stride=stride, pad=pad
            )
        wp = params["w_packed"]
    else:
        w = params["w"]
        d, kh_, kw_, _ = w.shape
        kh, kw = kh_, kw_

    patches, (oh, ow) = im2col(x, kh, kw, stride=stride, pad=pad)
    n = patches.shape[0]
    pk = patches.shape[-1]
    x2d = patches.reshape(n * oh * ow, pk)  # [NP, K]

    if cfg.mode == QuantMode.PACKED:
        y2d = _packed_matmul(wp, x2d, pk, cfg)
        if "alpha" in params:
            y2d = y2d * params["alpha"][None, :].astype(y2d.dtype)
    else:
        wm = filters_to_matrix(w)
        if cfg.mode == QuantMode.FAKE_QUANT:
            wq, alpha = binarize_weights(
                wm, scale_axis=-1 if cfg.use_scale else None
            )
            xq = binarize_activations(x2d) if cfg.binarize_acts else x2d
            y2d = xq @ wq.astype(x2d.dtype).T
            if alpha is not None:
                y2d = y2d * alpha.reshape(1, -1).astype(y2d.dtype)
        else:
            y2d = x2d @ wm.astype(x2d.dtype).T

    if "b" in params:
        y2d = y2d + params["b"].astype(y2d.dtype)
    return col2im(y2d.reshape(n, oh * ow, -1), oh, ow)
