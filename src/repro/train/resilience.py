"""Chaos-hardened elastic BNN training (DESIGN.md §13).

PR 8 hardened *serving* against faults; this module is the training
half: it wires the dormant ``distributed.fault_tolerance`` machinery
(`HeartbeatMonitor`, `StragglerDetector`, `run_with_recovery`,
`plan_mesh_for`) into the real BNN trainer so a long STE run survives
any injected fault and provably loses nothing:

* :func:`train_bnn_resilient` — the resilient driver. Runs the exact
  same step math as ``train_bnn`` (single device) or
  ``make_dp_train_step`` (a 1-D ``("data",)`` mesh), under
  ``run_with_recovery``: heartbeats each step, straggler eviction, a
  checkpoint cadence that snapshots params + Adam state + the
  per-device sign-SGD error-feedback residuals, and on any failure a
  restore from the latest *valid* checkpoint. Because the data
  pipeline is stateless (batch ``i`` is a pure function of
  ``(data_seed, i)`` — ``data.pipeline.cifar_batch_at``), replayed
  steps recompute the identical updates, so a recovered run's params
  are bit-identical to an uninterrupted run's.
* **Elastic shrink** — on ``WorkerFailure`` (device loss, straggler
  eviction) the driver shrinks to the largest power-of-two surviving
  device count (``plan_mesh_for`` on ``serving_shrink_plan``; powers
  of two keep the global batch divisible), rebuilds the jitted DP step
  for the new mesh, and restores from checkpoint. The dead devices'
  error-feedback residuals are folded into survivor 0
  (:func:`fold_error_feedback`) so compressed-gradient mass is
  conserved — asserted against a float64 reference, not assumed.
* :class:`LossSentinel` — NaN/inf and z-score loss-spike detection on
  the metrics stream. A tripped sentinel raises
  :class:`SentinelRollback`: the poisoned update is discarded, state
  rolls back to the last valid checkpoint, and the run replays — no
  human in the loop. A *sticky* poison (same step trips
  ``max_rollbacks_per_step`` times) gets its batch skipped and the
  event recorded.
* :class:`TrainFaultPlan` — deterministic fault injection mirroring
  ``serve.faults.FaultPlan``, keyed on exact step indices: ``preempt``
  (simulated process kill), ``device_loss``, ``nan_batch``,
  ``loss_spike``, ``straggler`` (inflated step time until the detector
  evicts), and ``torn_ckpt`` (corrupts the checkpoint written at that
  step). Faults are one-shot by default — a replayed step sees the
  clean batch, exactly like a transient production fault — and every
  firing is appended to ``plan.fired``.

``benchmarks/train_chaos.py`` drives a scripted plan against fault-free
controls and gates on bit-identity, EF-mass conservation, sentinel
recall, and bounded recompute (BENCH_train_chaos.json).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt_manager
from repro.core.bnn import init_bnn_params, update_bn_stats
from repro.data.pipeline import DataConfig, cifar_batch_at
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    Preemption,
    StragglerDetector,
    WorkerFailure,
    plan_mesh_for,
    run_with_recovery,
    serving_shrink_plan,
)
from repro.train.bnn_trainer import (
    BNNTrainerConfig,
    _BNNTask,
    bnn_clip_predicate,
    evaluate_bnn,
    init_dp_error_feedback,
    make_dp_train_step,
)
from repro.train.step import TrainConfig, init_opt_state, make_train_step

__all__ = [
    "TRAIN_FAULT_KINDS",
    "TrainFaultSpec",
    "TrainFaultPlan",
    "LossSentinel",
    "SentinelRollback",
    "ResilienceConfig",
    "ResilientTrainResult",
    "fold_error_feedback",
    "train_bnn_resilient",
]


# ---------------------------------------------------------------------------
# deterministic fault injection (serve/faults.py::FaultPlan, step-keyed)
# ---------------------------------------------------------------------------


TRAIN_FAULT_KINDS = (
    "preempt",       # simulated process kill before executing the step
    "device_loss",   # WorkerFailure([host]) -> elastic shrink
    "nan_batch",     # the step's batch is poisoned to all-NaN images
    "loss_spike",    # the step's images are scaled by `scale`
    "straggler",     # host `host` reports 10x step times for `count` steps
    "torn_ckpt",     # the checkpoint written at step `at` is corrupted
)

_TORN_FLAVORS = ("torn", "corrupt")


@dataclasses.dataclass(frozen=True)
class TrainFaultSpec:
    """One scheduled training fault, pinned to an exact step index.

    ``kind`` is one of :data:`TRAIN_FAULT_KINDS`. Step-time faults fire
    on ``at <= step < at + count``; ``torn_ckpt`` fires on the
    checkpoint *written at* step ``at`` (``flavor="torn"`` deletes the
    MANIFEST — a crash mid-write; ``"corrupt"`` appends junk to the
    shard — bit rot caught by the checksum). ``sticky`` faults re-fire
    when their step is replayed after a rollback (the default one-shot
    behavior models a transient fault: the replay sees clean data).
    """

    kind: str
    at: int
    count: int = 1
    host: int = 0
    scale: float = 64.0
    sticky: bool = False
    flavor: str = "torn"

    def __post_init__(self):
        if self.kind not in TRAIN_FAULT_KINDS:
            raise ValueError(f"unknown train fault kind {self.kind!r}; "
                             f"expected one of {TRAIN_FAULT_KINDS}")
        if self.flavor not in _TORN_FLAVORS:
            raise ValueError(f"unknown torn_ckpt flavor {self.flavor!r}; "
                             f"expected one of {_TORN_FLAVORS}")
        if self.at < 0 or self.count < 1:
            raise ValueError("need at >= 0 and count >= 1")


class TrainFaultPlan:
    """A deterministic schedule of :class:`TrainFaultSpec` entries.

    ``match(step)`` returns the first step-time spec covering ``step``
    that has not yet fired there (first match wins; non-``sticky``
    (spec, step) pairs fire at most once, so a rollback replay sees the
    clean step). ``match_save(step)`` is the same for ``torn_ckpt``
    specs, keyed on the save step. Every firing is appended to
    ``fired`` so harnesses can assert the realized schedule.
    """

    def __init__(self, specs: Sequence[TrainFaultSpec] = ()):
        self.specs = tuple(specs)
        self.fired: list[dict] = []
        self._consumed: set[tuple[int, int]] = set()

    def _match(self, step: int, *, save: bool) -> Optional[TrainFaultSpec]:
        for j, spec in enumerate(self.specs):
            if (spec.kind == "torn_ckpt") != save:
                continue
            if not spec.at <= step < spec.at + spec.count:
                continue
            key = (j, step)
            if not spec.sticky and key in self._consumed:
                continue
            self._consumed.add(key)
            return spec
        return None

    def match(self, step: int) -> Optional[TrainFaultSpec]:
        return self._match(step, save=False)

    def match_save(self, step: int) -> Optional[TrainFaultSpec]:
        return self._match(step, save=True)

    def on_fire(self, step: int, spec: TrainFaultSpec) -> None:
        self.fired.append({"step": step, "kind": spec.kind,
                           "host": spec.host})

    def steps_of(self, kind: str) -> list[int]:
        """Every step index a spec of ``kind`` is scheduled to fire at."""
        return sorted(
            s for spec in self.specs if spec.kind == kind
            for s in range(spec.at, spec.at + spec.count)
        )


# ---------------------------------------------------------------------------
# loss sentinel
# ---------------------------------------------------------------------------


class SentinelRollback(WorkerFailure):
    """Raised by the driver when the :class:`LossSentinel` trips: the
    just-applied update is poisoned (NaN/inf or a loss spike) and must
    be rolled back to the last valid checkpoint. No devices died, so
    ``hosts`` is empty — ``run_with_recovery`` takes the plain
    restore path."""

    def __init__(self, step: int, verdict: str):
        RuntimeError.__init__(
            self, f"loss sentinel tripped at step {step}: {verdict}")
        self.hosts: list[int] = []
        self.step = step
        self.verdict = verdict


class LossSentinel:
    """NaN/inf + z-score loss-spike detection on the metrics stream.

    ``check(step, loss)`` returns ``"nan"`` for a non-finite loss,
    ``"spike"`` when ``loss > mean + z * max(std, rel_floor * |mean|,
    1e-3)`` over the trailing ``window`` of accepted losses (only
    checked once ``min_history`` losses are in), else ``None`` — and
    only a clean loss is admitted into the history, so a poisoned step
    can never drag the baseline toward itself. Every trip is recorded
    in ``events``.

    The floor terms keep a flat early-loss window (std ~ 0) from
    tripping on normal noise; z defaults high because the sentinel's
    job is catching *divergence* (a poisoned batch, an optimizer
    blow-up), not ordinary variance.
    """

    def __init__(self, *, window: int = 16, z: float = 8.0,
                 min_history: int = 4, rel_floor: float = 0.05):
        self.window = int(window)
        self.z = float(z)
        self.min_history = int(min_history)
        self.rel_floor = float(rel_floor)
        self._hist: deque = deque(maxlen=self.window)
        self.events: list[dict] = []

    def check(self, step: int, loss: float,
              grad_norm: Optional[float] = None) -> Optional[str]:
        verdict = None
        if not np.isfinite(loss):
            verdict = "nan"
        elif grad_norm is not None and not np.isfinite(grad_norm):
            # Loss-only detection has a blind spot: the BNN's where()-
            # based binarization maps NaN activations to -1, so a NaN
            # *batch* yields a finite garbage-input loss (~log C) while
            # the backward pass is NaN — the update poisons the params
            # without the loss ever going non-finite. The gradient norm
            # sees the backward pass, so it catches what the loss hides.
            verdict = "nan"
        elif len(self._hist) >= self.min_history:
            vals = np.asarray(self._hist, dtype=np.float64)
            mu = float(vals.mean())
            sd = float(vals.std())
            floor = max(sd, self.rel_floor * abs(mu), 1e-3)
            if loss > mu + self.z * floor:
                verdict = "spike"
        if verdict is not None:
            self.events.append({
                "step": int(step), "kind": verdict, "loss": float(loss),
                "grad_norm": None if grad_norm is None else float(grad_norm),
            })
            return verdict
        self._hist.append(float(loss))
        return None


# ---------------------------------------------------------------------------
# error-feedback folding across an elastic resize
# ---------------------------------------------------------------------------


def fold_error_feedback(err, n_new: int):
    """Resize a stacked ``[n_old, ...]`` error-feedback residual tree to
    ``n_new`` shards, conserving total residual mass.

    Shrink: dead shards' residuals (rows ``n_new:``) are summed and
    folded into survivor 0 — the quantization error those shards were
    still owed re-enters the compressed all-reduce through the
    survivor's next round instead of silently vanishing. Grow: new
    shards start with zero residual (they are owed nothing).

    Returns ``(folded, report)`` where ``report`` carries a float64
    conservation check: per-leaf ``|sum(folded) - sum(err)|`` and its
    maximum relative to the leaf's L1 mass. The only deltas are float32
    re-association rounding in the fold itself, so the driver asserts
    ``max_rel_delta`` under a tight tolerance — conservation is
    checked, not assumed.
    """
    # restored checkpoints hand back plain numpy leaves; the fold uses
    # jnp indexed-update, so normalize first
    err = jax.tree.map(jnp.asarray, err)
    leaves = jax.tree.leaves(err)
    n_old = int(leaves[0].shape[0]) if leaves else int(n_new)
    if n_new < 1:
        raise ValueError(f"n_new must be >= 1, got {n_new}")

    if n_old == n_new:
        folded = err
    elif n_new > n_old:
        folded = jax.tree.map(
            lambda e: jnp.concatenate(
                [e, jnp.zeros((n_new - n_old,) + e.shape[1:], e.dtype)]),
            err,
        )
    else:
        folded = jax.tree.map(
            lambda e: e[:n_new].at[0].add(jnp.sum(e[n_new:], axis=0)), err
        )

    max_abs = 0.0
    max_rel = 0.0
    mass_l1 = 0.0
    for old_leaf, new_leaf in zip(jax.tree.leaves(err),
                                  jax.tree.leaves(folded)):
        old64 = np.asarray(old_leaf).astype(np.float64)
        new64 = np.asarray(new_leaf).astype(np.float64)
        delta = abs(float(new64.sum()) - float(old64.sum()))
        l1 = float(np.abs(old64).sum())
        mass_l1 += l1
        max_abs = max(max_abs, delta)
        max_rel = max(max_rel, delta / max(l1, 1e-12))
    report = {
        "n_old": n_old,
        "n_new": int(n_new),
        "mass_l1": mass_l1,
        "max_abs_delta": max_abs,
        "max_rel_delta": max_rel,
    }
    return folded, report


# ---------------------------------------------------------------------------
# cached step builders — replays and repeated harness runs must not retrace
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _single_device_step(task: _BNNTask, tcfg: TrainConfig):
    return jax.jit(make_train_step(task, tcfg,
                                   clip_predicate=bnn_clip_predicate))


@functools.lru_cache(maxsize=None)
def _dp_step(task: _BNNTask, tcfg: TrainConfig, n_devices: int,
             grad_compression: str):
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:n_devices]), ("data",))
    return jax.jit(make_dp_train_step(
        task, tcfg, mesh, grad_compression=grad_compression,
        clip_predicate=bnn_clip_predicate,
    ))


@functools.lru_cache(maxsize=None)
def _ema_step(momentum: float):
    return jax.jit(functools.partial(update_bn_stats, momentum=momentum))


def _fingerprint(tree) -> str:
    """sha256 over the tree's leaf keys + raw bytes — the bit-identity
    currency of the chaos gates (two runs agree iff every param leaf is
    bit-for-bit equal)."""
    h = hashlib.sha256()
    for key, leaf in ckpt_manager._leaf_paths(tree):
        h.update(key.encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# the resilient driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    max_restarts: int = 16
    keep_checkpoints: int = 8
    sentinel_window: int = 16
    sentinel_z: float = 8.0
    sentinel_min_history: int = 4
    # a step that trips the sentinel this many times is a sticky poison:
    # skip its batch (recorded) instead of rolling back forever
    max_rollbacks_per_step: int = 2
    straggler_z: float = 3.0
    straggler_patience: int = 3
    heartbeat_timeout_s: float = 3600.0
    ef_conservation_rtol: float = 1e-5


@dataclasses.dataclass
class ResilientTrainResult:
    params: Any
    opt_state: Any
    err: Any                    # EF residual tree, [n_devices, ...] leaves
    history: dict               # {"loss": [...], "acc": [...], "lr_scale": [...]}
    events: list                # faults, rollbacks, shrinks, folds, skips
    fingerprints: dict          # checkpoint step -> params sha256
    restore_points: list        # [{"step", "params_sha"}] per restore
    recomputed_steps: int       # replayed work across all recoveries
    device_trajectory: list     # [(step, n_devices)] incl. the start
    n_devices: int              # final mesh size
    skipped_steps: list         # sticky-poison batches dropped
    eval_loss: Optional[float]
    eval_acc: Optional[float]


def train_bnn_resilient(
    cfg: BNNTrainerConfig,
    *,
    resilience: ResilienceConfig = ResilienceConfig(),
    faults: Optional[TrainFaultPlan] = None,
    n_devices: int = 1,
    grad_compression: str = "signsgd",
    verbose: bool = False,
) -> ResilientTrainResult:
    """Train the CIFAR BNN under ``run_with_recovery``: heartbeat checks
    and straggler eviction each step, checkpoint cadence
    ``cfg.checkpoint_every`` (params + Adam state + EF residuals), loss
    sentinel with rollback, and elastic shrink on device loss.

    Single-device (``n_devices=1``) runs use the exact ``train_bnn``
    step math — a fault-free resilient run is bit-identical to
    ``train_bnn`` — and multi-device runs use ``make_dp_train_step``
    over a 1-D ``("data",)`` mesh with ``grad_compression``. A fresh
    process pointed at the same ``checkpoint_dir`` resumes from the
    latest valid checkpoint, which is what makes a REAL preemption
    (process kill) recoverable, not just the simulated one.
    """
    if not cfg.checkpoint_dir:
        raise ValueError(
            "train_bnn_resilient needs cfg.checkpoint_dir: rollback and "
            "preemption recovery restore from checkpoints, so a run "
            "without a checkpoint directory cannot be made resilient"
        )
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if n_devices > jax.device_count():
        raise ValueError(
            f"n_devices={n_devices} but only {jax.device_count()} jax "
            f"devices are visible; off-TPU, force simulated host devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_devices} before jax initializes"
        )
    if cfg.batch % n_devices:
        raise ValueError(
            f"global batch {cfg.batch} is not divisible by "
            f"n_devices={n_devices}"
        )

    task = _BNNTask(cfg.model_config())
    tcfg = cfg.train_config()
    data_cfg = DataConfig(seed=cfg.data_seed, global_batch=cfg.batch)
    ema = _ema_step(cfg.bn_momentum)
    cadence = max(1, int(cfg.checkpoint_every))

    sentinel = LossSentinel(
        window=resilience.sentinel_window, z=resilience.sentinel_z,
        min_history=resilience.sentinel_min_history,
    )
    detector = StragglerDetector(z=resilience.straggler_z,
                                 patience=resilience.straggler_patience)
    monitor = HeartbeatMonitor(num_hosts=n_devices,
                               timeout=resilience.heartbeat_timeout_s)

    def fresh_state(n: int) -> dict:
        params = init_bnn_params(jax.random.PRNGKey(cfg.seed))
        return {
            "params": params,
            "opt": init_opt_state(params),
            "err": init_dp_error_feedback(params, n),
        }

    st = {
        "state": fresh_state(n_devices),
        "live": list(range(n_devices)),
        "n": n_devices,
        "fail_step": None,
        "rollbacks_at": {},
    }
    history: dict[int, dict] = {}
    events: list[dict] = []
    fingerprints: dict[int, str] = {}
    restore_points: list[dict] = []
    skip_steps: set[int] = set()
    device_trajectory: list[tuple[int, int]] = [(0, n_devices)]
    recomputed = {"steps": 0}

    def step_callable():
        if st["n"] == 1:
            return _single_device_step(task, tcfg)
        return _dp_step(task, tcfg, st["n"], grad_compression)

    def step_fn(step: int) -> dict:
        spec = faults.match(step) if faults is not None else None
        if spec is not None and spec.kind == "preempt":
            faults.on_fire(step, spec)
            events.append({"kind": "preempt", "step": step})
            raise Preemption(step)
        if spec is not None and spec.kind == "device_loss":
            faults.on_fire(step, spec)
            events.append({"kind": "device_loss", "step": step,
                           "host": spec.host})
            raise WorkerFailure([spec.host])

        if step in skip_steps:
            events.append({"kind": "skipped_batch", "step": step})
            return {"skipped": True, "step": step}

        batch = cifar_batch_at(data_cfg, step)
        feed = {"images": batch["images"], "labels": batch["labels"]}
        if spec is not None and spec.kind == "nan_batch":
            faults.on_fire(step, spec)
            events.append({"kind": "nan_batch", "step": step})
            feed["images"] = jnp.full_like(feed["images"], jnp.nan)
        elif spec is not None and spec.kind == "loss_spike":
            faults.on_fire(step, spec)
            events.append({"kind": "loss_spike", "step": step,
                           "scale": spec.scale})
            # A pure image rescale is absorbed exactly by BatchNorm
            # (conv is linear; BN normalizes with the poisoned batch's
            # own statistics), so the poison that actually moves the
            # loss is mislabeled signal: rotate every label half the
            # class circle. The rescale rides along as a realistic
            # corruption artifact.
            half = data_cfg.num_classes // 2
            feed["images"] = feed["images"] * spec.scale
            feed["labels"] = (feed["labels"] + half) % data_cfg.num_classes

        state = st["state"]
        if st["n"] == 1:
            params, opt, metrics = step_callable()(
                state["params"], state["opt"], feed)
            err = state["err"]
        else:
            params, opt, err, metrics = step_callable()(
                state["params"], state["opt"], state["err"], feed)
        params = ema(params, metrics.pop("bn_stats"))
        st["state"] = {"params": params, "opt": opt, "err": err}
        loss = float(metrics["loss"])

        # Straggler eviction: every live host reports a step time; an
        # injected straggler reports 10x until the detector's patience
        # runs out, then is evicted like a dead worker. All "hosts" here
        # are simulated by ONE process, so the real wall clock carries
        # no per-host signal — worse, its shared-CPU noise (GC pauses,
        # neighbor load) exceeds the detector's 5% band and can flag the
        # whole uniform fleet at once. Healthy hosts therefore report a
        # synthetic unit time, which is exactly the detector's contract:
        # relative per-host step times.
        times = {h: 1.0 for h in st["live"]}
        if spec is not None and spec.kind == "straggler":
            faults.on_fire(step, spec)
            times[spec.host] = 10.0
        flagged = detector.observe(times)
        if flagged:
            events.append({"kind": "straggler_evicted", "step": step,
                           "hosts": sorted(flagged)})
            raise WorkerFailure(flagged)

        for h in st["live"]:
            monitor.beat(h)

        verdict = sentinel.check(step, loss,
                                 grad_norm=float(metrics["grad_norm"]))
        if verdict is not None:
            count = st["rollbacks_at"].get(step, 0) + 1
            st["rollbacks_at"][step] = count
            events.append({"kind": f"sentinel_{verdict}", "step": step,
                           "loss": loss, "rollback": count})
            if count >= resilience.max_rollbacks_per_step:
                skip_steps.add(step)
                events.append({"kind": "poisoned_window_skipped",
                               "step": step})
            raise SentinelRollback(step, verdict)

        history[step] = {"loss": loss, "acc": float(metrics["acc"]),
                         "lr_scale": float(metrics["lr_scale"])}
        if verbose and (step % cfg.log_every == 0 or step == cfg.steps - 1):
            print(f"step {step:4d} loss {loss:.4f} "
                  f"acc {history[step]['acc']:.3f} n_dev {st['n']}")
        return {"loss": loss, "step": step}

    def save_fn(step: int) -> None:
        # Defense in depth behind the sentinel: a poisoned update that
        # somehow kept both loss and grad_norm finite must still never
        # reach disk — a non-finite checkpoint would turn every later
        # rollback into a restore of the poison itself.
        bad = [
            k for k, leaf in ckpt_manager._leaf_paths(st["state"]["params"])
            if not np.isfinite(np.asarray(leaf)).all()
        ]
        if bad:
            events.append({"kind": "poisoned_checkpoint_averted",
                           "step": step, "leaves": bad[:8]})
            raise SentinelRollback(step, "nonfinite_params")
        fingerprints[step] = _fingerprint(st["state"]["params"])
        path = ckpt_manager.save(cfg.checkpoint_dir, step, st["state"])
        spec = faults.match_save(step) if faults is not None else None
        if spec is not None:
            faults.on_fire(step, spec)
            events.append({"kind": "torn_ckpt", "step": step,
                           "flavor": spec.flavor})
            if spec.flavor == "torn":
                os.remove(os.path.join(path, "MANIFEST.json"))
            else:
                shard = os.path.join(path, "shard_00000.npz")
                with open(shard, "ab") as f:
                    f.write(b"\x00corruption")
        ckpt_manager.retain(cfg.checkpoint_dir,
                            keep=resilience.keep_checkpoints)

    def restore_fn() -> int:
        latest = ckpt_manager.latest_valid_step(cfg.checkpoint_dir)
        if latest is None:
            st["state"] = fresh_state(st["n"])
            restored = 0
            if st["fail_step"] is not None:
                events.append({"kind": "restored_fresh", "step": 0})
        else:
            tree = ckpt_manager.restore(
                cfg.checkpoint_dir, latest, st["state"])
            err = tree["err"]
            n_saved = int(jax.tree.leaves(err)[0].shape[0])
            if n_saved != st["n"]:
                err, report = fold_error_feedback(err, st["n"])
                if report["max_rel_delta"] > resilience.ef_conservation_rtol:
                    raise RuntimeError(
                        f"error-feedback mass NOT conserved folding "
                        f"{n_saved} -> {st['n']} shards: relative delta "
                        f"{report['max_rel_delta']:.3e} exceeds "
                        f"{resilience.ef_conservation_rtol:.1e} "
                        f"(report: {report})"
                    )
                events.append({"kind": "ef_folded", "step": latest,
                               **report})
            st["state"] = {"params": tree["params"], "opt": tree["opt"],
                           "err": err}
            restored = latest
            restore_points.append({
                "step": latest,
                "params_sha": _fingerprint(tree["params"]),
            })
        if st["fail_step"] is not None:
            recomputed["steps"] += max(0, st["fail_step"] - restored)
            st["fail_step"] = None
        for s in [s for s in history if s >= restored]:
            del history[s]
        return restored

    def on_failure(failure: WorkerFailure, step: int) -> None:
        st["fail_step"] = step

    def rebuild_fn(dead_hosts: Sequence[int]) -> None:
        if not dead_hosts:
            return  # preemption / sentinel rollback: no mesh change
        st["live"] = [h for h in st["live"] if h not in set(dead_hosts)]
        if not st["live"]:
            raise RuntimeError("no surviving devices to rebuild a mesh")
        n_new = serving_shrink_plan(len(st["live"]))
        plan = plan_mesh_for(n_new)
        n_new = plan.num_devices
        if cfg.batch % n_new:
            raise RuntimeError(
                f"cannot shrink to {n_new} devices: global batch "
                f"{cfg.batch} is not divisible"
            )
        events.append({"kind": "elastic_shrink", "step": st["fail_step"],
                       "from": st["n"], "to": n_new,
                       "survivors": len(st["live"]),
                       "plan": {"shape": list(plan.shape),
                                "axes": list(plan.axes)}})
        st["n"] = n_new

    final_metrics = run_with_recovery(
        num_steps=cfg.steps,
        step_fn=step_fn,
        save_fn=save_fn,
        restore_fn=restore_fn,
        monitor=monitor,
        rebuild_fn=rebuild_fn,
        checkpoint_every=cadence,
        max_restarts=resilience.max_restarts,
        on_failure=on_failure,
    )
    del final_metrics  # per-step metrics live in `history`
    if cfg.steps % cadence != 0:
        save_fn(cfg.steps)
    else:
        fingerprints.setdefault(
            cfg.steps, _fingerprint(st["state"]["params"]))
    for step, n in [(e["step"], e["to"]) for e in events
                    if e["kind"] == "elastic_shrink"]:
        device_trajectory.append((step, n))

    eval_loss = eval_acc = None
    if cfg.eval_batches > 0:
        eval_iter = (cifar_batch_at(data_cfg, s)
                     for s in range(cfg.steps, cfg.steps + cfg.eval_batches))
        eval_loss, eval_acc = evaluate_bnn(
            st["state"]["params"], eval_iter, batches=cfg.eval_batches,
            use_scale=cfg.use_scale,
        )

    ordered = sorted(history)
    return ResilientTrainResult(
        params=st["state"]["params"],
        opt_state=st["state"]["opt"],
        err=st["state"]["err"],
        history={
            "loss": [history[s]["loss"] for s in ordered],
            "acc": [history[s]["acc"] for s in ordered],
            "lr_scale": [history[s]["lr_scale"] for s in ordered],
        },
        events=events,
        fingerprints=fingerprints,
        restore_points=restore_points,
        recomputed_steps=recomputed["steps"],
        device_trajectory=device_trajectory,
        n_devices=st["n"],
        skipped_steps=sorted(skip_steps),
        eval_loss=eval_loss,
        eval_acc=eval_acc,
    )
