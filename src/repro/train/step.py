"""pjit-able train / serve step factories.

``make_train_step(model, opt_cfg)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` function:
value_and_grad over the model loss (remat'd scan inside), global-norm
clip, AdamW with latent-weight clipping (BNN training detail), optional
microbatch gradient accumulation (scan over microbatches — the
activation-memory knob), optional error-feedback int8 gradient
compression on the data-parallel axis (see distributed/compression.py
for scope notes).

``make_decode_step`` / ``make_prefill`` wrap the model's serving
functions — these are what the decode/prefill dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model_factory import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig(lr=3e-4, weight_decay=0.01, latent_clip=True)
    clip_norm: float = 1.0
    microbatches: int = 1          # >1 => gradient accumulation
    warmup_steps: int = 100
    total_steps: int = 10_000


def make_train_step(model: Model, tcfg: TrainConfig):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        step = opt_state["adam"]["count"]
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                loss, metrics, grads = grads_of(params, mb)
                return (jax.tree.map(jnp.add, acc, grads),), (loss, metrics)

            mbs = jax.tree.map(
                lambda t: t.reshape(tcfg.microbatches,
                                    t.shape[0] // tcfg.microbatches,
                                    *t.shape[1:]),
                batch,
            )
            zero = jax.tree.map(jnp.zeros_like, params)
            (gsum,), (losses, _) = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = jnp.mean(losses)
        else:
            loss, _, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr_scale = cosine_schedule(
            step, warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps
        )
        new_params, new_adam = adamw_update(
            grads, opt_state["adam"], params, tcfg.adamw, lr_scale=lr_scale
        )
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return new_params, {"adam": new_adam}, metrics

    return train_step


def init_opt_state(params) -> dict:
    return {"adam": adamw_init(params)}


def make_prefill(model: Model):
    def prefill_step(params, state, batch):
        return model.prefill(params, state, batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, state, batch):
        return model.decode_step(params, state, batch)

    return decode_step
