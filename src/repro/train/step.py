"""pjit-able train / serve step factories.

``make_train_step(model, opt_cfg)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` function:
value_and_grad over the model loss (remat'd scan inside), global-norm
clip, AdamW with latent-weight clipping (BNN training detail), optional
microbatch gradient accumulation (scan over microbatches — the
activation-memory knob). The model's own loss metrics (accuracy, BN
batch statistics, ...) ride along in the returned ``metrics`` dict —
averaged over microbatches when accumulating — so BNN trainers can
maintain running BatchNorm statistics without a second forward pass
(train/bnn_trainer.py). ``clip_predicate`` selects which param leaves
the optimizer's latent clip applies to (the binarized latent weights).

The schedule is fed the POST-increment optimizer step (``count + 1``):
``cosine_schedule(0)`` returns 0.0 during warmup, so feeding the
pre-increment count would multiply the very first update by a zero
learning rate — an entire wasted accumulated batch when
``microbatches > 1`` (regression-tested in tests/test_train.py).

``make_decode_step`` / ``make_prefill`` wrap the model's serving
functions — these are what the decode/prefill dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_factory import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig(lr=3e-4, weight_decay=0.01, latent_clip=True)
    clip_norm: float = 1.0
    microbatches: int = 1          # >1 => gradient accumulation
    warmup_steps: int = 100
    total_steps: int = 10_000


def _split_microbatches(batch, microbatches: int):
    """Reshape every array leaf ``[B, ...] -> [microbatches, B/mb, ...]``.

    Raises an actionable ValueError instead of letting a bare reshape
    die with a cryptic shape error (or, worse, silently mis-split a
    leaf whose leading dim differs from the batch size).
    """
    leaves = jax.tree_util.tree_leaves_with_path(batch)
    if not leaves:
        raise ValueError("empty batch")
    sizes = {jax.tree_util.keystr(path): jnp.shape(leaf)[0] if jnp.ndim(leaf) else None
             for path, leaf in leaves}
    dims = set(sizes.values())
    if None in dims or len(dims) != 1:
        raise ValueError(
            f"gradient accumulation needs every batch leaf to share one "
            f"leading batch dim; got leading dims {sizes} (drop scalar "
            f"bookkeeping keys like 'step' before the train step)"
        )
    (bsz,) = dims
    if bsz % microbatches != 0:
        raise ValueError(
            f"batch size {bsz} is not divisible by "
            f"tcfg.microbatches={microbatches}; pick a batch size that "
            f"is a multiple of the microbatch count"
        )
    return jax.tree.map(
        lambda t: t.reshape(microbatches, bsz // microbatches, *t.shape[1:]),
        batch,
    )


def make_train_step(model: Model, tcfg: TrainConfig,
                    clip_predicate: Optional[Callable] = None):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        # post-increment step: adamw_update below runs with count+1, and
        # cosine_schedule(0) == 0.0 — the pre-increment count would make
        # the first optimizer step a no-op (warmup off-by-one).
        step = opt_state["adam"]["count"] + 1
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                loss, metrics, grads = grads_of(params, mb)
                return (jax.tree.map(jnp.add, acc, grads),), (loss, metrics)

            mbs = _split_microbatches(batch, tcfg.microbatches)
            zero = jax.tree.map(jnp.zeros_like, params)
            (gsum,), (losses, mmetrics) = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = jnp.mean(losses)
            model_metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0),
                                         mmetrics)
        else:
            loss, model_metrics, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr_scale = cosine_schedule(
            step, warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps
        )
        new_params, new_adam = adamw_update(
            grads, opt_state["adam"], params, tcfg.adamw, lr_scale=lr_scale,
            clip_predicate=clip_predicate,
        )
        metrics = {**model_metrics,
                   "loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return new_params, {"adam": new_adam}, metrics

    return train_step


def init_opt_state(params) -> dict:
    return {"adam": adamw_init(params)}


def make_prefill(model: Model):
    def prefill_step(params, state, batch):
        return model.prefill(params, state, batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, state, batch):
        return model.decode_step(params, state, batch)

    return decode_step
