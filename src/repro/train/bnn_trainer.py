"""The train half of the train-to-serve loop (DESIGN.md §12): a real
CIFAR training loop for the Courbariaux BNN over ``make_train_step``,
with the three BNN-specific pieces the generic step factory cannot know
about:

* **STE training task** — ``bnn_train_loss`` (FAKE_QUANT forward, batch
  BatchNorm, straight-through gradients) adapted to the ``model.loss``
  contract ``(params, batch) -> (loss, metrics)``; accuracy and the BN
  batch statistics ride along as metrics.
* **Latent-weight clipping** — :func:`bnn_clip_predicate` names exactly
  the binarized latent matrices (``conv[i].w`` / ``fc[j].w``) for
  AdamW's ``latent_clip``: outside [-1, 1] the STE gradient is zero and
  a latent weight would be stuck forever, so the optimizer pins them to
  the STE support. Biases and BatchNorm params are never clipped.
* **Running BN statistics** — after each optimizer step the batch
  (mean, var) from the loss aux are EMA'd into the ``mean``/``var``
  buffers (``update_bn_stats``); packed inference evaluates with those
  buffers, so this is what makes the exported model serve what was
  trained.

``make_dp_train_step`` is the shard_map data-parallel variant: per-shard
gradients are all-reduced through ``distributed.compression`` — fp32
(``"none"``), error-feedback int8 (``"int8"``), or 1-bit EF sign-SGD
(``"signsgd"``, the natural endpoint once weights and activations are
already 1-bit: gradients are the only fat tensors left).

Checkpoints go through ``checkpoint/manager.py`` (full float latents +
optimizer state, resumable); ``core.bnn.save_binary_checkpoint`` is the
separate ~32x-smaller sign-form export for serving/goldens.

For long or multi-device runs, ``train/resilience.py`` wraps this loop
in the fault-tolerance machinery (heartbeats, loss-sentinel rollback,
elastic shrink with error-feedback folding, bit-identical resume) —
``train_bnn_resilient`` with a fault-free plan is bit-identical to
``train_bnn``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.checkpoint import manager as ckpt_manager
from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_eval_logits,
    bnn_train_loss,
    init_bnn_params,
    update_bn_stats,
)
from repro.data.pipeline import DataConfig, synthetic_cifar_batches
from repro.distributed import compression
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import cosine_schedule
from repro.train.step import TrainConfig, init_opt_state, make_train_step


def bnn_clip_predicate(path: tuple) -> bool:
    """True exactly for the binarized latent weight matrices of the BNN
    param tree — ``("conv", i, "w")`` and ``("fc", j, "w")``. Every one
    of those is binarized in the FAKE_QUANT forward (first conv
    included: its *inputs* stay real, its weights do not), so every one
    needs the latent clip; nothing else (biases, BatchNorm) does."""
    return (
        len(path) >= 2
        and path[0] in ("conv", "fc")
        and path[-1] == "w"
    )


@dataclasses.dataclass(frozen=True)
class _BNNTask:
    """``model.loss`` adapter: the only part of the Model bundle the
    train step factory consumes."""

    cfg: BNNConfig

    def loss(self, params, batch):
        return bnn_train_loss(
            params, batch["images"], batch["labels"], self.cfg
        )


@dataclasses.dataclass(frozen=True)
class BNNTrainerConfig:
    steps: int = 200
    batch: int = 64
    lr: float = 3e-3
    weight_decay: float = 0.0      # latents live in [-1,1]; decay hurts
    clip_norm: float = 5.0
    warmup_steps: int = 10
    microbatches: int = 1
    bn_momentum: float = 0.9
    use_scale: bool = False        # XNOR-Net per-channel alpha
    seed: int = 0                  # param init
    data_seed: int = 11            # synthetic-CIFAR stream
    eval_batches: int = 4          # held-out batches AFTER the train range
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 20

    def train_config(self) -> TrainConfig:
        return TrainConfig(
            adamw=AdamWConfig(
                lr=self.lr, weight_decay=self.weight_decay,
                latent_clip=True,
            ),
            clip_norm=self.clip_norm,
            microbatches=self.microbatches,
            warmup_steps=self.warmup_steps,
            total_steps=self.steps,
        )

    def model_config(self) -> BNNConfig:
        return BNNConfig(mode=QuantMode.FAKE_QUANT, use_scale=self.use_scale)


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: dict          # {"loss": [...], "acc": [...], "lr_scale": [...]}
    eval_loss: float
    eval_acc: float
    start_step: int        # 0, or the resumed checkpoint's step


def _eval_fn(use_scale: bool):
    @jax.jit
    def evaluate(params, images, labels):
        logits = bnn_eval_logits(params, images, use_scale=use_scale)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
        return loss, acc

    return evaluate


def evaluate_bnn(params, data_iter, *, batches: int,
                 use_scale: bool = False) -> tuple[float, float]:
    """Mean (loss, accuracy) of the float-boundary eval forward — which
    is bit-identical to packed serving, so this IS serving accuracy."""
    ev = _eval_fn(use_scale)
    losses, accs = [], []
    for _, b in zip(range(batches), data_iter):
        loss, acc = ev(params, b["images"], b["labels"])
        losses.append(float(loss))
        accs.append(float(acc))
    return float(jnp.mean(jnp.asarray(losses))), float(
        jnp.mean(jnp.asarray(accs)))


def train_bnn(cfg: BNNTrainerConfig, *, params=None,
              verbose: bool = False) -> TrainResult:
    """Train the CIFAR BNN with STE + latent clip + running BN stats.

    Deterministic end to end: param seed, stateless (seed, step) data
    batches, single-threaded updates. Checkpoints (full latent floats +
    optimizer state, via checkpoint/manager.py) are written every
    ``checkpoint_every`` steps when ``checkpoint_dir`` is set, and the
    run RESUMES from the latest valid checkpoint in that directory —
    batch ``i`` is reproducible from the data seed alone, so a resumed
    run replays the exact remaining stream.
    """
    task = _BNNTask(cfg.model_config())
    tcfg = cfg.train_config()
    if params is None:
        params = init_bnn_params(jax.random.PRNGKey(cfg.seed))
    opt_state = init_opt_state(params)

    start_step = 0
    if cfg.checkpoint_dir:
        latest = ckpt_manager.latest_valid_step(cfg.checkpoint_dir)
        if latest is not None:
            tree = ckpt_manager.restore(
                cfg.checkpoint_dir, latest,
                {"params": params, "opt": opt_state},
            )
            params, opt_state = tree["params"], tree["opt"]
            start_step = latest

    step_fn = jax.jit(
        make_train_step(task, tcfg, clip_predicate=bnn_clip_predicate)
    )
    ema_fn = jax.jit(
        functools.partial(update_bn_stats, momentum=cfg.bn_momentum)
    )

    data = synthetic_cifar_batches(
        DataConfig(seed=cfg.data_seed, global_batch=cfg.batch)
    )
    history: dict = {"loss": [], "acc": [], "lr_scale": []}
    for i, batch in zip(range(cfg.steps), data):
        if i < start_step:
            continue  # stateless stream: skip batches the resume covered
        feed = {"images": batch["images"], "labels": batch["labels"]}
        params, opt_state, metrics = step_fn(params, opt_state, feed)
        params = ema_fn(params, metrics.pop("bn_stats"))
        history["loss"].append(float(metrics["loss"]))
        history["acc"].append(float(metrics["acc"]))
        history["lr_scale"].append(float(metrics["lr_scale"]))
        if verbose and (i % cfg.log_every == 0 or i == cfg.steps - 1):
            print(
                f"step {i:4d} loss {history['loss'][-1]:.4f} "
                f"acc {history['acc'][-1]:.3f} "
                f"lr_scale {history['lr_scale'][-1]:.3f}"
            )
        if (
            cfg.checkpoint_dir
            and cfg.checkpoint_every
            and (i + 1) % cfg.checkpoint_every == 0
        ):
            ckpt_manager.save(
                cfg.checkpoint_dir, i + 1,
                {"params": params, "opt": opt_state},
            )

    if cfg.checkpoint_dir:
        ckpt_manager.save(
            cfg.checkpoint_dir, cfg.steps,
            {"params": params, "opt": opt_state},
        )

    # Held-out eval: the stateless stream continues PAST the train
    # range, so these batches were never trained on (same class means,
    # fresh noise and labels).
    eval_loss, eval_acc = evaluate_bnn(
        params, data, batches=cfg.eval_batches, use_scale=cfg.use_scale
    )
    if verbose:
        print(f"eval loss {eval_loss:.4f} acc {eval_acc:.3f} "
              f"(chance {1.0 / 10:.2f})")
    return TrainResult(
        params=params, opt_state=opt_state, history=history,
        eval_loss=eval_loss, eval_acc=eval_acc, start_step=start_step,
    )


# ---------------------------------------------------------------------------
# Data-parallel train step with compressed gradient all-reduce.
# ---------------------------------------------------------------------------

DP_COMPRESSIONS = ("none", "int8", "signsgd")


def init_dp_error_feedback(params, n_devices: int):
    """Zero error-feedback residuals for the compressed all-reduce
    paths: one residual per gradient leaf PER SHARD, stacked on a
    leading ``[n_devices, ...]`` axis. Error feedback is genuinely
    per-shard state (each shard accumulates the quantization error of
    its OWN gradient stream), so the residual tree is sharded over the
    data axis like the batch — never replicated."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_devices,) + p.shape, p.dtype), params
    )


def make_dp_train_step(
    task,
    tcfg: TrainConfig,
    mesh,
    *,
    grad_compression: str = "signsgd",
    clip_predicate=None,
):
    """shard_map data-parallel train step: ``(params, opt_state, err,
    batch) -> (params, opt_state, err, metrics)``.

    The batch is sharded over the mesh's ``"data"`` axis; params and
    optimizer state are replicated. Per-shard gradients meet in a
    compressed all-reduce (``distributed.compression``):

      * ``"none"``    — fp32 ``pmean`` (the baseline),
      * ``"int8"``    — error-feedback int8 (``psum_compressed``),
      * ``"signsgd"`` — 1-bit error-feedback sign-SGD
        (``psum_signsgd``, 32x fewer payload bits).

    ``err`` is the error-feedback residual tree from
    :func:`init_dp_error_feedback`: per-shard state (each shard
    accumulates the quantization error of its own gradient stream), so
    it carries a leading ``[n_devices, ...]`` axis and is sharded over
    ``"data"`` exactly like the batch — each shard reads and writes only
    its own slice.

    Metrics (loss/acc/bn_stats) come back pmean'd over shards so the
    caller's BN-stat EMA sees global batch statistics.
    """
    if grad_compression not in DP_COMPRESSIONS:
        raise ValueError(
            f"unknown grad_compression {grad_compression!r}; expected one "
            f"of {DP_COMPRESSIONS}"
        )
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = "data"

    def shard_step(params, adam, err, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: task.loss(p, batch), has_aux=True
        )(params)
        if grad_compression == "none":
            grads = jax.tree.map(lambda g: lax.pmean(g, axis), grads)
            new_err = err
        else:
            fn = (
                compression.psum_compressed
                if grad_compression == "int8"
                else compression.psum_signsgd
            )
            # err leaves arrive as this shard's [1, ...] slice of the
            # stacked residual tree; peel / restack the device axis.
            err_local = jax.tree.map(lambda e: e[0], err)
            pairs = jax.tree.map(
                lambda g, e: fn(g, e, axis), grads, err_local
            )
            is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
            grads = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
            new_err = jax.tree.map(
                lambda t: t[1][None], pairs, is_leaf=is_pair
            )
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        step = adam["count"] + 1  # post-increment: warmup step 1 is live
        lr_scale = cosine_schedule(
            step, warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )
        new_params, new_adam = adamw_update(
            grads, adam, params, tcfg.adamw, lr_scale=lr_scale,
            clip_predicate=clip_predicate,
        )
        out_metrics = {
            **jax.tree.map(lambda m: lax.pmean(m, axis), metrics),
            "loss": lax.pmean(loss, axis),
            "grad_norm": gnorm,
            "lr_scale": lr_scale,
        }
        return new_params, new_adam, new_err, out_metrics

    sharded = shard_map(
        shard_step, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=(P(), P(), P(axis), P()),
        check_rep=False,
    )

    def train_step(params, opt_state, err, batch):
        new_params, new_adam, new_err, metrics = sharded(
            params, opt_state["adam"], err, batch
        )
        return new_params, {"adam": new_adam}, new_err, metrics

    return train_step
