"""Config schema: one ``ArchConfig`` per assigned architecture plus the
paper's own BNN, and the four assigned input-shape cells.

Every (arch x shape) cell the dry-run / roofline consumes is a
``Cell = (ArchConfig, ShapeConfig)``; applicability rules (long-context
needs sub-quadratic attention, encoder-only has no decode) live here so
launch/ and benchmarks agree on the cell list.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.binarize import QuantMode
from repro.models.common import QuantPolicy


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact values from the assignment)."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # MoE FFN on layers where i % moe_every == 0
    dense_residual_ff: int = 0     # arctic: parallel always-on dense FFN width
    capacity_factor: float = 1.25
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0        # 0 = full attention
    # --- hybrid (jamba): attention layer every `attn_every`, rest mamba ---
    attn_every: int = 0
    d_state: int = 16
    conv_width: int = 4
    mamba_expand: int = 2
    # --- xlstm ---
    slstm_every: int = 0           # sLSTM block every N layers, rest mLSTM
    # --- enc-dec ---
    encoder_layers: int = 0
    # --- modality frontend stub ---
    input_kind: str = "tokens"     # tokens | embeddings (vlm/audio stubs)
    # --- misc ---
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    dtype: object = jnp.bfloat16

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding row-shards over 16-way model
        parallelism (seamless's 256206 is the one that needs it)."""
        return _round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def is_attention_layer(self, i: int) -> bool:
        if self.family != "hybrid" or self.attn_every <= 0:
            return True
        # jamba: 1 attention : (attn_every - 1) mamba, attention placed at
        # position attn_every//2 within each period (paper's 1:7 interleave).
        return i % self.attn_every == self.attn_every // 2

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and i % self.moe_every == 0

    def is_slstm_layer(self, i: int) -> bool:
        return self.slstm_every > 0 and i % self.slstm_every == 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (DESIGN.md §4)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one of the 40 cells."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{model.name} is pure full-attention (DESIGN.md §4)"
        )
    if shape.kind == "decode" and not model.has_decode:
        return False, f"{model.name} has no decode step"
    return True, ""


# --- quantization policies (the paper's technique as a feature) -------------

def train_policy(enabled: bool = True) -> QuantPolicy:
    """Training: fake-quant STE binarization of every *_proj matmul."""
    return QuantPolicy(
        enabled=enabled, mode=QuantMode.FAKE_QUANT,
        binarize_acts=False, use_scale=True, engine="xla",
    )


def serve_policy(enabled: bool = True) -> QuantPolicy:
    """Serving: packed 1-bit weights (paper §3.1 encoding), SPMD-safe
    unpack->MXU engine (DESIGN.md §2)."""
    return QuantPolicy(
        enabled=enabled, mode=QuantMode.PACKED,
        binarize_acts=False, use_scale=True, engine="xla",
    )


def float_policy() -> QuantPolicy:
    """Control group: same graph, no binarization (paper §4.3)."""
    return QuantPolicy(enabled=False)


# --- registry ----------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (triggers per-arch module imports)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs  # noqa: F401

    return sorted(_REGISTRY)


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, narrow,
    tiny vocab/experts — exercises the identical code path."""
    c = get_config(name)
    return dataclasses.replace(
        c,
        num_layers=min(c.num_layers, 4 if c.family in ("hybrid", "ssm") else 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(c.num_kv_heads, 2),
        head_dim=32,
        d_ff=256 if c.d_ff else 0,
        vocab_size=512,
        num_experts=min(c.num_experts, 8),
        experts_per_token=min(c.experts_per_token, 2),
        dense_residual_ff=256 if c.dense_residual_ff else 0,
        encoder_layers=min(c.encoder_layers, 2),
        sliding_window=min(c.sliding_window, 64) if c.sliding_window else 0,
        attn_every=2 if c.attn_every else 0,
        slstm_every=2 if c.slstm_every else 0,
        d_state=8,
        dtype=jnp.float32,
    )
