"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64e top-6.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    num_experts=64,
    experts_per_token=6,
    moe_every=1,
    rope_theta=50_000.0,
))
