"""pixtral-12b — pixtral-ViT frontend (STUB) + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified] 40L d_model=5120 32H
(GQA kv=8) d_ff=14336 vocab=131072. The ViT frontend is a stub per the
assignment: ``input_specs()`` provides precomputed patch embeddings
[B, S, d_model]; only the transformer backbone is modeled.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    input_kind="embeddings",
    rope_theta=1_000_000.0,
))
