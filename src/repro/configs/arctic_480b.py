"""arctic-480b — Snowflake Arctic: 128-expert top-2 MoE *plus* an
always-on dense residual FFN in parallel (the "dense-MoE hybrid").

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 (per expert) vocab=32000, MoE 128e top-2.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    num_experts=128,
    experts_per_token=2,
    moe_every=1,
    dense_residual_ff=4864,   # parallel dense FFN branch (arctic residual)
    rope_theta=10_000.0,
))
