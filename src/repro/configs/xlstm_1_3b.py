"""xlstm-1.3b — sLSTM + mLSTM blocks (1 sLSTM per 8-layer period).

[arXiv:2405.04517; unverified] 48L d_model=2048 4H (kv=4) d_ff=0
(xLSTM blocks carry their own up/down projections) vocab=50304.
Recurrent, O(1) decode state -> owns the long_500k cell.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
))
