"""Config registry: importing this package registers every assigned
architecture. ``get_config(name)`` / ``list_configs()`` are the API."""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_applicable,
    float_policy,
    get_config,
    list_configs,
    serve_policy,
    smoke_config,
    train_policy,
)

# one module per assigned architecture (+ the paper's own model)
from repro.configs import (  # noqa: F401
    arctic_480b,
    bnn_cifar,
    jamba_1_5_large_398b,
    mistral_large_123b,
    moonshot_v1_16b_a3b,
    pixtral_12b,
    qwen2_5_3b,
    qwen2_5_32b,
    seamless_m4t_large_v2,
    smollm_360m,
    xlstm_1_3b,
)

ASSIGNED = [
    "moonshot-v1-16b-a3b",
    "arctic-480b",
    "jamba-1.5-large-398b",
    "mistral-large-123b",
    "qwen2.5-32b",
    "smollm-360m",
    "qwen2.5-3b",
    "pixtral-12b",
    "xlstm-1.3b",
    "seamless-m4t-large-v2",
]
