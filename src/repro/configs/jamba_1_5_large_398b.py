"""jamba-1.5-large-398b — AI21 Jamba: Mamba+attention 1:7 interleave,
16-expert top-2 MoE on every other layer.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2. Sub-quadratic (hybrid) -> runs long_500k.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,              # MoE on even layers within the period
    attn_every=8,             # 1 attention : 7 mamba per 8-layer period
    d_state=16,
    conv_width=4,
    mamba_expand=2,
    rope_theta=10_000.0,
))
