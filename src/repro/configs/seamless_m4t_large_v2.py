"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio STUB).

[arXiv:2308.11596; hf] 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206. The speech frontend is a stub per the assignment:
``input_specs()`` provides precomputed frame embeddings for the encoder.
Vocab pads 256206 -> 256256 so the embedding row-shards 16-way.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,            # decoder depth
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    input_kind="embeddings",
    norm="layernorm",
    act="gelu",
    rope_theta=10_000.0,
))
