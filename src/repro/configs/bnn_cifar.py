"""The paper's own evaluation setup: Courbariaux BNN on CIFAR-10
(paper §4.2) with the three kernel modes of Table 2.

This is not an LM config — the model lives in ``repro.core.bnn``; this
module records the experiment configuration the benchmarks use.
"""

import dataclasses

from repro.core.binarize import QuantMode
from repro.core.bnn import BNNConfig


@dataclasses.dataclass(frozen=True)
class BNNExperiment:
    name: str
    batch: int = 64
    num_batches: int = 16     # timed inference batches (paper used 10k imgs)


# paper Table 2 rows (our analogue, same-graph comparisons under XLA CPU)
PAPER_KERNEL = BNNConfig(mode=QuantMode.PACKED, engine="xnor")     # "Our Kernel"
DIRECT_KERNEL = BNNConfig(mode=QuantMode.PACKED, engine="xnor",    # DESIGN.md §5:
                          conv_impl="direct")                      # no im2col
MXU_KERNEL = BNNConfig(mode=QuantMode.PACKED, engine="unpack")     # beyond-paper
XLA_PACKED = BNNConfig(mode=QuantMode.PACKED, engine="xla")        # SPMD engine
CONTROL_GROUP = BNNConfig(mode=QuantMode.FLOAT)                    # "Control Group"
SIMULATION = BNNConfig(mode=QuantMode.FAKE_QUANT)                  # released BNNs
