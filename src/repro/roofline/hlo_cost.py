"""Trip-count-aware cost model over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program (every model here) is undercounted by the trip
count (88x for mistral-large). This walker parses the post-SPMD HLO,
finds each while's ``known_trip_count`` backend config, and accumulates

  * flops            — 2 * prod(result) * contraction for every dot
                       (incl. dots inside fusion bodies),
  * traffic bytes    — operands + outputs of every top-level op, with
                       fusions counted at their boundary (internals are
                       register/VMEM-resident post-fusion),
  * collective bytes — result bytes x wire multiplier (all-reduce 2x
                       for ring, others 1x) per collective op,

multiplying everything inside a while body by its trip count
(recursively — chunked-scan-inside-period-scan nests multiply).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLL_MULT = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INST = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"\b([a-z][\w\-]*)\(")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_PARAM = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\])(?:\{[^}]*\})?)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "constant", "after-all",
    "bitcast", "partition-id", "replica-id",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


@dataclasses.dataclass
class _Inst:
    name: str
    op: str
    result: str            # result type string
    rhs: str               # full right-hand side (operands + attrs)


@dataclasses.dataclass
class _Computation:
    name: str
    params: dict           # name -> type string
    insts: list


def _parse_module(text: str) -> dict[str, "_Computation"]:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                params = dict(_PARAM.findall(m.group(2)))
                cur = _Computation(m.group(1), params, [])
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPNAME.search(rhs)
        op = om.group(1) if om else ""
        result = rhs[: om.start()] if om else rhs
        cur.insts.append(_Inst(name, op, result, rhs))
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # traffic inside jax.named_scope("vmem_fusible") regions: tile-
    # resident intermediates (flash-attention scores, SSM scan states)
    # that the shipped Pallas kernels keep in VMEM on TPU; the CPU HLO
    # materializes them because interpret/XLA-CPU cannot express VMEM
    # residency. Reported separately so the memory term can be given
    # raw and kernel-fused.
    fusible_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_MULT}
    )

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.fusible_bytes += other.fusible_bytes * mult
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] += v * mult


def _dot_flops(inst: _Inst, shapes: dict) -> float:
    _, out_b = _shape_elems_bytes(inst.result)
    out_elems, _ = _shape_elems_bytes(inst.result)
    cdims = _LHS_CDIMS.search(inst.rhs)
    # lhs operand shape
    ops = _OPERANDS.findall(inst.rhs.split(")", 1)[0])
    k = 1
    if cdims and ops:
        lhs_shape = shapes.get(ops[0], "")
        m = _SHAPE_RE.search(lhs_shape)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


class HloCostModel:
    def __init__(self, text: str):
        self.comps = _parse_module(text)
        # global name -> result type (instructions) for operand lookup
        self.shapes: dict[str, str] = {}
        for comp in self.comps.values():
            for pname, ptype in comp.params.items():
                self.shapes.setdefault(pname, ptype)
            for inst in comp.insts:
                self.shapes.setdefault(inst.name, inst.result)
        self._memo: dict[str, HloCost] = {}
        self._marker_memo: dict[str, bool] = {}
        self.entry = self._find_entry(text)

    def _comp_has_marker(self, comp_name: str) -> bool:
        """True if any instruction in the (fusion) computation carries
        the vmem_fusible scope. XLA fusion instructions often drop their
        root's metadata, so the boundary line alone is not reliable."""
        if comp_name in self._marker_memo:
            return self._marker_memo[comp_name]
        comp = self.comps.get(comp_name)
        found = bool(comp) and any(
            "vmem_fusible" in inst.rhs for inst in comp.insts
        )
        self._marker_memo[comp_name] = found
        return found

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        return m.group(1) if m else next(iter(self.comps))

    def _operand_bytes(self, inst: _Inst) -> float:
        # operand names = %refs in the first paren group of the rhs
        call = inst.rhs[inst.rhs.index("(") + 1:] if "(" in inst.rhs else ""
        depth = 1
        out = []
        for ch_i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    call = call[:ch_i]
                    break
        total = 0.0
        for name in _OPERANDS.findall(call):
            t = self.shapes.get(name)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def _fusion_flops(self, comp_name: str) -> float:
        """Dots inside a fusion body (bytes stay at the boundary)."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for inst in comp.insts:
            if inst.op == "dot":
                total += _dot_flops(inst, self.shapes)
        return total

    def _inplace_correction(self, comp_name: str) -> float:
        """In-place update semantics for fusions.

        A fusion whose body dynamic-update-slices (or scatters) into a
        buffer ALIASES that buffer: real HBM traffic is the update
        region (read+write), not the whole buffer in and out. Scan
        stacking (remat stashes, lax.map outputs, KV-cache writes) all
        hit this; without the correction an 88-layer remat stash counts
        as 88 x full-stash traffic. Returns the (negative) byte delta
        to apply at the fusion boundary.
        """
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        delta = 0.0
        sliced_params: set = set()
        dus_results: set = set()
        for inst in comp.insts:
            if inst.op in ("dynamic-update-slice", "scatter"):
                _, buf_b = _shape_elems_bytes(inst.result)
                ops = _OPERANDS.findall(inst.rhs[inst.rhs.index("(") + 1:])
                upd_b = 0
                if len(ops) >= 2:
                    t = self.shapes.get(ops[1], "")
                    upd_b = _shape_elems_bytes(t)[1]
                buf_src = ops[0] if ops else ""
                dus_results.add(inst.name)
                # The full buffer crosses the fusion boundary at most
                # twice (as a parameter and as the output); chained
                # updates into the same buffer only add their update
                # traffic.
                if buf_src in comp.params:
                    delta += 2.0 * upd_b - 2.0 * buf_b
                elif buf_src in dus_results:
                    delta += 2.0 * upd_b
                else:  # buffer materialized in-body; only output side
                    delta += 2.0 * upd_b - buf_b
            elif inst.op in ("dynamic-slice", "gather"):
                # reading a slice of a big parameter buffer: traffic is
                # the slice, not the buffer
                ops = _OPERANDS.findall(inst.rhs[inst.rhs.index("(") + 1:])
                if ops and ops[0] in comp.params and ops[0] not in sliced_params:
                    sliced_params.add(ops[0])
                    buf_b = _shape_elems_bytes(comp.params[ops[0]])[1]
                    out_b = _shape_elems_bytes(inst.result)[1]
                    delta -= max(0.0, buf_b - out_b)
        return delta

    def cost_of(self, comp_name: str) -> HloCost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = HloCost()  # cycle guard
        comp = self.comps.get(comp_name)
        cost = HloCost()
        if comp is None:
            return cost
        for inst in comp.insts:
            op = inst.op
            if op in _NO_TRAFFIC or not op:
                continue
            _, out_b = _shape_elems_bytes(inst.result)
            if op == "while":
                # control flow: no boundary traffic (loop state is
                # aliased in place; body ops are counted per trip)
                trips = 1
                tm = _TRIP.search(inst.rhs)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY.search(inst.rhs)
                if bm:
                    cost.add(self.cost_of(bm.group(1)), mult=trips)
                continue
            if op in ("call", "conditional", "async-start"):
                cm = _CALLS.search(inst.rhs) or _BODY.search(inst.rhs)
                if cm:
                    cost.add(self.cost_of(cm.group(1)))
                continue
            base = op.replace("-start", "")
            if base in _COLL_MULT:
                wire = out_b * _COLL_MULT[base]
                if base == "all-reduce":
                    # payload = operand (result == operand for all-reduce)
                    pass
                cost.collective_bytes += wire
                cost.collective_breakdown[base] += wire
                cost.bytes += out_b + self._operand_bytes(inst)
                continue
            if op.endswith("-done"):
                continue
            fusible = "vmem_fusible" in inst.rhs
            if not fusible and op == "fusion":
                fm = _CALLS.search(inst.rhs)
                if fm:
                    fusible = self._comp_has_marker(fm.group(1))

            def _acc(n: float):
                if fusible:
                    cost.fusible_bytes += n
                else:
                    cost.bytes += n

            if op in ("dynamic-update-slice", "scatter"):
                # in-place: traffic = update region read+write (+ indices)
                ops = _OPERANDS.findall(inst.rhs[inst.rhs.index("(") + 1:])
                upd_b = 0
                if len(ops) >= 2:
                    upd_b = _shape_elems_bytes(self.shapes.get(ops[1], ""))[1]
                _acc(2.0 * upd_b)
                continue
            if op in ("dynamic-slice", "gather"):
                # slice-read: traffic ~= the slice (indices negligible)
                _acc(2.0 * out_b)
                continue
            if op == "dot":
                cost.flops += _dot_flops(inst, self.shapes)
                _acc(out_b + self._operand_bytes(inst))
                continue
            if op == "fusion":
                cm = _CALLS.search(inst.rhs)
                boundary = out_b + self._operand_bytes(inst)
                if cm:
                    cost.flops += self._fusion_flops(cm.group(1))
                    boundary = max(
                        0.0, boundary + self._inplace_correction(cm.group(1))
                    )
                _acc(boundary)
                continue
            _acc(out_b + self._operand_bytes(inst))
            if op == "convolution":
                # approximation: 2 * out_elems * (in_ch * window) — we
                # have no conv ops in the LM paths; BNN convs go via dot.
                cost.flops += 2.0 * _shape_elems_bytes(inst.result)[0]
        self._memo[comp_name] = cost
        return cost

    def total(self) -> HloCost:
        return self.cost_of(self.entry)


def analyze(text: str) -> HloCost:
    return HloCostModel(text).total()
