"""Three-term roofline from a compiled dry-run artifact (no hardware).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` reports the post-SPMD, PER-DEVICE module,
so the per-chip division is already done for the first two terms; the
collective term sums operand bytes of every collective op in
``compiled.as_text()`` with a wire-traffic multiplier per op kind
(ring all-reduce moves ~2x its payload; all-gather/reduce-scatter ~1x).

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# --- hardware constants (TPU v5e, per assignment) ----------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# wire-traffic multiplier per collective kind (ring algorithms)
_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """'bf16[256,1024]' -> bytes. Tuple shapes handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes x wire multiplier per collective kind.

    '-done' ops are skipped (the '-start' carries the shape) and each
    fusion/computation body is counted once — HLO prints every op once.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(kind)[0]
        out[kind] += _shape_bytes(lhs) * _COLLECTIVES[kind]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float          # kernel-fused HBM traffic
    collective_bytes_per_chip: float
    collective_breakdown: dict
    model_flops: float                 # 6*N*D (or 2*N_active per decode token)
    bytes_per_chip_peak: Optional[float] = None   # memory_analysis temp+args
    # tile-resident traffic the Pallas kernels keep in VMEM on TPU
    # (flash-attention scores, SSM scan states); raw = fused + this
    fusible_bytes_per_chip: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def memory_raw_s(self) -> float:
        """Memory term WITHOUT the VMEM-fusible kernel credit — what the
        XLA-CPU lowering would literally move through HBM."""
        return (self.hlo_bytes_per_chip + self.fusible_bytes_per_chip) / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — remat/redundancy waste."""
        total = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips x peak x roofline step time)."""
        denom = self.chips * PEAK_FLOPS * self.step_time_s
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            memory_raw_s=self.memory_raw_s,
            collective_s=self.collective_s,
            bottleneck=self.bottleneck,
            step_time_s=self.step_time_s,
            useful_flops_fraction=self.useful_flops_fraction,
            mfu=self.mfu,
        )
        return d

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)


def from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                  chips: int, model_flops: float,
                  memory_stats: Optional[dict] = None) -> Roofline:
    """Derive the roofline from the compiled per-device HLO.

    Uses the trip-count-aware walker (roofline/hlo_cost.py) because
    XLA's own cost_analysis counts while bodies once — a scanned
    88-layer model would be undercounted 88x.
    """
    from repro.roofline import hlo_cost

    cost = hlo_cost.analyze(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=cost.flops,
        hlo_bytes_per_chip=cost.bytes,
        collective_bytes_per_chip=cost.collective_bytes,
        collective_breakdown=dict(cost.collective_breakdown),
        model_flops=model_flops,
        bytes_per_chip_peak=(memory_stats or {}).get("temp_bytes"),
        fusible_bytes_per_chip=cost.fusible_bytes,
    )


def _from_compiled_xla_cost(compiled, *, arch: str, shape: str,
                            mesh_name: str, chips: int, model_flops: float,
                            memory_stats: Optional[dict] = None) -> Roofline:
    """Legacy path: XLA cost_analysis + line-regex collective parse.

    Kept for cross-checking the walker; undercounts while bodies."""
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        collective_bytes_per_chip=sum(coll.values()),
        collective_breakdown=coll,
        model_flops=model_flops,
        bytes_per_chip_peak=(memory_stats or {}).get("temp_bytes"),
    )


# --------------------------- model FLOPs (6ND) --------------------------------


def count_params(tree, *, active_moe_fraction: Optional[float] = None) -> float:
    """Total (or active) param count from a float param pytree/eval_shape.

    Leaves under a ``moe`` subtree with a leading expert axis are scaled
    by ``active_moe_fraction`` (= experts_per_token / num_experts) when
    given. Packed leaves (w_packed) count as size*32 latent params.
    """
    import jax
    import numpy as np

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = 0.0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        size = float(np.prod(np.shape(leaf) or (1,)))
        if keys and keys[-1] == "w_packed":
            size *= 32
        if "moe" in keys and keys[-1] in ("w", "w_packed") \
                and "router" not in keys:
            if active_moe_fraction is not None:
                size *= active_moe_fraction
        total += size
    return total


def model_flops_for(cfg, shape_cfg, n_params_total: float,
                    n_params_active: float) -> float:
    """6*N*D train / 2*N per generated token decode (per step)."""
    n = n_params_active
    tokens = shape_cfg.global_batch * shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n * tokens
    if shape_cfg.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape_cfg.global_batch
