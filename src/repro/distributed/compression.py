"""Error-feedback int8 gradient compression for the data-parallel axis.

Scope note (DESIGN.md §5): under pjit auto-SPMD the gradient all-reduce
is inserted by XLA inside the backward pass, so a library cannot
intercept the wire format there. This module therefore targets the
``shard_map`` data-parallel path (used by ``examples/ddp_compression.py``
and the elastic-DP trainer): per-device grads are quantized to int8 with
an error-feedback residual, the all-reduce ("psum") runs on the int8
payload widened to int32 (8/32 = 4x fewer payload bytes than fp32 on a
bandwidth-limited interconnect; TPU ICI reduces in the payload dtype),
then dequantized. Error feedback keeps the quantization noise unbiased
across steps (Seide et al. / EF-SGD), which the convergence test in
tests/test_distributed.py checks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(grads):
    return jax.tree.map(jnp.zeros_like, grads)


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray,
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Local (single-device) EF quantization round trip.

    Returns (dequantized grad to feed the optimizer, new error residual).
    """
    corrected = g + err
    q, scale = _quantize(corrected)
    deq = _dequantize(q, scale)
    return deq, corrected - deq


def psum_compressed(g: jnp.ndarray, err: jnp.ndarray, axis_name: str,
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """EF-int8 all-reduce for use INSIDE shard_map.

    Two collectives: a scalar pmax agrees on a common scale, then the
    int8 payload (widened to int32 so a 512-way sum cannot overflow)
    is psum'd — 4x fewer payload bytes than an fp32 all-reduce. The
    local quantization error goes into the error-feedback residual.
    """
    corrected = g + err
    gmax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.maximum(gmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    new_err = corrected - q.astype(jnp.float32) * scale
    return mean, new_err


def tree_compress_decompress(grads, err_state):
    out = jax.tree.map(
        lambda g, e: compress_decompress(g, e), grads, err_state,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
