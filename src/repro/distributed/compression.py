"""Compressed gradient all-reduce for the data-parallel axis:
error-feedback int8 and 1-bit error-feedback sign-SGD.

Scope note (DESIGN.md §5): under pjit auto-SPMD the gradient all-reduce
is inserted by XLA inside the backward pass, so a library cannot
intercept the wire format there. This module therefore targets the
``shard_map`` data-parallel path (``examples/ddp_compression.py``, the
elastic-DP trainer, and ``train/bnn_trainer.py::make_dp_train_step``):
per-device grads are quantized with an error-feedback residual, the
all-reduce runs on the quantized payload, then dequantizes. Error
feedback keeps the quantization noise unbiased across steps (Seide et
al. / EF-SGD / Karimireddy et al. 2019), which the convergence tests in
tests/test_distributed.py check against the fp32 baseline.

Byte accounting — stated honestly:

* ``psum_compressed`` (int8) quantizes to 8 bits, but the psum payload
  is the int8 grads *widened to int32* so a 512-way sum cannot
  overflow. On an interconnect that reduces in the transferred compute
  dtype (the all-reduce as lowered here) the wire bytes therefore equal
  fp32; the 8/32 = **4x payload reduction applies only where the fabric
  can reduce in int8** (or where the transport truncates to the
  quantized dtype between hops). What the int8 path always buys is the
  information-theoretic 4x: 8 bits of entropy per coordinate survive,
  which is what makes it a useful EF baseline.
* ``psum_signsgd`` (1-bit) keeps **1 bit of entropy per coordinate**
  plus one shared fp32 scale per tensor — a 32x bit-rate reduction
  against fp32 (``SIGNSGD_BITS_RATIO``). The reference lowering again
  widens the ±1 payload for the sum; a bit-packed fabric transfer would
  move ceil(n/32) words per tensor. For the binarized nets this repo
  serves, gradients are the only fat tensors left once weights and
  activations are 1-bit — this is the train-side analogue of the packed
  serving path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Entropy ratio vs an fp32 all-reduce: bits kept per coordinate.
INT8_BITS_RATIO = 32 / 8     # 4x — realized on int8-reducing fabrics only
SIGNSGD_BITS_RATIO = 32 / 1  # 32x — 1 sign bit (+ one fp32 scale/tensor)


def init_error_feedback(grads):
    return jax.tree.map(jnp.zeros_like, grads)


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray,
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Local (single-device) EF int8 quantization round trip.

    Returns (dequantized grad to feed the optimizer, new error residual).
    """
    corrected = g + err
    q, scale = _quantize(corrected)
    deq = _dequantize(q, scale)
    return deq, corrected - deq


def psum_compressed(g: jnp.ndarray, err: jnp.ndarray, axis_name: str,
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """EF-int8 all-reduce for use INSIDE shard_map.

    Two collectives: a scalar pmax agrees on a common scale, then the
    int8 payload (widened to int32 so a 512-way sum cannot overflow)
    is psum'd. 8 of 32 bits of entropy per coordinate survive
    quantization; the *wire* savings are fabric-dependent — see the
    module byte-accounting note (the widened payload moves fp32-sized
    words unless the interconnect reduces in int8). The local
    quantization error goes into the error-feedback residual.
    """
    corrected = g + err
    gmax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.maximum(gmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    new_err = corrected - q.astype(jnp.float32) * scale
    return mean, new_err


def signsgd_compress_decompress(g: jnp.ndarray, err: jnp.ndarray,
                                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Local (single-device) EF sign-SGD round trip (Karimireddy et al.
    2019 EF-signSGD): the compressed form is ``scale * sign(corrected)``
    with ``scale = mean(|corrected|)`` — the l1-optimal magnitude for a
    sign vector. Returns (decompressed grad, new error residual)."""
    corrected = g + err
    scale = jnp.mean(jnp.abs(corrected))
    sgn = jnp.where(corrected >= 0, 1.0, -1.0).astype(jnp.float32)
    deq = scale * sgn
    return deq, corrected - deq


def psum_signsgd(g: jnp.ndarray, err: jnp.ndarray, axis_name: str,
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """1-bit EF sign-SGD all-reduce for use INSIDE shard_map.

    Payload per tensor per device: the sign bits (1 bit/coordinate —
    the same ``x >= 0`` convention as the packed weight bits) plus ONE
    fp32 scalar, a 32x bit-rate reduction vs fp32
    (``SIGNSGD_BITS_RATIO``; the reference lowering widens the ±1
    payload to int32 for the sum — see the module byte-accounting
    note). Two collectives, mirroring :func:`psum_compressed`: a scalar
    pmean agrees on the common magnitude scale, then the sign payload
    is psum'd and rescaled. Each device's quantization error
    (``corrected - scale * sign``) feeds its error-feedback residual,
    which is what keeps the noise unbiased across steps and lets
    EF-sign-SGD track the fp32 baseline (convergence-tested in
    tests/test_distributed.py).
    """
    corrected = g + err
    # one common scale so the psum'd signs dequantize consistently:
    # mean(|.|) is the l1-optimal magnitude for a sign vector.
    scale = jax.lax.pmean(jnp.mean(jnp.abs(corrected)), axis_name)
    sgn = jnp.where(corrected >= 0, 1, -1).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(sgn.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    new_err = corrected - sgn.astype(jnp.float32) * scale
    return mean, new_err


def tree_compress_decompress(grads, err_state):
    out = jax.tree.map(
        lambda g, e: compress_decompress(g, e), grads, err_state,
        is_leaf=lambda x: isinstance(x, jnp.ndarray),
    )
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
