"""Fault tolerance: heartbeats, straggler detection, elastic re-meshing.

On a real multi-host TPU fleet these hooks attach to the coordination
service (jax.distributed); in this single-process container the monitor
runs against injectable clocks/device-lists so the *logic* — what the
1000-node deployment needs — is fully implemented and tested:

* ``HeartbeatMonitor``    — marks a host dead after ``timeout`` without a
  beat; the training driver polls ``dead_hosts()`` each step and raises
  ``WorkerFailure`` to trigger the restart path.
* ``StragglerDetector``   — per-step-time EMA + z-score; persistent
  stragglers get flagged for eviction (mitigation = drop to checkpoint,
  rebuild mesh without them, resume).
* ``ElasticMesh``         — rebuilds the largest usable (data, model)
  mesh from the surviving device count and recomputes shardings; with
  the npz checkpoint format, restore-to-new-mesh is just
  ``checkpoint.restore(..., shardings=new)`` (no resharding pass).
* ``run_with_recovery``   — the driver loop: step, heartbeat, checkpoint
  cadence, and on failure: wait -> rebuild mesh -> restore -> continue.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np


class WorkerFailure(RuntimeError):
    def __init__(self, hosts: Sequence[int]):
        super().__init__(f"workers failed: {sorted(hosts)}")
        self.hosts = sorted(hosts)


class Preemption(WorkerFailure):
    """The scheduler killed this process (spot/preemptible capacity).

    A preemption loses the in-memory state but no devices: ``hosts`` is
    empty, so ``run_with_recovery`` takes the plain restart path —
    restore from the latest valid checkpoint, no mesh rebuild. The
    training chaos harness (train/resilience.py) raises this to
    simulate a process kill in-process."""

    def __init__(self, step: Optional[int] = None):
        RuntimeError.__init__(
            self,
            "preempted" if step is None else f"preempted at step {step}",
        )
        self.hosts: list[int] = []
        self.step = step


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, *, timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.last_beat = {h: now for h in range(num_hosts)}

    def beat(self, host: int) -> None:
        self.last_beat[host] = self.clock()

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return [h for h, t in self.last_beat.items()
                if now - t > self.timeout]

    def check(self) -> None:
        dead = self.dead_hosts()
        if dead:
            raise WorkerFailure(dead)


class StragglerDetector:
    """Flags hosts whose step time is persistently > ``z`` sigmas above
    the fleet EMA. ``observe`` takes {host: step_seconds} each step."""

    def __init__(self, *, alpha: float = 0.2, z: float = 3.0,
                 patience: int = 5):
        self.alpha = alpha
        self.z = z
        self.patience = patience
        self.ema: dict[int, float] = {}
        self.strikes: dict[int, int] = {}

    def observe(self, step_times: dict[int, float]) -> list[int]:
        for h, t in step_times.items():
            prev = self.ema.get(h, t)
            self.ema[h] = (1 - self.alpha) * prev + self.alpha * t
        vals = np.array(list(self.ema.values()))
        mu = float(np.median(vals))
        # robust sigma (MAD): a single straggler must not inflate the
        # threshold that is supposed to catch it
        sigma = float(1.4826 * np.median(np.abs(vals - mu)) + 1e-3 * mu + 1e-9)
        flagged = []
        for h, t in step_times.items():
            if t > mu + self.z * sigma and t > 1.05 * mu:
                self.strikes[h] = self.strikes.get(h, 0) + 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                flagged.append(h)
        return flagged


# ------------------------------ elastic mesh ----------------------------------


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def num_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_mesh_for(num_devices: int, *, model_parallel: int = 16,
                  multi_pod_at: int = 512) -> MeshPlan:
    """Largest usable mesh from the surviving device count.

    Keeps the model axis fixed (param shardings stay valid) and shrinks
    the data axis to the largest fit — elastic scale-down/up. Below one
    model-parallel group it degrades to a 1D data mesh.
    """
    if num_devices >= multi_pod_at and num_devices % (model_parallel * 2) == 0:
        per_pod = num_devices // 2 // model_parallel
        return MeshPlan((2, per_pod, model_parallel), ("pod", "data", "model"))
    if num_devices >= model_parallel:
        data = num_devices // model_parallel
        return MeshPlan((data, model_parallel), ("data", "model"))
    return MeshPlan((num_devices,), ("data",))


def make_elastic_mesh(devices: Optional[Sequence] = None,
                      *, model_parallel: int = 16) -> jax.sharding.Mesh:
    devices = list(devices if devices is not None else jax.devices())
    plan = plan_mesh_for(len(devices), model_parallel=model_parallel)
    used = plan.num_devices
    dev_array = np.asarray(devices[:used]).reshape(plan.shape)
    return jax.sharding.Mesh(dev_array, plan.axes)


def serving_shrink_plan(n_surviving: int) -> int:
    """Device count the serving mesh shrinks to: the largest power of
    two <= ``n_surviving``.

    The serving ladders (`serve.buckets.mesh_buckets`,
    `serve.executor.default_extents`) round every rung up to a device
    multiple, so a power-of-two successor keeps every warmed rung of a
    power-of-two predecessor divisible — the shrunk cache re-warms the
    *same* rung set at the new multiple and steady state stays
    recompile-free (DESIGN.md §11).  Losing 1 of 8 devices therefore
    lands on 4, not 7.
    """
    if n_surviving < 1:
        return 0
    return 1 << (int(n_surviving).bit_length() - 1)


def shrink_serving_mesh(mesh, dead: Sequence[int]):
    """The largest surviving serving mesh after losing ``dead`` (flat
    device indices into ``mesh``), or None when no shrink is possible
    (no valid dead index, or nothing would survive).

    Always a 1-D ``("data",)`` mesh — the serving path's only layout
    (DESIGN.md §10).
    """
    devices = list(np.asarray(mesh.devices).flat)
    dead_set = {int(d) for d in dead if 0 <= int(d) < len(devices)}
    if not dead_set:
        return None
    survivors = [d for i, d in enumerate(devices) if i not in dead_set]
    n = serving_shrink_plan(len(survivors))
    if n < 1:
        return None
    return jax.sharding.Mesh(np.asarray(survivors[:n]), ("data",))


# ------------------------------ recovery loop ---------------------------------


def run_with_recovery(
    *,
    num_steps: int,
    step_fn: Callable[[int], dict],
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    monitor: HeartbeatMonitor,
    rebuild_fn: Optional[Callable[[Sequence[int]], None]] = None,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    on_failure: Optional[Callable[[WorkerFailure, int], None]] = None,
) -> dict:
    """Generic driver: runs ``step_fn`` with heartbeat checks and
    checkpoint cadence; on WorkerFailure rebuilds (elastic) and resumes
    from the latest valid checkpoint. Returns the last metrics.

    ``on_failure(failure, step)`` (if given) observes every caught
    failure with the step it interrupted, BEFORE the rebuild/restore —
    the hook resilient drivers use to account recomputed work
    (replayed steps = failed step - restored step)."""
    restarts = 0
    step = restore_fn()
    metrics: dict = {}
    while step < num_steps:
        try:
            monitor.check()
            metrics = step_fn(step)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except WorkerFailure as failure:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_failure is not None:
                on_failure(failure, step)
            if rebuild_fn is not None:
                rebuild_fn(failure.hosts)
            for h in failure.hosts:   # evicted hosts stop being monitored
                monitor.last_beat.pop(h, None)
            step = restore_fn()
    return metrics
