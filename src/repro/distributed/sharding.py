"""Sharding rules: param/state pytree -> PartitionSpec pytree.

Scheme (DESIGN.md §5):

* float weight matrices ``w [.., out, in]`` — 2D-sharded: ``out -> model``
  and ``in -> data`` (the data axis doubles as an FSDP axis; XLA SPMD
  inserts the all-gathers at use). MoE stacks ``[E, out, in]`` shard
  experts over ``model`` and ``in`` over ``data``.
* packed 1-bit weights ``w_packed [.., out, in/32]`` — ``out -> model``,
  replicated over data: they are 32x smaller, and replicating them is
  what buys the collective-free decode path (the paper's footprint win
  spent on communication).
* embeddings / LM head ``[V, D]`` — vocab over ``model``, D over ``data``.
* norms, biases of tiny fan-out, SSM dynamics, recurrent R — replicated.
* every rule is divisibility-guarded: an axis that does not divide the
  mesh axis is left unsharded (this is what lets one rule set serve
  10 architectures with head counts like 15 and 56).

Leading stack axes (scan periods, per-period layers) are never sharded.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh-axis names used across the project
DATA_AXES = ("pod", "data")      # batch / FSDP axes (pod absent on 1-pod mesh)
MODEL_AXIS = "model"

_REPLICATED_LEAVES = {
    "scale", "bias", "gamma", "beta", "mean", "var", "gn_scale",
    "conv_w", "conv_b", "A_log", "D", "R",
}


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def _guard(mesh: Mesh, dim: int, axis) -> Optional[Any]:
    """axis if it exists in the mesh and divides dim, else None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        axis = tuple(a for a in axis if a in mesh.shape)
        if not axis:
            return None
        axis = axis if len(axis) > 1 else axis[0]
    elif axis not in mesh.shape:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


# Megatron-style roles: column-parallel projections put the tensor axis
# on their OUT dim (q/k/v/up/gate produce model-sharded features);
# row-parallel projections contract over the model-sharded feature and
# put FSDP on their OUT dim (down/o/out: partial-sum -> one all-reduce /
# reduce-scatter per block instead of re-gathering the wide activation).
_ROW_PARALLEL = {"down_proj", "o_proj", "out_proj"}


def _matrix_spec(mesh: Mesh, shape, *, name: str) -> P:
    """Weight base shape [..., out, in] -> role-dependent spec.

    column-parallel: (model on out, (pod,data)-FSDP on in)
    row-parallel:    ((pod,data)-FSDP on out, model on in)
    MoE stacks [E, out, in]: experts over model, FSDP on in (expert-
    parallel — the contraction stays device-local per expert).
    """
    if len(shape) >= 3:  # stacked experts
        e_ax = _guard(mesh, shape[0], MODEL_AXIS)
        # FSDP on the expert in-dim makes every expert matmul a partial
        # sum -> an all-reduce of the whole [E, cap, d] activation
        # buffer per layer (moonshot hillclimb, §Perf hc7). Only pay
        # that when the expert stack is too big to replicate over data
        # (arctic/jamba); small expert stacks replicate.
        big = float(np.prod(shape)) > 1e9
        in_ax = _guard(mesh, shape[-1], DATA_AXES) if big else None
        return P(e_ax, *(None,) * (len(shape) - 2), in_ax)
    if name in _ROW_PARALLEL:
        return P(_guard(mesh, shape[-2], DATA_AXES),
                 _guard(mesh, shape[-1], MODEL_AXIS))
    return P(_guard(mesh, shape[-2], MODEL_AXIS),
             _guard(mesh, shape[-1], DATA_AXES))


def _path_keys(path) -> list:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(p.key)
        elif hasattr(p, "idx"):
            keys.append(p.idx)
        elif hasattr(p, "name"):
            keys.append(p.name)
        else:
            keys.append(str(p))
    return keys


def param_spec(mesh: Mesh, path, leaf) -> P:
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    parent = next((k for k in reversed(keys[:-1]) if isinstance(k, str)), "")
    shape = tuple(np.shape(leaf))
    # scanned layer stacks carry one leading period/layer axis — never
    # sharded (it is the scan xs dimension)
    stacked = 1 if "layers" in keys and len(shape) >= 1 else 0
    base = shape[stacked:]
    lead = (None,) * stacked

    if name in _REPLICATED_LEAVES or len(base) == 0:
        return P()
    if len(base) == 1:
        if name in ("b", "alpha") and parent not in _ROW_PARALLEL:
            return P(*lead, _guard(mesh, base[0], MODEL_AXIS))
        return P()
    if parent == "router":  # tiny, accuracy-critical — replicate
        return P()
    if name == "w_packed":
        if len(base) >= 3:  # stacked experts [E, out, kw]
            return P(*lead, _guard(mesh, base[0], MODEL_AXIS),
                     *(None,) * (len(base) - 1))
        return P(*lead, _guard(mesh, base[-2], MODEL_AXIS), None)
    if name == "table":  # embedding [V, D]
        return P(_guard(mesh, base[0], MODEL_AXIS),
                 _guard(mesh, base[1], DATA_AXES))
    if name == "w":
        return P(*lead, *_matrix_spec(mesh, base, name=parent))
    return P()


def params_shardings(mesh: Mesh, params) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf)),
        params,
    )


# ----------------------------- streaming state -------------------------------


def state_spec(mesh: Mesh, path, leaf) -> P:
    """KV caches [L, B, S, H, Dh]; SSM/xLSTM states [L, B, ...].

    Batch shards over (pod, data) when divisible; for batch-1
    long-context the KV sequence axis shards over model instead; other
    feature axes shard over model when they divide.
    """
    keys = _path_keys(path)
    shape = np.shape(leaf)
    if len(shape) == 0:
        return P()
    name = keys[-1] if keys else ""
    top = keys[0] if keys else ""
    if top == "kv" or name in ("k", "v"):
        # [L, B, S, Hkv, Dh]
        b_ax = _guard(mesh, shape[1], DATA_AXES)
        if b_ax is None:
            b_ax = _guard(mesh, shape[1], "data")
        s_ax = _guard(mesh, shape[2], MODEL_AXIS)
        return P(None, b_ax, s_ax, None, None)
    if top == "memory" or name == "memory":
        # encoder memory [B, S, D]
        return P(_guard(mesh, shape[0], DATA_AXES),
                 _guard(mesh, shape[1], MODEL_AXIS), None)
    if len(shape) >= 2:
        b_ax = _guard(mesh, shape[1], DATA_AXES) or _guard(mesh, shape[1], "data")
        rest = [None] * (len(shape) - 2)
        # shard the largest divisible feature axis over model
        cands = [
            (shape[i], i) for i in range(2, len(shape))
            if _guard(mesh, shape[i], MODEL_AXIS) is not None
        ]
        if cands:
            _, i = max(cands)
            rest[i - 2] = MODEL_AXIS
        return P(None, b_ax, *rest)
    return P()


def state_shardings(mesh: Mesh, state) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, state_spec(mesh, path, leaf)),
        state,
    )


# ------------------------------- batches -------------------------------------


def batch_spec(mesh: Mesh, path, leaf) -> P:
    shape = np.shape(leaf)
    if len(shape) == 0:
        return P()
    b_ax = _guard(mesh, shape[0], DATA_AXES) or _guard(mesh, shape[0], "data")
    return P(b_ax, *(None,) * (len(shape) - 1))


def batch_shardings(mesh: Mesh, batch) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, batch_spec(mesh, path, leaf)),
        batch,
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# --------------------------- BNN serving (DESIGN.md §10) ----------------------
#
# The packed-BNN serving path is pure data parallelism over a 1-D
# ``("data",)`` mesh (``launch.mesh.make_serving_mesh``): packed weights
# replicated (they are ~1.75 MB — the paper's 32x footprint win spent on
# a collective-free forward), batch sharded. These helpers are what
# ``core.bnn.bnn_serve_fn(mesh=...)`` builds its shard_map specs from.


def mesh_devices(mesh: Optional[Mesh]) -> int:
    """Device count of a serving mesh (1 for ``None`` — the
    single-device dispatch path)."""
    return 1 if mesh is None else int(np.prod(list(mesh.shape.values())))


def serve_specs(mesh: Mesh) -> tuple[P, P, P]:
    """``(params_spec, images_spec, logits_spec)`` for the serving
    forward: weights replicated, batch dim sharded over ``data``.

    Reuses the rule-table guard discipline: a mesh without a ``data``
    axis degrades to fully replicated specs (single-device dispatch)
    instead of erroring — the same ``_guard`` posture that lets one
    rule set serve every mesh shape. Batch divisibility is NOT guarded
    here (shard_map specs are shape-free); the serving executors are
    responsible for dispatching only device-divisible batches
    (``serve.executor.extent_for(..., devices=)`` /
    ``serve.buckets.mesh_buckets``), padding bit-neutral zero rows when
    a batch does not divide.
    """
    axis = "data" if "data" in mesh.shape else None
    return P(), P(axis), P(axis)


# ------------------------- activation constraints -----------------------------
#
# Models are mesh-agnostic; the launcher installs the active mesh here and
# model code calls ``constrain(x, batch_axes, seq_axis, ...)`` at layer
# boundaries (Megatron-style sequence parallelism: the residual stream
# lives [B/(pod*data), S/model, D] between blocks). Every axis is
# divisibility-guarded, so the same call is a no-op on a single CPU
# device (smoke tests) and for shapes that don't divide (decode S=1).

import contextlib
import threading

_ACTIVE = threading.local()


def get_active_mesh() -> Optional[Mesh]:
    return getattr(_ACTIVE, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    prev = get_active_mesh()
    _ACTIVE.mesh = mesh
    try:
        yield
    finally:
        _ACTIVE.mesh = prev


def constrain(x, *axes):
    """with_sharding_constraint under the installed mesh, guarded.

    ``axes`` entries are mesh-axis names / tuples / None, one per dim.
    """
    mesh = get_active_mesh()
    if mesh is None:
        return x
    spec = P(*(
        _guard(mesh, dim, ax) for dim, ax in zip(x.shape, axes)
    ))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_seq(x):
    """Residual stream [B, S, D] between blocks: batch over (pod, data),
    seq/features replicated over model (classic Megatron TP layout — the
    model axis parallelism lives inside the blocks via the col/row
    weight rules; sequence-sharding the residual was tried and measured
    WORSE under XLA SPMD: the chunked-attention q-slices fight the
    seq shard and trigger involuntary remat, see EXPERIMENTS.md §Perf)."""
    return constrain(x, DATA_AXES, None, None)
