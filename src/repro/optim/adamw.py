"""AdamW with BNN-aware extras.

Binarized training detail (BNN, Courbariaux et al.): latent real weights
are *clipped to [-1, 1]* after each update — outside that range the STE
gradient is zero and the weight would be stuck forever. ``latent_clip``
applies this to every param whose pytree path marks it as a binarized
matrix (callers pass a predicate).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    latent_clip: bool = False  # clip binarized latent weights to [-1, 1]


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads,
    state,
    params,
    cfg: AdamWConfig,
    *,
    lr_scale: jnp.ndarray | float = 1.0,
    clip_predicate: Optional[Callable] = None,
):
    """Returns (new_params, new_state). ``lr_scale`` multiplies the base
    lr (schedule output); ``clip_predicate(path)`` selects latent-clipped
    binarized weights."""
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    c = count.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1**c)
    nu_hat_scale = 1.0 / (1 - b2**c)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        step = lr * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if cfg.weight_decay:
            step = step + lr * cfg.weight_decay * p
        return (p - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)

    if cfg.latent_clip:
        pred = clip_predicate or (lambda path: path and path[-1] == "w")
        flat = jax.tree_util.tree_flatten_with_path(new_params)
        leaves, treedef = flat
        clipped = [
            jnp.clip(v, -1.0, 1.0)
            if pred(tuple(_key_str(k) for k in path))
            else v
            for path, v in leaves
        ]
        new_params = jax.tree_util.tree_unflatten(treedef, clipped)

    return new_params, {"mu": mu, "nu": nu, "count": count}


def _key_str(k):
    if hasattr(k, "key"):
        return k.key
    if hasattr(k, "idx"):
        return k.idx
    if hasattr(k, "name"):
        return k.name
    return str(k)
