"""The serving engine: queue + executor cache + stats in one dispatch
loop (DESIGN.md §7).

Synchronous by construction — ``submit()`` enqueues, ``step()`` applies
the micro-batcher's flush rules and runs every ready batch, ``drain()``
finishes the tail. The caller owns the loop (the CLI's load generator,
the benchmark, the tests); there is no background thread to make timing
nondeterministic. Results are per-request float logits, bit-identical
to calling ``bnn_apply_fused`` on the request's images alone — padding
to a bucket never perturbs real rows (``tests/test_serve.py``).

Resilience (DESIGN.md §11): dispatch is wrapped in a bounded
retry-with-backoff loop, so an executor failure completes requests with
`RequestFailed` results after exhaustion instead of killing the engine
and stranding the queue. Per-request deadlines (``submit(...,
deadline_s=)``) are enforced before every dispatch — an expired request
completes as `DeadlineExceeded`, never silently late. A
`FallbackPolicy` demotes the engine down the bit-identical
`SERVE_FALLBACKS` ladder on repeated kernel failure, and on a meshed
engine a `DeviceLost` dispatch triggers an elastic shrink to the
largest surviving power-of-two mesh with in-flight work re-dispatched.
A `FaultPlan` injects deterministic failures for tests and the chaos
benchmark. All of it is observable through `ServeStats`
(``snapshot()["dispatch"|"mesh"|"degraded"]``) — resilience is never
silent.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serve.buckets import DEFAULT_BUCKETS, mesh_buckets
from repro.serve.executor import IMAGE_SHAPE, ExecutorCache
from repro.serve.faults import (DeadlineExceeded, DeviceLost, FallbackPolicy,
                                FaultPlan, InjectedFault, NaNLogits,
                                RequestFailed, RetryPolicy)
from repro.serve.queue import MicroBatcher
from repro.serve.stats import ServeStats


@dataclasses.dataclass
class _Work:
    """One assembled batch awaiting (re)dispatch.  ``attempts`` counts
    dispatches burned; ``not_before`` is the engine-clock time before
    which a retried batch must not redispatch (backoff)."""

    batch: object
    attempts: int = 0
    not_before: float = 0.0


class ServingEngine:
    """Batched inference over the fused packed BNN.

    ``packed_params`` comes from ``core.bnn.pack_bnn_params_fused`` —
    or ``pack_bnn_params_megakernel`` when ``engine`` is
    ``"megakernel"``/``"megakernel_xla"`` (one launch per network
    stage, DESIGN.md §8). ``engine``/``conv_impl``/``blocks`` select
    the kernel path exactly as in ``bnn_serve_fn``; ``buckets``/
    ``max_wait_s`` shape the batching policy; ``clock`` is injectable
    for deterministic tests.

    ``mesh`` (DESIGN.md §10) scales the same engine out data-parallel:
    executors dispatch through ``bnn_serve_fn(mesh=...)`` (weights
    replicated, batch sharded) and the bucket ladder is normalized to
    device multiples (``mesh_buckets``) so every dispatch divides the
    mesh. Logits stay bit-identical to single-device dispatch.

    Resilience knobs (DESIGN.md §11): ``deadline_s`` is the default
    per-request deadline (``submit`` can override per request);
    ``retry`` is the `RetryPolicy` bounding redispatch of failed
    batches; ``fallback`` is an optional `FallbackPolicy` arming engine
    demotion; ``faults`` is an optional `FaultPlan` injecting
    deterministic failures; ``heartbeat_timeout_s`` (meshed engines
    only) arms a `HeartbeatMonitor` — call ``beat(device)`` from the
    device-health source; a silent device triggers the same elastic
    shrink a mid-dispatch `DeviceLost` does.
    """

    def __init__(
        self,
        packed_params: dict,
        *,
        engine: str = "xla",
        conv_impl: str = "im2col",
        blocks: object = "auto",
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        max_wait_s: float = 0.002,
        mesh: object = None,
        deadline_s: Optional[float] = None,
        retry: Optional[RetryPolicy] = None,
        fallback: Optional[FallbackPolicy] = None,
        faults: Optional[FaultPlan] = None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        from repro.distributed.sharding import mesh_devices

        self.stats = ServeStats()
        self.clock = clock
        self.batcher = MicroBatcher(
            mesh_buckets(buckets, mesh_devices(mesh)),
            max_wait_s=max_wait_s, clock=clock,
        )
        self.executors = ExecutorCache(
            packed_params, engine=engine, conv_impl=conv_impl,
            blocks=blocks, mesh=mesh, stats=self.stats,
        )
        # rid -> [n, 10] float logits being filled segment by segment
        self._partial: dict[int, np.ndarray] = {}
        self._filled: dict[int, int] = {}
        self.results: dict[int, object] = {}
        self._init_resilience(deadline_s, retry, fallback, faults,
                              heartbeat_timeout_s)

    def _init_resilience(self, deadline_s, retry, fallback, faults,
                         heartbeat_timeout_s) -> None:
        """Shared resilience wiring — the continuous subclass builds its
        own batcher/executors instead of calling ``super().__init__``,
        so everything §11 adds lives in this one helper."""
        self.deadline_s = deadline_s
        self.retry = retry if retry is not None else RetryPolicy()
        self.fallback = fallback
        self.faults = faults
        # rid -> (absolute deadline on the engine clock, deadline_s, n)
        self._deadline: dict[int, tuple] = {}
        self._inflight: deque[_Work] = deque()
        self._dispatch_seq = 0
        self._retry_events = 0
        self._engine_failures = 0
        self._standby = None
        self.monitor = None
        if heartbeat_timeout_s is not None and self.executors.mesh is not None:
            from repro.distributed.fault_tolerance import HeartbeatMonitor
            self.monitor = HeartbeatMonitor(
                self.executors.devices, timeout=heartbeat_timeout_s,
                clock=self.clock,
            )

    # -- lifecycle ---------------------------------------------------------
    def _warm_shapes(self) -> Sequence[int]:
        """The shape ladder ``warmup`` compiles (bucket rungs here;
        extent classes in the continuous subclass)."""
        return self.batcher.buckets

    def warmup(self) -> int:
        """Compile every shape in the ladder (bucket rungs / extent
        classes) before taking traffic. Returns the number of
        executors compiled."""
        return self.executors.warmup(self._warm_shapes())

    def prewarm_fallback(self) -> int:
        """Build and warm a HOT-STANDBY executor cache one rung down
        the fallback ladder, so a later demotion swaps in compiled
        executables instead of stalling traffic behind fresh XLA
        compiles.  Returns the executors compiled (0 when no fallback
        is armed or the ladder is exhausted)."""
        if self.fallback is None:
            return 0
        nxt = self.fallback.next_engine(self.executors.engine)
        if nxt is None:
            return 0
        self._standby = self.executors.rebuild(
            packed=self.fallback.params_for(nxt), engine=nxt)
        return self._standby.warmup(self._warm_shapes())

    def submit(self, images: np.ndarray, *,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one request of ``[n, 32, 32, 3]`` images.

        The per-image shape is checked against the model's fixed input
        HERE — the queue's own consistency check pins itself to the
        FIRST request it sees, so without this a wrong-shaped first
        request would be accepted, blow up mid-dispatch, and poison the
        queue for every later (valid) request.

        ``deadline_s`` (falling back to the engine default) bounds how
        long the request may wait: past it, the request completes as a
        `DeadlineExceeded` result instead of being served late.
        """
        images = np.asarray(images)
        if images.shape[1:] != IMAGE_SHAPE:
            raise ValueError(
                f"request rows must be {IMAGE_SHAPE} images, got "
                f"{images.shape[1:]}"
            )
        rid = self.batcher.submit(images)
        self.stats.on_submit(self.batcher.requests[rid].n)
        self.stats.mark_wall(self.clock())
        d = deadline_s if deadline_s is not None else self.deadline_s
        if d is not None:
            self._deadline[rid] = (self.clock() + d, float(d),
                                   self.batcher.requests[rid].n)
        return rid

    def step(self) -> list[int]:
        """Run the flush rules once; dispatch any ready batches.
        Returns the request ids resolved by this call (completed,
        expired, or failed)."""
        self._check_heartbeats()
        resolved = self._expire()
        return resolved + self._run(self.batcher.poll())

    def drain(self) -> list[int]:
        """Flush and run everything still pending — including retried
        batches whose backoff has not elapsed yet (a drain must leave
        nothing unresolved)."""
        resolved = self._expire()
        return resolved + self._run(self.batcher.drain(), force=True)

    def take(self, rid: int):
        """Pop a resolved request's result: its ``[n, 10]`` logits, or a
        `DeadlineExceeded`/`RequestFailed` marker (``faults.is_error``
        distinguishes). None if not resolved yet."""
        return self.results.pop(rid, None)

    def cancel(self, rid: int) -> bool:
        """Cancel a request: drop its queued rows, any partially filled
        logits, and any unread result. Returns whether anything was
        dropped. Rows of the request already inside an assembled batch
        simply compute and are discarded at scatter time (the engine
        guards on the request still existing) — other requests in that
        batch are untouched.
        """
        req = self.batcher.forget(rid)
        partial = self._partial.pop(rid, None)
        self._filled.pop(rid, None)
        self._deadline.pop(rid, None)
        result = self.results.pop(rid, None)
        return req is not None or partial is not None or result is not None

    def beat(self, device: int) -> None:
        """Record a heartbeat for ``device`` (meshed engines with
        ``heartbeat_timeout_s`` armed; no-op otherwise)."""
        if self.monitor is not None:
            self.monitor.beat(device)

    # -- internals ---------------------------------------------------------
    def _check_heartbeats(self) -> None:
        if self.monitor is None:
            return
        for dev in self.monitor.dead_hosts():
            # One shrink per step: a shrink rebuilds the monitor for the
            # new mesh, so stale dead indices from the old one are moot.
            if self._shrink(dev):
                break

    def _expire(self) -> list[int]:
        """Complete every past-deadline request as `DeadlineExceeded`.
        Runs before each dispatch, so a request never computes after its
        deadline passed (rows already inside an assembled batch are
        dropped at scatter time by the forget guard)."""
        now = self.clock()
        out: list[int] = []
        for rid in [r for r, (t, _, _) in self._deadline.items()
                    if now >= t]:
            t, d, n = self._deadline.pop(rid)
            self.batcher.forget(rid)
            self._partial.pop(rid, None)
            self._filled.pop(rid, None)
            self.results[rid] = DeadlineExceeded(
                rid=rid, deadline_s=d, waited_s=now - (t - d))
            self.stats.on_expire(n)
            out.append(rid)
        if out:
            self.stats.mark_wall(now)
        return out

    def _execute_rows(self, x: np.ndarray) -> np.ndarray:
        """One executor run, through the fault plan and the NaN guard.
        Each call burns one monotone dispatch index — the unit the
        `FaultPlan` schedules on — whether or not it succeeds."""
        idx = self._dispatch_seq
        self._dispatch_seq += 1
        engine = self.executors.engine
        spec = None
        if self.faults is not None:
            spec = self.faults.match(idx, x.shape[0], engine)
            if spec is not None:
                self.faults.on_fire(idx, spec, x.shape[0], engine)
                if spec.kind == "latency":
                    self.faults.sleep(spec.latency_s)
                elif spec.kind == "raise":
                    raise InjectedFault(f"injected fault at dispatch {idx}")
                elif spec.kind == "device_loss":
                    raise DeviceLost(spec.device)
        logits = self.executors.run(x)
        if spec is not None and spec.kind == "nan":
            logits = np.full_like(logits, np.nan)
        # Always-on guard: a silently corrupted kernel becomes a
        # retryable failure, never poisoned results.
        if not np.isfinite(logits).all():
            raise NaNLogits(f"non-finite logits at dispatch {idx} "
                            f"(engine {engine})")
        return logits

    def _dispatch(self, batch) -> tuple[np.ndarray, int]:
        """Assemble + execute one batch; returns ``(logits,
        dispatched_rows)`` — the rows the accelerator actually ran
        (bucket size here; tile-padded extent in the continuous
        subclass), which is what the pad-waste accounting records."""
        x = batch.assemble(self.batcher.requests)
        logits = self._execute_rows(x)
        return logits, x.shape[0]

    def _run(self, batches, force: bool = False) -> list[int]:
        """Enqueue freshly coalesced batches behind any retried work and
        pump the in-flight queue in FIFO order."""
        for batch in batches:
            self._inflight.append(_Work(batch))
        return self._pump(force=force)

    def _pump(self, *, force: bool = False) -> list[int]:
        """Process the in-flight queue head-first.  A retried batch in
        backoff blocks the queue (head-of-line on purpose: dispatching
        around it would break FIFO among successes); ``force`` ignores
        backoff so ``drain()`` always runs dry."""
        resolved: list[int] = []
        while self._inflight:
            resolved.extend(self._expire())
            work = self._inflight[0]
            if not force and work.not_before > self.clock():
                break
            self._inflight.popleft()
            resolved.extend(self._process(work))
        return resolved

    def _process(self, work: _Work) -> list[int]:
        batch = work.batch
        if all(
            seg.rid not in self.batcher.requests
            for seg in batch.segments
        ):
            return []  # every request cancelled/expired since batching
        try:
            logits, dispatched = self._dispatch(batch)
        except Exception as err:  # noqa: BLE001 — resilience boundary
            return self._on_failure(work, err)
        self._engine_failures = 0
        self.stats.on_dispatch(dispatched, batch.rows, batch.reason)
        now = self.clock()
        self.stats.mark_wall(now)
        done: list[int] = []
        for seg in batch.segments:
            req = self.batcher.requests.get(seg.rid)
            if req is None:
                # Cancelled/expired between assembly and scatter: its
                # rows computed as dead weight; drop them.
                continue
            buf = self._partial.get(seg.rid)
            if buf is None:
                buf = np.empty((req.n, logits.shape[-1]), logits.dtype)
                self._partial[seg.rid] = buf
                self._filled[seg.rid] = 0
            buf[seg.offset:seg.offset + seg.length] = (
                logits[seg.batch_row:seg.batch_row + seg.length]
            )
            self._filled[seg.rid] += seg.length
            if self._filled[seg.rid] == req.n:
                self.results[seg.rid] = self._partial.pop(seg.rid)
                del self._filled[seg.rid]
                self._deadline.pop(seg.rid, None)
                self.stats.on_complete(req.n, now - req.t_submit)
                self.batcher.forget(seg.rid)
                done.append(seg.rid)
        return done

    def _on_failure(self, work: _Work, err: Exception) -> list[int]:
        """Route one failed dispatch: device loss shrinks the mesh and
        redispatches free of charge; anything else burns an attempt,
        may demote the engine, and either backs off at the queue front
        (FIFO preserved) or — budget exhausted — completes every rider
        as `RequestFailed`."""
        if isinstance(err, DeviceLost) and self._shrink(err.device):
            # The loss is the mesh's fault, not the batch's: re-dispatch
            # in-flight work on the shrunk mesh without charging its
            # retry budget.
            self._inflight.appendleft(work)
            return []
        self._engine_failures += 1
        self._maybe_demote()
        work.attempts += 1
        if work.attempts >= self.retry.max_attempts:
            return self._fail_batch(work, err)
        live = sum(1 for seg in work.batch.segments
                   if seg.rid in self.batcher.requests)
        self._retry_events += 1
        self.stats.on_retry(live)
        work.not_before = self.clock() + self.retry.delay_s(
            work.attempts, self._retry_events)
        self._inflight.appendleft(work)
        return []

    def _fail_batch(self, work: _Work, err: Exception) -> list[int]:
        failed: list[int] = []
        for seg in work.batch.segments:
            req = self.batcher.forget(seg.rid)
            if req is None:
                continue  # cancelled/expired already
            self._partial.pop(seg.rid, None)
            self._filled.pop(seg.rid, None)
            self._deadline.pop(seg.rid, None)
            self.results[seg.rid] = RequestFailed(
                rid=seg.rid, error=f"{type(err).__name__}: {err}",
                attempts=work.attempts)
            self.stats.on_fail(req.n)
            failed.append(seg.rid)
        self.stats.mark_wall(self.clock())
        return failed

    def _maybe_demote(self) -> None:
        """After ``failures_before_demote`` consecutive failures, rebuild
        the executor cache one rung down the bit-identical fallback
        ladder (logit-exact by the bedrock invariant)."""
        if self.fallback is None:
            return
        if self._engine_failures < self.fallback.failures_before_demote:
            return
        nxt = self.fallback.next_engine(self.executors.engine)
        if nxt is None:
            return
        old = self.executors.engine
        if self._standby is not None and self._standby.engine == nxt:
            # Hot standby (prewarm_fallback): swap in already-compiled
            # executables — no compile stall under traffic.
            self.executors = self._standby
            self._standby = None
            self._engine_failures = 0
            self.stats.on_fallback(old, nxt)
            return
        self.executors = self.executors.rebuild(
            packed=self.fallback.params_for(nxt), engine=nxt)
        self._engine_failures = 0
        self.stats.on_fallback(old, nxt)
        if self.fallback.warm:
            self.warmup()

    def _shrink(self, device: int) -> bool:
        """Elastic mesh shrink: rebuild executors on the largest
        surviving power-of-two mesh and re-warm the ladder at the new
        device multiple.  Returns False when no shrink is possible
        (unmeshed engine, invalid device, nothing left) — the caller
        then treats the loss as an ordinary dispatch failure."""
        from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                                       shrink_serving_mesh)

        if self.executors.mesh is None:
            return False
        new_mesh = shrink_serving_mesh(self.executors.mesh, (device,))
        if new_mesh is None:
            return False
        old_devices = self.executors.devices
        self.executors = self.executors.rebuild(mesh=new_mesh)
        self._on_remesh()
        self.stats.on_shrink(old_devices, self.executors.devices)
        if self.monitor is not None:
            self.monitor = HeartbeatMonitor(
                self.executors.devices, timeout=self.monitor.timeout,
                clock=self.clock,
            )
        self.warmup()
        return True

    def _on_remesh(self) -> None:
        # The bucket ladder was normalized to multiples of the ORIGINAL
        # device count; power-of-two shrink keeps every rung divisible
        # by the survivor count (serving_shrink_plan), so the ladder
        # stays valid as-is.  The continuous subclass recomputes its
        # extent ladder here instead.
        pass

    def snapshot(self) -> dict:
        return self.stats.snapshot()


__all__ = ["ServingEngine"]
