"""The serving engine: queue + executor cache + stats in one dispatch
loop (DESIGN.md §7).

Synchronous by construction — ``submit()`` enqueues, ``step()`` applies
the micro-batcher's flush rules and runs every ready batch, ``drain()``
finishes the tail. The caller owns the loop (the CLI's load generator,
the benchmark, the tests); there is no background thread to make timing
nondeterministic. Results are per-request float logits, bit-identical
to calling ``bnn_apply_fused`` on the request's images alone — padding
to a bucket never perturbs real rows (``tests/test_serve.py``).
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serve.buckets import DEFAULT_BUCKETS, mesh_buckets
from repro.serve.executor import IMAGE_SHAPE, ExecutorCache
from repro.serve.queue import MicroBatcher
from repro.serve.stats import ServeStats


class ServingEngine:
    """Batched inference over the fused packed BNN.

    ``packed_params`` comes from ``core.bnn.pack_bnn_params_fused`` —
    or ``pack_bnn_params_megakernel`` when ``engine`` is
    ``"megakernel"``/``"megakernel_xla"`` (one launch per network
    stage, DESIGN.md §8). ``engine``/``conv_impl``/``blocks`` select
    the kernel path exactly as in ``bnn_serve_fn``; ``buckets``/
    ``max_wait_s`` shape the batching policy; ``clock`` is injectable
    for deterministic tests.

    ``mesh`` (DESIGN.md §10) scales the same engine out data-parallel:
    executors dispatch through ``bnn_serve_fn(mesh=...)`` (weights
    replicated, batch sharded) and the bucket ladder is normalized to
    device multiples (``mesh_buckets``) so every dispatch divides the
    mesh. Logits stay bit-identical to single-device dispatch.
    """

    def __init__(
        self,
        packed_params: dict,
        *,
        engine: str = "xla",
        conv_impl: str = "im2col",
        blocks: object = "auto",
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        max_wait_s: float = 0.002,
        mesh: object = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        from repro.distributed.sharding import mesh_devices

        self.stats = ServeStats()
        self.clock = clock
        self.batcher = MicroBatcher(
            mesh_buckets(buckets, mesh_devices(mesh)),
            max_wait_s=max_wait_s, clock=clock,
        )
        self.executors = ExecutorCache(
            packed_params, engine=engine, conv_impl=conv_impl,
            blocks=blocks, mesh=mesh, stats=self.stats,
        )
        # rid -> [n, 10] float logits being filled segment by segment
        self._partial: dict[int, np.ndarray] = {}
        self._filled: dict[int, int] = {}
        self.results: dict[int, np.ndarray] = {}

    # -- lifecycle ---------------------------------------------------------
    def warmup(self) -> int:
        """Compile every bucket in the ladder before taking traffic.
        Returns the number of executors compiled."""
        return self.executors.warmup(self.batcher.buckets)

    def submit(self, images: np.ndarray) -> int:
        """Enqueue one request of ``[n, 32, 32, 3]`` images.

        The per-image shape is checked against the model's fixed input
        HERE — the queue's own consistency check pins itself to the
        FIRST request it sees, so without this a wrong-shaped first
        request would be accepted, blow up mid-dispatch, and poison the
        queue for every later (valid) request.
        """
        images = np.asarray(images)
        if images.shape[1:] != IMAGE_SHAPE:
            raise ValueError(
                f"request rows must be {IMAGE_SHAPE} images, got "
                f"{images.shape[1:]}"
            )
        rid = self.batcher.submit(images)
        self.stats.on_submit(self.batcher.requests[rid].n)
        self.stats.mark_wall(self.clock())
        return rid

    def step(self) -> list[int]:
        """Run the flush rules once; dispatch any ready batches.
        Returns the request ids completed by this call."""
        return self._run(self.batcher.poll())

    def drain(self) -> list[int]:
        """Flush and run everything still pending."""
        return self._run(self.batcher.drain())

    def take(self, rid: int) -> Optional[np.ndarray]:
        """Pop a completed request's logits (None if not finished)."""
        return self.results.pop(rid, None)

    def cancel(self, rid: int) -> bool:
        """Cancel a request: drop its queued rows, any partially filled
        logits, and any unread result. Returns whether anything was
        dropped. Rows of the request already inside an assembled batch
        simply compute and are discarded at scatter time (the engine
        guards on the request still existing) — other requests in that
        batch are untouched.
        """
        req = self.batcher.forget(rid)
        partial = self._partial.pop(rid, None)
        self._filled.pop(rid, None)
        result = self.results.pop(rid, None)
        return req is not None or partial is not None or result is not None

    # -- internals ---------------------------------------------------------
    def _dispatch(self, batch) -> tuple[np.ndarray, int]:
        """Assemble + execute one batch; returns ``(logits,
        dispatched_rows)`` — the rows the accelerator actually ran
        (bucket size here; tile-padded extent in the continuous
        subclass), which is what the pad-waste accounting records."""
        x = batch.assemble(self.batcher.requests)
        logits = self.executors.run(x)
        return logits, x.shape[0]

    def _run(self, batches) -> list[int]:
        done: list[int] = []
        for batch in batches:
            if all(
                seg.rid not in self.batcher.requests
                for seg in batch.segments
            ):
                continue  # every request cancelled since batching
            logits, dispatched = self._dispatch(batch)
            self.stats.on_dispatch(dispatched, batch.rows, batch.reason)
            now = self.clock()
            self.stats.mark_wall(now)
            for seg in batch.segments:
                req = self.batcher.requests.get(seg.rid)
                if req is None:
                    # Cancelled between assembly and scatter: its rows
                    # computed as dead weight; drop them.
                    continue
                buf = self._partial.get(seg.rid)
                if buf is None:
                    buf = np.empty((req.n, logits.shape[-1]), logits.dtype)
                    self._partial[seg.rid] = buf
                    self._filled[seg.rid] = 0
                buf[seg.offset:seg.offset + seg.length] = (
                    logits[seg.batch_row:seg.batch_row + seg.length]
                )
                self._filled[seg.rid] += seg.length
                if self._filled[seg.rid] == req.n:
                    self.results[seg.rid] = self._partial.pop(seg.rid)
                    del self._filled[seg.rid]
                    self.stats.on_complete(req.n, now - req.t_submit)
                    self.batcher.forget(seg.rid)
                    done.append(seg.rid)
        return done

    def snapshot(self) -> dict:
        return self.stats.snapshot()


__all__ = ["ServingEngine"]
