"""Fault model for the serving engines (DESIGN.md §11).

This module is the resilience vocabulary shared by both serving
engines: terminal *result markers* (`DeadlineExceeded`,
`RequestFailed`) that `take()` hands back in place of logits, the
exception types a dispatch can die with, the `RetryPolicy` backoff
schedule, the `FallbackPolicy` engine-demotion ladder, and the
deterministic `FaultPlan` injection harness the chaos benchmark and
tests drive.

Everything here is deterministic and clock-free by construction:

- `FaultPlan` decides whether dispatch *i* faults from a stateless
  per-index RNG (`np.random.default_rng((seed, i))`), so the schedule
  is a pure function of the seed — independent of retries, wall time,
  and call order.  Latency faults go through an injectable ``sleep``
  hook (the fake-clock tests pass ``clk.advance``).
- `RetryPolicy` jitter is seeded per retry event, so backoff delays
  replay exactly.

Nothing in this file touches jax; it is pure policy.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "DeadlineExceeded",
    "RequestFailed",
    "is_error",
    "InjectedFault",
    "NaNLogits",
    "DeviceLost",
    "FaultSpec",
    "FaultPlan",
    "RetryPolicy",
    "FallbackPolicy",
]


# ---------------------------------------------------------------------------
# terminal result markers — returned by ``take()``, never raised
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeadlineExceeded:
    """Result marker: the request's deadline passed before dispatch
    completed.  The engine never serves a request late and silent — it
    completes it with this marker instead."""

    rid: int
    deadline_s: float
    waited_s: float


@dataclasses.dataclass(frozen=True)
class RequestFailed:
    """Result marker: every retry attempt for the request's batch was
    exhausted.  ``error`` records the final exception, ``attempts`` how
    many dispatches were burned."""

    rid: int
    error: str
    attempts: int


def is_error(result) -> bool:
    """True when a ``take()`` result is a terminal error marker rather
    than a logits array."""
    return isinstance(result, (DeadlineExceeded, RequestFailed))


# ---------------------------------------------------------------------------
# dispatch-time exceptions
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """A fault raised by a `FaultPlan` (kind="raise")."""


class NaNLogits(RuntimeError):
    """The executor produced non-finite logits.  The engines guard
    every dispatch with this check, so a silently corrupted kernel is
    converted into a retryable failure instead of poisoned results."""


class DeviceLost(RuntimeError):
    """A device in the serving mesh died mid-dispatch.  Carries the
    flat index of the lost device; the engine reacts by shrinking the
    mesh (DESIGN.md §11) rather than charging the batch's retry
    budget."""

    def __init__(self, device: int = 0):
        super().__init__(f"device {device} lost")
        self.device = int(device)


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------


_KINDS = ("raise", "nan", "latency", "device_loss")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One explicitly scheduled fault.

    Fires on dispatch indices ``at <= i < at + count`` whose extent /
    engine match (``None`` is a wildcard).  ``kind`` is one of
    ``raise`` (executor raises `InjectedFault`), ``nan`` (logits come
    back all-NaN), ``latency`` (dispatch sleeps ``latency_s`` through
    the plan's sleep hook before running), ``device_loss`` (raises
    `DeviceLost` for ``device``).
    """

    kind: str
    at: int = 0
    count: int = 1
    extent: Optional[int] = None
    engine: Optional[str] = None
    latency_s: float = 0.0
    device: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_KINDS}")

    def matches(self, index: int, extent: int, engine: str) -> bool:
        if not (self.at <= index < self.at + self.count):
            return False
        if self.extent is not None and self.extent != extent:
            return False
        if self.engine is not None and self.engine != engine:
            return False
        return True


class FaultPlan:
    """A deterministic, seeded schedule of injected faults.

    Two layers compose:

    - ``specs``: explicit `FaultSpec` entries, checked first (first
      match wins) — for pinning a failure to an exact dispatch index /
      extent / engine in tests and the chaos gate.
    - random mode: with ``rate`` > 0, dispatch *i* additionally faults
      with probability ``rate``, the kind drawn uniformly from
      ``kinds``.  The draw uses ``np.random.default_rng((seed, i))`` —
      a *stateless* per-index stream, so the schedule is identical no
      matter how many times a batch is retried or in what order
      indices are consulted.

    ``sleep`` is the hook latency faults go through; production uses
    ``time.sleep``, fake-clock tests pass ``clk.advance``.  Every fault
    that fires is appended to ``fired`` (index, kind, extent, engine)
    so benches can report the realized schedule.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), *,
                 rate: float = 0.0,
                 kinds: Tuple[str, ...] = ("raise", "nan", "latency"),
                 latency_s: float = 0.0,
                 seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        for k in kinds:
            if k not in _KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self.specs = tuple(specs)
        self.rate = float(rate)
        self.kinds = tuple(kinds)
        self.latency_s = float(latency_s)
        self.seed = int(seed)
        self.sleep = sleep
        self.fired: list = []

    def match(self, index: int, extent: int,
              engine: str) -> Optional[FaultSpec]:
        """The fault dispatch ``index`` should suffer, or None."""
        for spec in self.specs:
            if spec.matches(index, extent, engine):
                return spec
        if self.rate > 0.0:
            rng = np.random.default_rng((self.seed, index))
            if rng.random() < self.rate:
                kind = self.kinds[int(rng.integers(len(self.kinds)))]
                return FaultSpec(kind, at=index, latency_s=self.latency_s)
        return None

    def on_fire(self, index: int, spec: FaultSpec, extent: int,
                engine: str) -> None:
        self.fired.append({"index": index, "kind": spec.kind,
                           "extent": extent, "engine": engine})


# ---------------------------------------------------------------------------
# retry backoff
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``delay_s(attempt, event)`` returns
    ``min(cap, base * 2**(attempt-1)) * (1 + jitter * u)`` with
    ``u ~ U[-1, 1]`` drawn from ``default_rng((seed, event))`` — the
    engine feeds a monotone retry-event counter, so delays replay
    exactly under a fixed seed.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def delay_s(self, attempt: int, event: int) -> float:
        base = min(self.backoff_cap_s,
                   self.backoff_base_s * 2.0 ** max(0, attempt - 1))
        if self.jitter == 0.0:
            return base
        u = 2.0 * np.random.default_rng((self.seed, event)).random() - 1.0
        return base * (1.0 + self.jitter * u)


# ---------------------------------------------------------------------------
# engine failover
# ---------------------------------------------------------------------------


class FallbackPolicy:
    """Demotion ladder across serving engines.

    After ``failures_before_demote`` *consecutive* dispatch failures,
    the engine rebuilds its executor cache one rung down
    `SERVE_FALLBACKS` (megakernel → xnor → xla; *_xla → xla).  Because
    every engine is bit-identical (the repo's bedrock invariant),
    failover is logit-exact — a demoted engine serves the same bits
    the primary would have.

    The megakernel family packs params differently
    (`pack_bnn_params_megakernel`) from the fused family
    (`pack_bnn_params_fused`), so the policy holds both param sets and
    skips ladder rungs it has no params for.
    """

    def __init__(self, *, fused_params=None, mega_params=None,
                 failures_before_demote: int = 2, warm: bool = True):
        if failures_before_demote < 1:
            raise ValueError("failures_before_demote must be >= 1")
        self.fused_params = fused_params
        self.mega_params = mega_params
        self.failures_before_demote = int(failures_before_demote)
        self.warm = warm

    def _has_params(self, engine: str) -> bool:
        if engine.startswith("megakernel"):
            return self.mega_params is not None
        return self.fused_params is not None

    def params_for(self, engine: str):
        if not self._has_params(engine):
            raise ValueError(f"no packed params for engine {engine!r}")
        if engine.startswith("megakernel"):
            return self.mega_params
        return self.fused_params

    def next_engine(self, current: str) -> Optional[str]:
        """The first ladder rung below ``current`` we hold params for,
        or None when there is nowhere left to demote."""
        from repro.core.bnn import SERVE_FALLBACKS

        for rung in SERVE_FALLBACKS.get(current, ()):
            if self._has_params(rung):
                return rung
        return None
