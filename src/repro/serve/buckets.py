"""Shape buckets: the ladder of batch sizes the serving engine compiles.

XLA compiles one executable per input shape, so a serving system that
dispatched every request at its exact batch size would recompile on
every novel request count — tens of seconds each on the BNN chain.
Instead requests are padded up to a small ladder of bucket sizes
(default 1/8/32/128) and every bucket's executable is compiled once
(ideally at warmup); steady-state traffic then never compiles.

Padding is mathematically free for this model: the BNN forward is
per-sample independent (convs act per image, FCs per row, inference BN
uses fixed statistics), so the logits of the real rows are bit-identical
whether the batch carries 3 images or 3 real + 5 padding images — the
core correctness claim of bucketing, asserted for every engine x
conv_impl pair in ``tests/test_serve.py``.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

# Batch-size ladder. Small enough that warmup compiles stay cheap,
# geometric enough that padding waste is bounded (<= ~4x at the seams,
# far less in aggregate under mixed traffic — BENCH_serving.json
# records the realized padding overhead).
DEFAULT_BUCKETS = (1, 8, 32, 128)


def normalize_buckets(buckets: Sequence[int]) -> tuple[int, ...]:
    """Sorted, deduplicated, validated bucket ladder."""
    out = sorted(set(int(b) for b in buckets))
    if not out or out[0] < 1:
        raise ValueError(f"buckets must be positive ints, got {buckets!r}")
    return tuple(out)


def mesh_buckets(buckets: Sequence[int], devices: int) -> tuple[int, ...]:
    """The ladder a mesh-sharded engine compiles: every rung rounded UP
    to a multiple of ``devices`` (then normalized — collapsed rungs
    dedup), so each dispatched batch divides the 1-D serving mesh and
    every device receives the same shard shape (DESIGN.md §10). With 8
    devices the default 1/8/32/128 ladder becomes 8/32/128: light
    traffic pays at most ``devices - 1`` bit-neutral pad rows per
    dispatch, the price of keeping the forward collective-free."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if devices == 1:
        return normalize_buckets(buckets)
    return normalize_buckets(
        -(-int(b) // devices) * devices for b in buckets
    )


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n. ``n`` must not exceed the largest bucket
    (the micro-batcher never assembles more rows than that)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} rows exceeds largest bucket {buckets[-1]}")


def pad_to_bucket(images: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a ``[n, ...]`` image batch with zero rows up to ``bucket``.

    Zero images are valid model inputs (the first conv consumes real
    values), so the padded rows execute normally and their logits are
    discarded; they cannot perturb the real rows (per-sample
    independence, see module docstring).
    """
    n = images.shape[0]
    if n > bucket:
        raise ValueError(f"{n} rows do not fit bucket {bucket}")
    if n == bucket:
        return images
    pad = np.zeros((bucket - n,) + images.shape[1:], dtype=images.dtype)
    return np.concatenate([np.asarray(images), pad], axis=0)


__all__ = ["DEFAULT_BUCKETS", "mesh_buckets", "normalize_buckets",
           "bucket_for", "pad_to_bucket"]
