"""Serving statistics: one mutable recorder threaded through the queue,
executor cache and engine, plus the snapshot schema every surface
(`launch/serve_bnn.py`, `benchmarks/serving.py`, tests) reads.

Snapshot schema (``ServeStats.snapshot()``)::

    {"scheduler": "bucket" | "continuous",
     "requests": {"submitted": int, "completed": int,
                  "images_submitted": int, "images_completed": int,
                  "rejected": int, "images_rejected": int,
                  "expired": int, "images_expired": int,   # deadline
                  "failed": int, "images_failed": int,     # retries gone
                  "retried": int},        # requests touched by a retry
     "batches": {"dispatched": int, "real_rows": int, "padded_rows": int,
                 "dispatched_rows": int,           # real + padded
                 "padding_overhead": float,        # padded / (real+padded)
                 "pad_row_fraction": float,        # padded / dispatched_rows
                 "per_bucket": {bucket: count},    # dispatch counts per
                                                   # bucket rung / extent
                 "bucket_hit_rate": {bucket: fraction of dispatches},
                 "flush_reasons": {"full"|"max_wait"|"drain": count}},
     "executors": {"compiles": int, "hits": int, "misses": int,
                   "keys": [str, ...]},            # cache keys built
     "latency_s": {"count": int, "mean": float,
                   "p50": float, "p95": float, "p99": float, "max": float},
     "throughput": {"images_per_s": float, "wall_s": float},
     "slo": {"slo_s": float | None, "images_within_slo": int,
             "goodput_images_per_s": float},       # within-SLO imgs / wall
     "dispatch": {"retries": int,                  # batch redispatches
                  "fallbacks": int,                # engine demotions
                  "engine_path": ["old->new", ...]},
     "mesh": {"shrinks": int, "devices": int | None},
     "degraded": bool}    # any fallback or mesh shrink happened

``scheduler`` labels which dispatch policy produced the numbers (the
bucket ladder or the continuous/ragged scheduler, DESIGN.md §7/§9); the
``per_bucket`` map then keys on bucket rungs or tile-padded extent
classes respectively. ``pad_row_fraction`` is the pad-row waste the
continuous scheduler exists to remove — BENCH_serving.json reports it
per scheduler side by side. Goodput counts only images whose request
completed within ``slo_s`` (0.0 goodput and an empty within-SLO count
when no SLO is configured).

Latency is measured request-submit -> request-complete on the engine's
(injectable) clock, so the deterministic tests drive it with a fake
clock and the CLI with ``time.monotonic``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    ordered = sorted(xs)
    rank = max(0, min(len(ordered) - 1, int(round(q / 100.0 * (len(ordered) - 1)))))
    return ordered[rank]


@dataclasses.dataclass
class ServeStats:
    """Mutable counters; the engine owns one instance per lifetime.

    ``scheduler`` is a label only (snapshot provenance); ``slo_s``, when
    set, makes ``on_complete`` tally within-SLO images for the goodput
    figure.
    """

    scheduler: str = "bucket"
    slo_s: Optional[float] = None
    submitted_requests: int = 0
    submitted_images: int = 0
    completed_requests: int = 0
    completed_images: int = 0
    rejected_requests: int = 0
    rejected_images: int = 0
    expired_requests: int = 0
    expired_images: int = 0
    failed_requests: int = 0
    failed_images: int = 0
    retried_requests: int = 0
    batch_retries: int = 0
    dispatch_fallbacks: int = 0
    engine_path: list = dataclasses.field(default_factory=list)
    mesh_shrinks: int = 0
    mesh_devices: Optional[int] = None
    images_within_slo: int = 0
    dispatched_batches: int = 0
    real_rows: int = 0
    padded_rows: int = 0
    bucket_dispatches: dict = dataclasses.field(default_factory=dict)
    flush_reasons: dict = dataclasses.field(default_factory=dict)
    executor_compiles: int = 0
    executor_hits: int = 0
    executor_misses: int = 0
    executor_keys: list = dataclasses.field(default_factory=list)
    latencies_s: list = dataclasses.field(default_factory=list)
    wall_start: Optional[float] = None
    wall_end: Optional[float] = None

    # -- recording hooks ---------------------------------------------------
    def on_submit(self, n_images: int) -> None:
        self.submitted_requests += 1
        self.submitted_images += n_images

    def on_dispatch(self, bucket: int, real: int, reason: str) -> None:
        self.dispatched_batches += 1
        self.real_rows += real
        self.padded_rows += bucket - real
        self.bucket_dispatches[bucket] = self.bucket_dispatches.get(bucket, 0) + 1
        self.flush_reasons[reason] = self.flush_reasons.get(reason, 0) + 1

    def on_complete(self, n_images: int, latency_s: float) -> None:
        self.completed_requests += 1
        self.completed_images += n_images
        self.latencies_s.append(latency_s)
        if self.slo_s is not None and latency_s <= self.slo_s:
            self.images_within_slo += n_images

    def on_reject(self, n_images: int) -> None:
        """An admission-control rejection (continuous scheduler's
        ``max_queue_rows`` bound): the request never entered the queue."""
        self.rejected_requests += 1
        self.rejected_images += n_images

    def on_expire(self, n_images: int) -> None:
        """A request's deadline passed before its logits did — completed
        as a `DeadlineExceeded` result (DESIGN.md §11)."""
        self.expired_requests += 1
        self.expired_images += n_images

    def on_fail(self, n_images: int) -> None:
        """A request's batch exhausted its retry budget — completed as a
        `RequestFailed` result."""
        self.failed_requests += 1
        self.failed_images += n_images

    def on_retry(self, n_requests: int) -> None:
        """A failed batch was re-enqueued at the queue front; counts one
        batch retry and every live request riding in it."""
        self.batch_retries += 1
        self.retried_requests += n_requests

    def on_fallback(self, old_engine: str, new_engine: str) -> None:
        self.dispatch_fallbacks += 1
        self.engine_path.append(f"{old_engine}->{new_engine}")

    def on_shrink(self, old_devices: int, new_devices: int) -> None:
        self.mesh_shrinks += 1
        self.mesh_devices = new_devices

    def on_executor(self, key: str, *, hit: bool, compiled: bool) -> None:
        if hit:
            self.executor_hits += 1
        else:
            self.executor_misses += 1
            self.executor_keys.append(key)
        if compiled:
            self.executor_compiles += 1

    def mark_wall(self, t: float) -> None:
        if self.wall_start is None:
            self.wall_start = t
        self.wall_end = t

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        total_rows = self.real_rows + self.padded_rows
        wall = (
            (self.wall_end - self.wall_start)
            if self.wall_start is not None and self.wall_end is not None
            else 0.0
        )
        lat = self.latencies_s
        return {
            "scheduler": self.scheduler,
            "requests": {
                "submitted": self.submitted_requests,
                "completed": self.completed_requests,
                "images_submitted": self.submitted_images,
                "images_completed": self.completed_images,
                "rejected": self.rejected_requests,
                "images_rejected": self.rejected_images,
                "expired": self.expired_requests,
                "images_expired": self.expired_images,
                "failed": self.failed_requests,
                "images_failed": self.failed_images,
                "retried": self.retried_requests,
            },
            "batches": {
                "dispatched": self.dispatched_batches,
                "real_rows": self.real_rows,
                "padded_rows": self.padded_rows,
                "dispatched_rows": total_rows,
                "padding_overhead": (
                    self.padded_rows / total_rows if total_rows else 0.0
                ),
                "pad_row_fraction": (
                    self.padded_rows / total_rows if total_rows else 0.0
                ),
                "per_bucket": dict(sorted(self.bucket_dispatches.items())),
                "bucket_hit_rate": {
                    b: c / self.dispatched_batches
                    for b, c in sorted(self.bucket_dispatches.items())
                } if self.dispatched_batches else {},
                "flush_reasons": dict(sorted(self.flush_reasons.items())),
            },
            "executors": {
                "compiles": self.executor_compiles,
                "hits": self.executor_hits,
                "misses": self.executor_misses,
                "keys": list(self.executor_keys),
            },
            "latency_s": {
                "count": len(lat),
                "mean": sum(lat) / len(lat) if lat else 0.0,
                "p50": percentile(lat, 50),
                "p95": percentile(lat, 95),
                "p99": percentile(lat, 99),
                "max": max(lat) if lat else 0.0,
            },
            "throughput": {
                "images_per_s": (
                    self.completed_images / wall if wall > 0 else 0.0
                ),
                "wall_s": wall,
            },
            "slo": {
                "slo_s": self.slo_s,
                "images_within_slo": self.images_within_slo,
                "goodput_images_per_s": (
                    self.images_within_slo / wall
                    if wall > 0 and self.slo_s is not None else 0.0
                ),
            },
            "dispatch": {
                "retries": self.batch_retries,
                "fallbacks": self.dispatch_fallbacks,
                "engine_path": list(self.engine_path),
            },
            "mesh": {
                "shrinks": self.mesh_shrinks,
                "devices": self.mesh_devices,
            },
            "degraded": bool(self.dispatch_fallbacks or self.mesh_shrinks),
        }


__all__ = ["ServeStats", "percentile"]
