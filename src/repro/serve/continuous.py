"""Continuous-batching scheduler: ragged coalescing over the
variable-extent megakernel (serving engine v2, DESIGN.md §9).

The PR-4 bucket ladder pads every dispatch to a fixed rung (1/8/32/128):
pad rows burn xnor-popcount compute and full-bucket/timeout flushing
adds tail latency at awkward arrival rates. The paper's speedups come
from never wasting work on bits that don't exist; this scheduler
applies the same discipline to rows. On each ``step()`` it admits
whatever requests are queued — up to a row budget ``max_rows`` — and
concatenates their REAL rows into one contiguous ragged batch with
per-request row offsets (the existing ``Segment`` bookkeeping),
dispatching one launch whose batch extent is a tile-padded EXTENT CLASS
(``executor.extent_for``: powers of two below the sublane tile, then
tile multiples), never a bucket rung. Inside the megakernel the extent
is handled by the masked-tail batch path (``ragged=True`` through
``bnn_serve_fn``): N pads only to ``RAGGED_TILE_N``, and a tail grid
step zeroes its overhang against the traced ``n_real`` — the
dynamic-extent discipline whose precedent is
``popcount.accum_popcount_km_dyn``'s traced trip counts.

Policy knobs beyond the ladder's:

* **admission control** — ``max_queue_rows`` bounds queued rows;
  ``submit`` past the bound raises :class:`QueueFull` (counted under
  ``requests.rejected`` in the snapshot). An open-loop overload then
  sheds load at the front door instead of growing an unbounded queue
  whose every resident blows the SLO.
* **SLO-aware max-wait** — with ``slo_s`` set, the coalescing wait for
  a non-full batch shrinks as the head-of-line request's latency budget
  is consumed: the batcher keeps an EWMA of observed per-row service
  time and waits at most ``slo_s * slo_headroom - est_service(pending)``
  (never more than ``max_wait_s``). Light traffic still coalesces;
  traffic near the SLO edge dispatches immediately.

Bit-identity is inherited, not re-proven: ragged pad rows are zero
images, per-sample independence makes them bit-neutral (the §7
bucketing argument), and the masked-tail kernel path is asserted
bit-identical to the exact-N oracle in ``tests/test_megakernel.py`` —
so every request served here yields logits bit-identical to its
exact-shape execution (asserted across engine x conv_impl in
``tests/test_serve.py`` / ``tests/test_properties.py``).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.serve.engine import ServingEngine
from repro.serve.executor import (
    RaggedExecutorCache,
    default_extents,
    extent_for,
)
from repro.serve.queue import MicroBatcher

DEFAULT_MAX_ROWS = 32  # per-dispatch row budget (the ladder's top rung / 4)


class QueueFull(RuntimeError):
    """Admission control rejected a submit: queued rows would exceed
    ``max_queue_rows``. The request never entered the queue; the caller
    retries later or sheds the work.

    ``retry_after_s`` is the batcher's estimate of how long until the
    overflow clears — the service-time EWMA applied to the rows past
    the bound (falling back to the coalescing wait before the first
    observation lands). A well-behaved client backs off at least this
    long instead of hammering the front door."""

    def __init__(self, msg: str, *, retry_after_s: Optional[float] = None):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class ContinuousBatcher(MicroBatcher):
    """Ragged coalescer: FIFO admission up to a row budget, no ladder.

    Reuses the MicroBatcher's cursor/segment machinery (``_take`` and
    the split bookkeeping are scheduler-agnostic) but every batch it
    emits carries ``bucket == rows`` — exact rows out; the executor
    cache, not the queue, decides the padded extent class. ``poll``
    keeps the ladder's two flush triggers with new meanings:

    * **full** — pending rows reach ``max_rows``: dispatch a
      budget-sized batch immediately.
    * **max_wait** — the head-of-line request has waited out the
      CURRENT wait bound: dispatch everything pending (<= ``max_rows``)
      as one ragged batch. The bound is ``max_wait_s``, shrunk by the
      SLO budget when ``slo_s`` is set (see :meth:`current_wait`).
    """

    def __init__(
        self,
        *,
        max_rows: int = DEFAULT_MAX_ROWS,
        max_wait_s: float = 0.002,
        max_queue_rows: Optional[int] = None,
        slo_s: Optional[float] = None,
        slo_headroom: float = 0.5,
        clock: Callable[[], float] = time.monotonic,
    ):
        # The parent's ladder degenerates to the single budget rung —
        # max_bucket doubles as the per-dispatch row budget.
        super().__init__([int(max_rows)], max_wait_s=max_wait_s, clock=clock)
        self.max_rows = int(max_rows)
        if max_queue_rows is not None and max_queue_rows < self.max_rows:
            raise ValueError(
                f"max_queue_rows {max_queue_rows} < max_rows "
                f"{self.max_rows}: admission would reject batches the "
                f"budget could serve"
            )
        self.max_queue_rows = max_queue_rows
        self.slo_s = slo_s
        self.slo_headroom = float(slo_headroom)
        # EWMA of observed seconds-per-row across dispatches; None until
        # the first service observation lands.
        self._row_s: Optional[float] = None

    # -- producer side -----------------------------------------------------
    def submit(self, images: np.ndarray) -> int:
        images = np.asarray(images)
        n = images.shape[0] if images.ndim >= 1 else 0
        if (
            self.max_queue_rows is not None
            and self._pending_rows + max(n, 1) > self.max_queue_rows
        ):
            overflow = self._pending_rows + max(n, 1) - self.max_queue_rows
            hint = self.est_service_s(overflow)
            raise QueueFull(
                f"{self._pending_rows} rows queued + {n} > "
                f"max_queue_rows {self.max_queue_rows}",
                retry_after_s=hint if hint > 0.0 else self.max_wait_s,
            )
        return super().submit(images)

    # -- service model -----------------------------------------------------
    def note_service(self, rows: int, seconds: float) -> None:
        """Fold one dispatch observation into the per-row EWMA (the
        engine calls this after every launch; 0.3 smoothing keeps ~3-4
        dispatches of memory, enough to track warmup -> steady state)."""
        if rows < 1 or seconds <= 0.0:
            return
        per_row = seconds / rows
        self._row_s = (
            per_row if self._row_s is None
            else 0.7 * self._row_s + 0.3 * per_row
        )

    def est_service_s(self, rows: int) -> float:
        """Estimated service time of an ``rows``-row dispatch (0.0 until
        the first observation — optimistic, so cold starts coalesce)."""
        if self._row_s is None:
            return 0.0
        return self._row_s * max(rows, 1)

    def current_wait(self) -> float:
        """The coalescing bound ``poll`` holds a non-full batch to.

        Without an SLO: the static ``max_wait_s``. With one: the
        remaining latency budget of the pending work — ``slo_s *
        slo_headroom`` (headroom < 1 leaves room for queueing noise and
        the next arrival burst) minus the estimated service time of
        dispatching everything pending now — clipped to
        ``[0, max_wait_s]``. A hot queue or a slow model drives the
        bound to zero and the batch leaves immediately.
        """
        if self.slo_s is None:
            return self.max_wait_s
        budget = self.slo_s * self.slo_headroom
        budget -= self.est_service_s(min(self._pending_rows, self.max_rows))
        return max(0.0, min(self.max_wait_s, budget))

    # -- consumer side -----------------------------------------------------
    def poll(self) -> list:
        out = []
        while self._pending_rows >= self.max_rows:
            out.append(self._take(self.max_rows, self.max_rows, "full"))
        if self._pending_rows and self.oldest_wait() >= self.current_wait():
            rows = self._pending_rows
            out.append(self._take(rows, rows, "max_wait"))
        return out

    def drain(self) -> list:
        out = []
        while self._pending_rows >= self.max_rows:
            out.append(self._take(self.max_rows, self.max_rows, "drain"))
        if self._pending_rows:
            rows = self._pending_rows
            out.append(self._take(rows, rows, "drain"))
        return out


class ContinuousServingEngine(ServingEngine):
    """Serving engine v2: the continuous batcher over the ragged
    executor cache — same ``submit/step/drain/take`` surface (plus
    ``cancel``) as :class:`~repro.serve.engine.ServingEngine`, same
    bit-identity contract, different dispatch discipline.

    ``packed_params``/``engine``/``conv_impl``/``blocks`` mean exactly
    what they do for the bucket engine; ``max_rows`` bounds one
    dispatch, ``max_queue_rows`` bounds admission (:class:`QueueFull`
    on overflow), ``slo_s`` both arms the SLO-aware wait and makes the
    snapshot's goodput figure meaningful. ``warmup`` compiles every
    extent class ``default_extents(max_rows)`` instead of a ladder.

    ``mesh`` (DESIGN.md §10) shards every dispatch data-parallel over a
    1-D serving mesh: the extent ladder becomes mesh-multiple classes
    (``extent_for(..., devices=n)`` — closed under re-dispatch exactly
    like the single-device ladder) and the ragged executor pads a
    coalesced batch bit-neutrally up to its mesh-divisible extent, so a
    3-real-row batch on 8 devices dispatches at extent 8 and hands back
    exactly 3 rows. Per-request logits remain bit-identical to
    exact-shape single-device execution.
    """

    def __init__(
        self,
        packed_params: dict,
        *,
        engine: str = "xla",
        conv_impl: str = "im2col",
        blocks: object = "auto",
        max_rows: int = DEFAULT_MAX_ROWS,
        max_wait_s: float = 0.002,
        max_queue_rows: Optional[int] = None,
        slo_s: Optional[float] = None,
        slo_headroom: float = 0.5,
        mesh: object = None,
        deadline_s: Optional[float] = None,
        retry=None,
        fallback=None,
        faults=None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        # Deliberately NOT calling super().__init__: the base wires a
        # bucket MicroBatcher + bucket ExecutorCache; everything else
        # (submit validation, retry/deadline pump, _run scatter loop,
        # take/cancel) is inherited behavior over the attributes set
        # here (resilience state via the shared _init_resilience).
        from repro.serve.stats import ServeStats

        self.stats = ServeStats(scheduler="continuous", slo_s=slo_s)
        self.clock = clock
        self.batcher = ContinuousBatcher(
            max_rows=max_rows, max_wait_s=max_wait_s,
            max_queue_rows=max_queue_rows, slo_s=slo_s,
            slo_headroom=slo_headroom, clock=clock,
        )
        self.executors = RaggedExecutorCache(
            packed_params, engine=engine, conv_impl=conv_impl,
            blocks=blocks, mesh=mesh, stats=self.stats,
        )
        self.extents = default_extents(
            max_rows, tile=self.executors.tile,
            devices=self.executors.devices,
        )
        self._partial = {}
        self._filled = {}
        self.results = {}
        self._init_resilience(deadline_s, retry, fallback, faults,
                              heartbeat_timeout_s)

    def _warm_shapes(self):
        """Tile-padded extent classes instead of bucket rungs — warmed
        by both ``warmup`` and ``prewarm_fallback``."""
        return self.extents

    def submit(self, images: np.ndarray, *,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one request; raises :class:`QueueFull` (carrying a
        ``retry_after_s`` backoff hint, and counting the rejection)
        when admission control turns it away."""
        try:
            return super().submit(images, deadline_s=deadline_s)
        except QueueFull:
            n = np.asarray(images).shape[0]
            self.stats.on_reject(n)
            raise

    def _dispatch(self, batch) -> tuple[np.ndarray, int]:
        """Ragged dispatch: exact rows assembled, extent-class padding
        applied inside the executor; the service wall feeds the
        SLO-aware wait's EWMA and the stats record the extent actually
        run (pad waste = extent - real rows). Runs through the base
        engine's fault plan + NaN guard (`_execute_rows`); a faulted
        dispatch contributes no service observation."""
        x = batch.assemble(self.batcher.requests)
        extent = self.executors.extent_of(x.shape[0])
        t0 = self.clock()
        logits = self._execute_rows(x)
        self.batcher.note_service(extent, self.clock() - t0)
        return logits, extent

    def _on_remesh(self) -> None:
        # The extent ladder is device-multiple-scaled; after an elastic
        # shrink it must be recomputed at the survivor count so warmup
        # compiles the classes extent_of will actually produce.
        self.extents = default_extents(
            self.batcher.max_rows, tile=self.executors.tile,
            devices=self.executors.devices,
        )


__all__ = [
    "ContinuousBatcher",
    "ContinuousServingEngine",
    "QueueFull",
    "DEFAULT_MAX_ROWS",
    "extent_for",
]
