"""Compiled-executor cache: one jit'd BNN forward per shape bucket.

XLA specializes executables to input shapes, so each ``(bucket, engine,
conv_impl, blocks)`` combination compiles exactly once; after warmup,
steady-state traffic is pure cache hits and the compile count equals
the number of distinct buckets warmed (asserted in
``tests/test_serve.py`` and recorded in BENCH_serving.json).

The executors run :func:`repro.core.bnn.bnn_serve_fn` — the jit'd,
donation-annotated fused packed pipeline — so when ``blocks="auto"``
each Pallas launch inside the traced program resolves its tiles through
the PR-3 autotune cache (``kernels/autotune.py``): a ladder warmed once
on a machine with a populated cache compiles straight to the tuned
tilings, no re-measurement in the serving path.

``engine`` accepts every :data:`repro.core.bnn.SERVE_ENGINES` value:
``"xla"``/``"xnor"`` dispatch the per-layer fused chain
(``pack_bnn_params_fused`` params), ``"megakernel"``/
``"megakernel_xla"`` dispatch one-launch-per-stage megakernel forwards
(``pack_bnn_params_megakernel`` params, DESIGN.md §8) — the bucket
ladder, cache keys and steady-state compile invariant are identical,
so a deployment flips engines by constructing the cache with the
matching packed params and engine string.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.bnn import bnn_serve_fn
from repro.serve.stats import ServeStats

IMAGE_SHAPE = (32, 32, 3)  # the CIFAR BNN's fixed per-image shape


def blocks_key(blocks) -> str:
    """Stable cache-key fragment for a ``blocks`` config value."""
    if isinstance(blocks, str):
        return blocks
    # kernels.autotune.BlockConfig (frozen dataclass) or anything with
    # the same fields — spell the tiling out so distinct configs never
    # collide.
    return (f"bm{blocks.block_m}-bn{blocks.block_n}"
            f"-bkw{blocks.block_kw}-wg{blocks.word_group}")


class ExecutorCache:
    """Lazy per-bucket executor map with hit/miss/compile accounting."""

    def __init__(
        self,
        packed_params: dict,
        *,
        engine: str = "xla",
        conv_impl: str = "im2col",
        blocks: object = "auto",
        stats: Optional[ServeStats] = None,
    ):
        self.packed = packed_params
        self.engine = engine
        self.conv_impl = conv_impl
        self.blocks = blocks
        self.stats = stats if stats is not None else ServeStats()
        self._fns: dict[tuple, object] = {}

    def key(self, bucket: int) -> tuple:
        return (bucket, self.engine, self.conv_impl, blocks_key(self.blocks))

    def get(self, bucket: int):
        """The compiled callable for ``bucket``; builds (and counts a
        compile) on first use of that bucket."""
        k = self.key(bucket)
        fn = self._fns.get(k)
        if fn is not None:
            self.stats.on_executor("|".join(map(str, k)), hit=True,
                                   compiled=False)
            return fn
        # One miss == one jit build == one XLA compile for this shape
        # (the bucket fixes the only varying dimension).
        fn = bnn_serve_fn(engine=self.engine, conv_impl=self.conv_impl,
                          blocks=self.blocks)
        self._fns[k] = fn
        self.stats.on_executor("|".join(map(str, k)), hit=False,
                               compiled=True)
        return fn

    def run(self, images: np.ndarray) -> np.ndarray:
        """Execute the bucket-shaped batch (rows == some bucket size).

        Returns host logits ``[bucket, num_classes]``.
        """
        fn = self.get(images.shape[0])
        out = fn(self.packed, jnp.asarray(images))
        return np.asarray(out)

    def warmup(self, buckets: Sequence[int]) -> int:
        """Compile every bucket ahead of traffic (zeros input; the
        executable is shape-specialized, values are irrelevant).
        Returns the number of executors built by this call."""
        built = 0
        for b in buckets:
            if self.key(b) not in self._fns:
                built += 1
            fn = self.get(b)
            fn(self.packed, jnp.zeros((b,) + IMAGE_SHAPE,
                                      jnp.float32)).block_until_ready()
        return built

    @property
    def size(self) -> int:
        return len(self._fns)


__all__ = ["ExecutorCache", "blocks_key", "IMAGE_SHAPE"]
