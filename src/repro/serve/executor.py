"""Compiled-executor cache: one jit'd BNN forward per shape bucket.

XLA specializes executables to input shapes, so each ``(bucket, engine,
conv_impl, blocks)`` combination compiles exactly once; after warmup,
steady-state traffic is pure cache hits and the compile count equals
the number of distinct buckets warmed (asserted in
``tests/test_serve.py`` and recorded in BENCH_serving.json).

The executors run :func:`repro.core.bnn.bnn_serve_fn` — the jit'd,
donation-annotated fused packed pipeline — so when ``blocks="auto"``
each Pallas launch inside the traced program resolves its tiles through
the PR-3 autotune cache (``kernels/autotune.py``): a ladder warmed once
on a machine with a populated cache compiles straight to the tuned
tilings, no re-measurement in the serving path.

``engine`` accepts every :data:`repro.core.bnn.SERVE_ENGINES` value:
``"xla"``/``"xnor"`` dispatch the per-layer fused chain
(``pack_bnn_params_fused`` params), ``"megakernel"``/
``"megakernel_xla"`` dispatch one-launch-per-stage megakernel forwards
(``pack_bnn_params_megakernel`` params, DESIGN.md §8) — the bucket
ladder, cache keys and steady-state compile invariant are identical,
so a deployment flips engines by constructing the cache with the
matching packed params and engine string.

:class:`RaggedExecutorCache` is the continuous scheduler's variant
(DESIGN.md §9): it keys executors on tile-padded EXTENT classes instead
of bucket rungs — ``extent_for`` rounds a ragged batch up to the next
power of two below the sublane tile, then to tile multiples — and its
executors run ``bnn_serve_fn(..., ragged=True)`` so the megakernel FC
trunk pads only to the tile, never a ``block_n`` rung. The XLA compile
discipline is unchanged: one executable per extent class, all warmable
ahead of traffic.

Both caches accept a ``mesh=`` (a 1-D serving mesh from
``launch.mesh.make_serving_mesh``, DESIGN.md §10): executors are then
built with ``bnn_serve_fn(mesh=...)`` — weights replicated, batch
sharded over ``data`` — the cache key gains a device-count component
(``meshN``) so sharded executables never alias single-device ones, the
extent ladder scales to ``devices * extent_for(ceil(n/devices))`` so
every dispatched shape divides the mesh, and any out-of-ladder batch is
padded with bit-neutral zero rows to the next device multiple (sliced
back to exact rows) instead of crashing. The steady-state compile
invariant is unchanged: one executable per (shape class x mesh) key.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.bnn import bnn_serve_fn
from repro.kernels.ops import RAGGED_TILE_N
from repro.serve.stats import ServeStats

IMAGE_SHAPE = (32, 32, 3)  # the CIFAR BNN's fixed per-image shape

_UNSET = object()  # rebuild() sentinel: mesh=None is a meaningful override


def extent_for(n: int, *, tile: int = RAGGED_TILE_N, devices: int = 1) -> int:
    """The tile-padded extent class a ragged ``n``-row batch dispatches
    at: the next power of two while below ``tile`` (so light traffic
    compiles 1/2/4-row executables instead of padding everything to a
    full tile), then the next ``tile`` multiple. Monotone in ``n`` and
    ``extent_for(e) == e`` for every class ``e`` — the class set is
    closed under re-dispatch.

    ``devices > 1`` (mesh-sharded dispatch, DESIGN.md §10) applies the
    SAME ladder to the per-device shard and scales back up: the class is
    ``devices * extent_for(ceil(n / devices))``, so every class divides
    the mesh and each device sees a shard extent that is itself a valid
    single-device class (1/2/4 then tile multiples — full-tile classes
    land on ``tile x devices`` multiples globally). Monotonicity and
    closure under re-dispatch carry over because ``extent_for`` is
    idempotent on its own classes."""
    if n < 1:
        raise ValueError(f"batch needs >= 1 rows, got {n}")
    if devices > 1:
        return devices * extent_for(-(-n // devices), tile=tile)
    if n < tile:
        e = 1
        while e < n:
            e *= 2
        return min(e, tile)
    return -(-n // tile) * tile


def default_extents(max_rows: int, *, tile: int = RAGGED_TILE_N,
                    devices: int = 1) -> tuple[int, ...]:
    """Every extent class ``extent_for`` can produce for batches up to
    ``max_rows`` — the continuous engine's warmup set (compile count is
    ``log2(tile) + max_rows/tile``, e.g. 7 classes for tile 8, max 32).
    With ``devices > 1`` the set is the per-device-shard class set
    scaled by the device count (same cardinality bound, taken over
    ``ceil(max_rows / devices)`` shard rows)."""
    if max_rows < 1:
        raise ValueError(f"max_rows must be >= 1, got {max_rows}")
    if devices > 1:
        return tuple(
            devices * e
            for e in default_extents(-(-max_rows // devices), tile=tile)
        )
    cap = extent_for(max_rows, tile=tile)
    exts: list[int] = []
    e = 1
    while e < tile:
        if e <= cap:
            exts.append(e)
        e *= 2
    exts.extend(range(tile, cap + 1, tile))
    return tuple(exts)


def blocks_key(blocks) -> str:
    """Stable cache-key fragment for a ``blocks`` config value."""
    if isinstance(blocks, str):
        return blocks
    # kernels.autotune.BlockConfig (frozen dataclass) or anything with
    # the same fields — spell the tiling out so distinct configs never
    # collide.
    return (f"bm{blocks.block_m}-bn{blocks.block_n}"
            f"-bkw{blocks.block_kw}-wg{blocks.word_group}")


class ExecutorCache:
    """Lazy per-bucket executor map with hit/miss/compile accounting."""

    def __init__(
        self,
        packed_params: dict,
        *,
        engine: str = "xla",
        conv_impl: str = "im2col",
        blocks: object = "auto",
        mesh: object = None,
        stats: Optional[ServeStats] = None,
    ):
        from repro.distributed.sharding import mesh_devices

        self.packed = packed_params
        self.engine = engine
        self.conv_impl = conv_impl
        self.blocks = blocks
        self.mesh = mesh
        self.devices = mesh_devices(mesh)
        self.stats = stats if stats is not None else ServeStats()
        self._fns: dict[tuple, object] = {}

    def _mesh_key(self) -> tuple:
        """Device-count key component — present only for meshed caches,
        so single-device keys (and the stats strings tests/benchmarks
        pin) are unchanged, while a mesh-sharded executable can never
        alias a single-device one of the same bucket shape."""
        return (f"mesh{self.devices}",) if self.mesh is not None else ()

    def key(self, bucket: int) -> tuple:
        return (bucket, self.engine, self.conv_impl,
                blocks_key(self.blocks)) + self._mesh_key()

    def _build(self):
        return bnn_serve_fn(engine=self.engine, conv_impl=self.conv_impl,
                            blocks=self.blocks, mesh=self.mesh)

    def get(self, bucket: int):
        """The compiled callable for ``bucket``; builds (and counts a
        compile) on first use of that bucket."""
        k = self.key(bucket)
        fn = self._fns.get(k)
        if fn is not None:
            self.stats.on_executor("|".join(map(str, k)), hit=True,
                                   compiled=False)
            return fn
        # One miss == one jit build == one XLA compile for this shape
        # (the bucket fixes the only varying dimension).
        fn = self._build()
        self._fns[k] = fn
        self.stats.on_executor("|".join(map(str, k)), hit=False,
                               compiled=True)
        return fn

    def run(self, images: np.ndarray) -> np.ndarray:
        """Execute the bucket-shaped batch (rows == some bucket size).

        Returns host logits ``[rows, num_classes]`` for the rows passed
        in. On a meshed cache a batch whose row count does not divide
        the device count is padded with bit-neutral zero rows up to the
        next device multiple (and the pad rows' logits sliced back off)
        rather than crashing in shard_map — the engine's ladder is
        normalized to device multiples (``buckets.mesh_buckets``), so
        this pad only fires for out-of-ladder dispatch.
        """
        n = images.shape[0]
        run_n = -(-n // self.devices) * self.devices
        fn = self.get(run_n)
        if run_n != n:
            pad = np.zeros((run_n - n,) + images.shape[1:], images.dtype)
            images = np.concatenate([np.asarray(images), pad], axis=0)
        out = fn(self.packed, jnp.asarray(images))
        return np.asarray(out)[:n]

    def _ctor_kwargs(self) -> dict:
        return dict(engine=self.engine, conv_impl=self.conv_impl,
                    blocks=self.blocks, mesh=self.mesh, stats=self.stats)

    def rebuild(self, *, packed=None, engine: Optional[str] = None,
                mesh=_UNSET):
        """A fresh cache of the same class with ``packed``/``engine``/
        ``mesh`` overridden — the failover and mesh-shrink paths
        (DESIGN.md §11).  The stats recorder is SHARED with the old
        cache, so compile/hit accounting stays continuous across a
        demotion or shrink; executables are not carried over (they are
        specialized to the old engine/mesh)."""
        kw = self._ctor_kwargs()
        if engine is not None:
            kw["engine"] = engine
        if mesh is not _UNSET:
            kw["mesh"] = mesh
        return type(self)(self.packed if packed is None else packed, **kw)

    def warmup(self, buckets: Sequence[int]) -> int:
        """Compile every bucket ahead of traffic (zeros input; the
        executable is shape-specialized, values are irrelevant).
        Returns the number of executors built by this call."""
        built = 0
        for b in buckets:
            if self.key(b) not in self._fns:
                built += 1
            fn = self.get(b)
            fn(self.packed, jnp.zeros((b,) + IMAGE_SHAPE,
                                      jnp.float32)).block_until_ready()
        return built

    @property
    def size(self) -> int:
        return len(self._fns)


class RaggedExecutorCache(ExecutorCache):
    """Executor cache keyed on tile-padded extent classes (DESIGN.md §9).

    The continuous scheduler assembles EXACT-row batches; ``run`` rounds
    each up to its :func:`extent_for` class, zero-pads only that far
    (per-sample independence makes pad rows bit-neutral, exactly as in
    the bucket path) and slices the real rows back out. Executors are
    built with ``bnn_serve_fn(..., ragged=True)`` so the megakernel FC
    trunk takes the masked-tail batch path — pad-to-tile instead of
    pad-to-``block_n``-rung — which is a documented no-op for the
    exact-shape XLA engines. The cache key carries a ``ragged`` marker
    so a process running both schedulers over one stats recorder never
    aliases executables across dispatch disciplines.
    """

    def __init__(self, packed_params: dict, *, tile: int = RAGGED_TILE_N,
                 **kwargs):
        super().__init__(packed_params, **kwargs)
        self.tile = int(tile)

    def _ctor_kwargs(self) -> dict:
        kw = super()._ctor_kwargs()
        kw["tile"] = self.tile
        return kw

    def key(self, extent: int) -> tuple:
        return (extent, self.engine, self.conv_impl,
                blocks_key(self.blocks), "ragged") + self._mesh_key()

    def _build(self):
        return bnn_serve_fn(engine=self.engine, conv_impl=self.conv_impl,
                            blocks=self.blocks, ragged=True, mesh=self.mesh)

    def extent_of(self, n: int) -> int:
        return extent_for(n, tile=self.tile, devices=self.devices)

    def run(self, images: np.ndarray) -> np.ndarray:
        """Execute an exact-row ragged batch at its extent class.

        Returns host logits ``[n, num_classes]`` for the REAL rows only.
        """
        n = images.shape[0]
        extent = self.extent_of(n)
        fn = self.get(extent)
        if extent != n:
            pad = np.zeros((extent - n,) + images.shape[1:], images.dtype)
            images = np.concatenate([np.asarray(images), pad], axis=0)
        out = fn(self.packed, jnp.asarray(images))
        return np.asarray(out)[:n]


__all__ = [
    "ExecutorCache",
    "RaggedExecutorCache",
    "blocks_key",
    "default_extents",
    "extent_for",
    "IMAGE_SHAPE",
]
