"""Serving-level block selection: pick ONE kernel tiling for the whole
deployed engine, tuned for the steady-state (batched) buckets.

The PR-3 autotuner (``kernels/autotune.py``) tunes each GEMM/conv shape
in isolation. A serving deployment wants the complement: a single
``blocks`` config for the engine (the executor cache compiles one
program per bucket; per-layer shapes inside it are fixed by the
bucket), chosen to maximize throughput at the bucket the fleet actually
runs — the largest one, where batching amortizes the per-dispatch fixed
work. ``tune_serving_blocks`` measures whole ``bnn_serve_fn`` forwards
across a small candidate list at that bucket and persists the winner in
the SAME autotune JSON cache (kernel name ``"bnn_serve"``, shape key =
engine/conv_impl/bucket, stamped with jax version + device kind and
ignored on mismatch, exactly like the per-kernel entries). Warmup then
reuses the cached entry via :func:`load_serving_blocks` — steady-state
serving never re-measures.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence, Union

import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.autotune import AUTO, BlockConfig

SERVE_KERNEL = "bnn_serve"

Blocks = Union[str, BlockConfig]


def serving_shape(engine: str, conv_impl: str, bucket: int) -> dict:
    """The autotune-cache shape key for one serving configuration."""
    return {"engine": engine, "conv": conv_impl, "bucket": bucket}


def default_serving_candidates(bucket: int) -> list[Blocks]:
    """Per-shape AUTO plus a few throughput-oriented global tilings.

    The big-``block_n`` entries matter at large buckets: conv GEMM N is
    ``bucket * OH * OW``, so wider N tiles cut grid steps (and their
    per-step overhead) once batching has made N large.
    """
    cands: list[Blocks] = [AUTO]
    for bm, bn, bkw, wg in (
        (512, 512, 64, 32),
        (512, 1024, 64, 64),
        (512, 2048, 64, 32),
        (256, 512, 32, 8),
    ):
        if bn <= max(1024, bucket * 1024):  # don't over-tile tiny buckets
            cands.append(BlockConfig(block_m=bm, block_n=bn, block_kw=bkw,
                                     word_group=wg))
    return cands


def load_serving_blocks(
    engine: str, conv_impl: str, bucket: int
) -> Blocks:
    """Cached serving config for this engine/conv_impl/bucket, or AUTO.

    Entries recorded under a different jax version or device kind are
    ignored by the underlying :func:`kernels.autotune.load_entry`."""
    if not autotune.cache_enabled():
        return AUTO
    cfg = autotune.load_entry(
        SERVE_KERNEL, serving_shape(engine, conv_impl, bucket)
    )
    return cfg if cfg is not None else AUTO


def tune_serving_blocks(
    packed_params: dict,
    bucket: int,
    *,
    engine: str = "xnor",
    conv_impl: str = "im2col",
    candidates: Optional[Iterable[Blocks]] = None,
    repeats: int = 1,
    cache: bool = True,
    timings: Optional[dict] = None,
) -> Blocks:
    """Measure whole-forward wall time per candidate at ``bucket``;
    return (and optionally cache) the fastest config.

    Timing uses the shared :func:`kernels.autotune.time_call` protocol
    (one warmup/compile call, then the mean of ``repeats``). Pass a
    dict as ``timings`` to receive per-candidate seconds keyed by the
    candidate (``"auto"`` or a ``BlockConfig``).
    """
    from repro.core.bnn import bnn_serve_fn  # local: avoid import cycle
    from repro.serve.executor import IMAGE_SHAPE

    # A fresh operand per call: serve_fn donates its images buffer on
    # accelerators, so a captured array would die on the first call.
    def operand():
        return jnp.zeros((bucket,) + IMAGE_SHAPE, jnp.float32)

    cands = list(candidates) if candidates is not None else (
        default_serving_candidates(bucket)
    )
    best, best_t = None, float("inf")
    for blocks in cands:
        fn = bnn_serve_fn(engine=engine, conv_impl=conv_impl, blocks=blocks)
        t = autotune.time_call(lambda: fn(packed_params, operand()), repeats)
        if timings is not None:
            timings[blocks] = t
        if t < best_t:
            best, best_t = blocks, t
    assert best is not None, "empty candidate list"
    if cache and autotune.cache_enabled() and isinstance(best, BlockConfig):
        autotune.save_entry(
            SERVE_KERNEL, serving_shape(engine, conv_impl, bucket), best,
            wall_s=best_t,
        )
    return best


__all__ = [
    "SERVE_KERNEL",
    "serving_shape",
    "default_serving_candidates",
    "load_serving_blocks",
    "tune_serving_blocks",
]
