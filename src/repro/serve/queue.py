"""Request queue + micro-batcher: coalesce variable-count image
requests into bucket-sized batches (DESIGN.md §7).

The batcher is deliberately synchronous and clock-injected: ``poll()``
makes every flush decision from an explicit ``clock()`` reading, so the
deterministic tests drive it with a fake clock and production drives it
with ``time.monotonic``. No threads — the engine's dispatch loop is the
only consumer.

Flush rules (checked in this order by ``poll()``):

* **full** — pending rows fill the largest bucket: emit a full batch
  immediately (no reason to wait once a dispatch is maximal).
* **max_wait** — the oldest pending request has waited ``max_wait_s``:
  emit ALL pending rows in one batch at the smallest covering bucket
  (latency bound: no request waits more than one max_wait + one model
  dispatch).
* **drain** — ``drain()`` flushes the remainder regardless of age
  (shutdown / end of a load run).

Invariants (property-tested in ``tests/test_properties.py``): no row is
dropped, no row is duplicated, and rows stay FIFO — requests are packed
into batches in submission order, a request's rows stay in order, and a
request submitted earlier never lands in a later batch than a request
submitted after it. Requests larger than the biggest bucket are split
across consecutive batches (``Segment.offset`` tells the engine where
each slice lands in the request's result).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serve.buckets import (
    DEFAULT_BUCKETS,
    bucket_for,
    normalize_buckets,
    pad_to_bucket,
)


@dataclasses.dataclass
class Request:
    """One inference request: ``images [n, H, W, C]``."""

    rid: int
    images: np.ndarray
    t_submit: float

    @property
    def n(self) -> int:
        return self.images.shape[0]


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous slice of one request inside one batch."""

    rid: int
    batch_row: int   # first row inside the assembled batch
    length: int      # rows in this slice
    offset: int      # first row inside the request (for split requests)


@dataclasses.dataclass
class Batch:
    """One bucket-shaped unit of work: ``rows <= bucket`` real rows."""

    bucket: int
    segments: list[Segment]
    rows: int
    reason: str  # "full" | "max_wait" | "drain"

    def assemble(self, requests: dict[int, Request]) -> np.ndarray:
        """Concatenate the segment slices and zero-pad to the bucket.

        A segment whose request was cancelled between batching and
        assembly contributes zero rows in place (zero images are valid
        inputs, discarded at scatter time) — the other segments'
        ``batch_row`` offsets stay honest, so one cancellation never
        corrupts its batchmates' logits.
        """
        parts = []
        proto = None
        for s in self.segments:
            req = requests.get(s.rid)
            if req is None:
                parts.append(s)  # placeholder, materialized below
            else:
                part = req.images[s.offset:s.offset + s.length]
                proto = part
                parts.append(part)
        if proto is None:
            raise ValueError(
                "every request in this batch was cancelled; nothing to "
                "assemble"
            )
        parts = [
            np.zeros((p.length,) + proto.shape[1:], proto.dtype)
            if isinstance(p, Segment) else p
            for p in parts
        ]
        return pad_to_bucket(np.concatenate(parts, axis=0), self.bucket)


class MicroBatcher:
    """FIFO request coalescer over a bucket ladder."""

    def __init__(
        self,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        *,
        max_wait_s: float = 0.002,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.buckets = normalize_buckets(buckets)
        self.max_bucket = self.buckets[-1]
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self._next_rid = 0
        # (rid, offset) cursors into pending requests, FIFO.
        self._pending: deque[tuple[int, int]] = deque()
        self._pending_rows = 0
        self._row_shape: Optional[tuple[int, ...]] = None
        self.requests: dict[int, Request] = {}

    # -- producer side -----------------------------------------------------
    def submit(self, images: np.ndarray) -> int:
        """Enqueue one request; returns its request id.

        Rejects a mismatched per-row shape HERE, while the request is
        still the caller's problem — once rows are coalesced, a bad
        request would take its whole batch (other requests included)
        down with it at assemble time.
        """
        images = np.asarray(images)
        if images.ndim < 2 or images.shape[0] < 1:
            raise ValueError(f"request needs >= 1 leading rows, got "
                             f"shape {images.shape}")
        if self._row_shape is None:
            self._row_shape = images.shape[1:]
        elif images.shape[1:] != self._row_shape:
            raise ValueError(
                f"request row shape {images.shape[1:]} != this queue's "
                f"{self._row_shape}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, images, self.clock())
        self._pending.append((rid, 0))
        self._pending_rows += images.shape[0]
        return rid

    @property
    def pending_rows(self) -> int:
        return self._pending_rows

    def oldest_wait(self) -> float:
        """Seconds the head-of-line request has been pending (0 if none)."""
        if not self._pending:
            return 0.0
        rid, _ = self._pending[0]
        return self.clock() - self.requests[rid].t_submit

    # -- consumer side -----------------------------------------------------
    def _take(self, rows: int, bucket: int, reason: str) -> Batch:
        """Pop ``rows`` rows off the queue head into one batch."""
        segments: list[Segment] = []
        filled = 0
        while filled < rows:
            rid, offset = self._pending.popleft()
            avail = self.requests[rid].n - offset
            take = min(avail, rows - filled)
            segments.append(Segment(rid, filled, take, offset))
            filled += take
            if take < avail:  # split: the rest stays at the queue head
                self._pending.appendleft((rid, offset + take))
        self._pending_rows -= rows
        return Batch(bucket=bucket, segments=segments, rows=rows,
                     reason=reason)

    def poll(self) -> list[Batch]:
        """Apply the flush rules at the current clock; may return []."""
        out: list[Batch] = []
        while self._pending_rows >= self.max_bucket:
            out.append(self._take(self.max_bucket, self.max_bucket, "full"))
        if self._pending_rows and self.oldest_wait() >= self.max_wait_s:
            rows = self._pending_rows
            out.append(self._take(rows, bucket_for(rows, self.buckets),
                                  "max_wait"))
        return out

    def drain(self) -> list[Batch]:
        """Flush everything pending, age notwithstanding."""
        out: list[Batch] = []
        while self._pending_rows >= self.max_bucket:
            out.append(self._take(self.max_bucket, self.max_bucket, "drain"))
        if self._pending_rows:
            rows = self._pending_rows
            out.append(self._take(rows, bucket_for(rows, self.buckets),
                                  "drain"))
        return out

    def forget(self, rid: int) -> Optional[Request]:
        """Drop a request's images — on completion (the engine calls
        this once all of a request's rows have produced logits) or on
        cancellation.

        A cancelled request may still have a pending cursor: after a
        split (one slice already dispatched, the rest at the queue
        head), dropping only the ``requests`` entry would orphan the
        cursor — the next ``_take`` would build a Segment for a ghost
        rid and ``assemble`` would take the whole batch (other requests'
        rows included) down with a KeyError. So the cursor and its
        remaining-row count are retired here too, keeping the
        no-drop/no-dup invariant over the rows that still exist
        (regression-tested in ``tests/test_serve.py``).
        """
        req = self.requests.pop(rid, None)
        if req is None:
            return None
        for i, (r, off) in enumerate(self._pending):
            if r == rid:
                del self._pending[i]
                self._pending_rows -= req.n - off
                break
        return req


__all__ = ["Request", "Segment", "Batch", "MicroBatcher"]
