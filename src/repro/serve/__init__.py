"""Batched serving for the packed BNN: request queue + micro-batcher,
shape-bucket ladder, compiled-executor cache, and serving stats — plus
the v2 continuous-batching scheduler over the ragged megakernel path.

    from repro.serve import ServingEngine
    eng = ServingEngine(pack_bnn_params_fused(params), engine="xla")
    eng.warmup()
    rid = eng.submit(images)          # [n, 32, 32, 3]
    eng.step(); eng.drain()
    logits = eng.take(rid)            # [n, 10], bit-identical to
                                      # bnn_apply_fused on images alone

``ContinuousServingEngine`` has the same surface but replaces
pad-to-bucket dispatch with ragged coalescing over tile-padded extent
classes (DESIGN.md §9) plus admission control and an SLO-aware wait.

Both engines carry the §11 resilience layer (``repro.serve.faults``):
per-request deadlines, bounded retry with backoff, bit-identical
engine failover (`FallbackPolicy`), elastic mesh shrink on device
loss, and a deterministic fault-injection harness (`FaultPlan`).
``take()`` then returns either logits or a terminal
`DeadlineExceeded`/`RequestFailed` marker (``is_error`` discriminates).

See DESIGN.md §7/§9/§11 for the batching and failure designs and
docs/api.md for the stats/snapshot schema.
"""

from repro.serve.buckets import (
    DEFAULT_BUCKETS,
    bucket_for,
    mesh_buckets,
    normalize_buckets,
    pad_to_bucket,
)
from repro.serve.continuous import (
    DEFAULT_MAX_ROWS,
    ContinuousBatcher,
    ContinuousServingEngine,
    QueueFull,
)
from repro.serve.engine import ServingEngine
from repro.serve.faults import (
    DeadlineExceeded,
    DeviceLost,
    FallbackPolicy,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    NaNLogits,
    RequestFailed,
    RetryPolicy,
    is_error,
)
from repro.serve.executor import (
    ExecutorCache,
    RaggedExecutorCache,
    blocks_key,
    default_extents,
    extent_for,
)
from repro.serve.queue import Batch, MicroBatcher, Request, Segment
from repro.serve.stats import ServeStats, percentile
from repro.serve.tuning import (
    default_serving_candidates,
    load_serving_blocks,
    tune_serving_blocks,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_MAX_ROWS",
    "bucket_for",
    "mesh_buckets",
    "normalize_buckets",
    "pad_to_bucket",
    "ServingEngine",
    "ContinuousServingEngine",
    "ContinuousBatcher",
    "QueueFull",
    "ExecutorCache",
    "RaggedExecutorCache",
    "blocks_key",
    "default_extents",
    "extent_for",
    "Batch",
    "MicroBatcher",
    "Request",
    "Segment",
    "ServeStats",
    "percentile",
    "DeadlineExceeded",
    "RequestFailed",
    "is_error",
    "InjectedFault",
    "NaNLogits",
    "DeviceLost",
    "FaultSpec",
    "FaultPlan",
    "RetryPolicy",
    "FallbackPolicy",
    "default_serving_candidates",
    "load_serving_blocks",
    "tune_serving_blocks",
]
