"""Batched serving for the packed BNN: request queue + micro-batcher,
shape-bucket ladder, compiled-executor cache, and serving stats.

    from repro.serve import ServingEngine
    eng = ServingEngine(pack_bnn_params_fused(params), engine="xla")
    eng.warmup()
    rid = eng.submit(images)          # [n, 32, 32, 3]
    eng.step(); eng.drain()
    logits = eng.take(rid)            # [n, 10], bit-identical to
                                      # bnn_apply_fused on images alone

See DESIGN.md §7 for the batching design and docs/api.md for the
stats/snapshot schema.
"""

from repro.serve.buckets import (
    DEFAULT_BUCKETS,
    bucket_for,
    normalize_buckets,
    pad_to_bucket,
)
from repro.serve.engine import ServingEngine
from repro.serve.executor import ExecutorCache, blocks_key
from repro.serve.queue import Batch, MicroBatcher, Request, Segment
from repro.serve.stats import ServeStats, percentile
from repro.serve.tuning import (
    default_serving_candidates,
    load_serving_blocks,
    tune_serving_blocks,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "bucket_for",
    "normalize_buckets",
    "pad_to_bucket",
    "ServingEngine",
    "ExecutorCache",
    "blocks_key",
    "Batch",
    "MicroBatcher",
    "Request",
    "Segment",
    "ServeStats",
    "percentile",
    "default_serving_candidates",
    "load_serving_blocks",
    "tune_serving_blocks",
]
