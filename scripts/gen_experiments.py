"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json. Run after any sweep:

  PYTHONPATH=src:. python scripts/gen_experiments.py
"""

import json
import os
import sys

sys.path.insert(0, "src")
from benchmarks.roofline_table import load_cells, render  # noqa: E402

HEADER = open("docs/EXPERIMENTS.head.md").read()


def hillclimb_rows():
    rows = []
    order = [
        ("mistral-large-123b", "decode_32k", [
            ("", "baseline: packed weights, bf16 cache, GQA repeat_kv"),
            ("hc_float", "CONTROL: float (unpacked) weights"),
            ("hc1_gqa", "hc1: GQA-native grouped einsums (no KV repeat)"),
            ("hc2_carry", "hc2 REFUTED: cache in scan carry (XLA copies)"),
            ("hc3_xsys", "hc3: xs/ys cache + VMEM-scoped weight unpack"),
            ("hc4_int8kv", "hc4: int8 quantized KV cache"),
        ]),
        ("mistral-large-123b", "train_4k", [
            ("", "baseline: row/col-parallel + FSDP (post bring-up)"),
            ("hc2_carry", "(re-measure after GQA change)"),
            ("hc5_rematnames", "hc5 REFUTED: save-only-block-outputs remat"),
            ("hc6_mb16", "hc6: 16 grad-accum microbatches (capacity)"),
        ]),
        ("moonshot-v1-16b-a3b", "train_4k", [
            ("", "baseline: global-capacity MoE, FSDP expert in-dim"),
            ("hc7_expert_repl", "hc7 PARTIAL: replicate small expert stacks"),
            ("hc8_perrow", "hc8: per-row capacity + sort-based ranking"),
            ("hc9_pinned", "hc9 REFUTED: pin xe to (data, model)"),
            ("hc10_choreo", "hc10 REFUTED: pinned buffer + slice at xe"),
        ]),
        ("qwen2.5-32b", "prefill_32k", [
            ("", "baseline accounting (fusion metadata missed)"),
            ("hc11_fusemark", "hc11: fusion-body vmem_fusible detection"),
        ]),
    ]
    out = ["| cell | variant | compute_s | memory_s | collective_s | "
           "roofline step | MFU | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for arch, shape, variants in order:
        for tag, desc in variants:
            suffix = f"_{tag}" if tag else ""
            path = f"experiments/dryrun/{arch}_{shape}_single{suffix}.json"
            if not os.path.exists(path):
                continue
            d = json.load(open(path))
            if d["status"] != "ok":
                continue
            r = d["roofline"]
            out.append(
                f"| {arch} {shape} | {desc} | {r['compute_s']:.3f} "
                f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
                f"| {r['step_time_s']:.3f} | {r['mfu']:.4f} "
                f"| {r['useful_flops_fraction']:.2f} |"
            )
    return "\n".join(out)


def memory_table(tag):
    cells = load_cells(tag=tag)
    out = ["| arch | shape | mesh | per-device args (GB) | temp (GB) |",
           "|---|---|---|---|---|"]
    for c in cells:
        if c["status"] != "ok":
            continue
        m = c.get("memory_analysis", {})
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {m.get('argument_size_in_bytes', 0)/1e9:.2f} "
            f"| {m.get('temp_size_in_bytes', 0)/1e9:.2f} |"
        )
    return "\n".join(out)


def main():
    base = render(load_cells(tag=""))
    opt = render(load_cells(tag="opt"))
    doc = HEADER
    doc = doc.replace("<!--BASELINE_TABLE-->", base)
    doc = doc.replace("<!--OPT_TABLE-->", opt)
    doc = doc.replace("<!--HILLCLIMB_TABLE-->", hillclimb_rows())
    doc = doc.replace("<!--MEMORY_TABLE-->", memory_table(""))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("wrote EXPERIMENTS.md", len(doc), "chars")


if __name__ == "__main__":
    main()
