#!/usr/bin/env python
"""Docs gate for CI: README.md must exist, and every intra-repo
markdown link in the documentation set must resolve.

Checked files: README.md, DESIGN.md, ROADMAP.md, CHANGES.md and every
docs/*.md. A link is "intra-repo" when it is not an absolute URL
(http/https/mailto) and not a pure fragment (#...). Targets are
resolved relative to the file containing the link; a `path#anchor`
link checks only the path part.

  python scripts/check_docs_links.py [repo_root]
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excludes images' srcsets etc. well enough for our docs
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    docs = [root / n for n in ("README.md", "DESIGN.md", "ROADMAP.md",
                               "CHANGES.md")]
    docs += sorted((root / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def check(root: pathlib.Path) -> list[str]:
    errors = []
    readme = root / "README.md"
    if not readme.exists():
        errors.append("README.md is missing at the repo root")
    for doc in doc_files(root):
        text = doc.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                line = text.count("\n", 0, m.start()) + 1
                errors.append(
                    f"{doc.relative_to(root)}:{line}: broken link "
                    f"'{target}' (-> {resolved})"
                )
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    errors = check(root)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {len(doc_files(root))} files, all intra-repo "
              "links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
