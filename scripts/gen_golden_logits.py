"""(Re)generate the golden-logits fixture tests/golden/bnn_logits.json.

The fixture pins the PACKED CIFAR-BNN logits so kernel refactors that
silently change numerics fail tier-1 immediately (tests/test_golden.py).
Floats are stored as float32 hex strings — exact round-trip,
human-diffable.

Since the train-to-serve loop closed (DESIGN.md §12) the fixture is
generated from the committed TRAINED sign-form checkpoint
(tests/golden/bnn_trained_ckpt.npz, written by examples/bnn_cifar.py) —
the logits under regression are the ones a trained model actually
serves, not a random init's. ``--random-init SEED`` remains as a debug
escape hatch for bisecting numerics changes without a checkpoint.

Run from the repo root after an INTENTIONAL numerics change:

  PYTHONPATH=src python scripts/gen_golden_logits.py \
      --from-checkpoint tests/golden/bnn_trained_ckpt.npz
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from repro.core.binarize import QuantMode
from repro.core.bnn import (
    BNNConfig,
    bnn_apply,
    init_bnn_params,
    load_binary_checkpoint,
    pack_bnn_params,
)

IMAGE_SEED = 2024
BATCH = 4
ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = ROOT / "tests" / "golden" / "bnn_logits.json"
DEFAULT_CKPT = ROOT / "tests" / "golden" / "bnn_trained_ckpt.npz"


def compute_logits(params) -> np.ndarray:
    images = jax.random.normal(
        jax.random.PRNGKey(IMAGE_SEED), (BATCH, 32, 32, 3)
    )
    logits = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    return np.asarray(logits, np.float32)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--from-checkpoint", type=pathlib.Path, default=DEFAULT_CKPT,
        help="sign-form checkpoint (core.bnn.save_binary_checkpoint) "
             "to pin logits for [default: the committed trained ckpt]",
    )
    ap.add_argument(
        "--random-init", type=int, default=None, metavar="SEED",
        help="debug escape hatch: pin a random init instead of a "
             "checkpoint (tests/test_golden.py only accepts the "
             "checkpoint form)",
    )
    args = ap.parse_args()

    if args.random_init is not None:
        params = init_bnn_params(jax.random.PRNGKey(args.random_init))
        source = {"param_seed": args.random_init}
        src_desc = f"init_bnn_params(PRNGKey({args.random_init}))"
    else:
        params = load_binary_checkpoint(args.from_checkpoint)
        rel = args.from_checkpoint.resolve().relative_to(ROOT)
        source = {"checkpoint": str(rel)}
        src_desc = f"trained sign-form checkpoint {rel}"

    logits = compute_logits(params)
    fixture = {
        "description": (
            "PACKED (engine=xla) logits of the CIFAR BNN for "
            f"{src_desc} on "
            f"normal(PRNGKey({IMAGE_SEED}), ({BATCH}, 32, 32, 3)). "
            "float32 hex — exact. Regenerate ONLY for intentional "
            "numeric changes: scripts/gen_golden_logits.py"
        ),
        **source,
        "image_seed": IMAGE_SEED,
        "shape": list(logits.shape),
        "generated_with_jax": jax.__version__,
        "logits_hex": [[float(v).hex() for v in row] for row in logits],
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(fixture, indent=2) + "\n")
    print(f"wrote {OUT}")
    print(logits)


if __name__ == "__main__":
    main()
