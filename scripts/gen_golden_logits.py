"""(Re)generate the golden-logits fixture tests/golden/bnn_logits.json.

The fixture pins the PACKED CIFAR-BNN logits for a fixed seed so kernel
refactors that silently change numerics fail tier-1 immediately
(tests/test_golden.py). Floats are stored as float32 hex strings —
exact round-trip, human-diffable.

Run from the repo root after an INTENTIONAL numerics change:

  PYTHONPATH=src python scripts/gen_golden_logits.py
"""

from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.core.binarize import QuantMode
from repro.core.bnn import BNNConfig, bnn_apply, init_bnn_params, pack_bnn_params

PARAM_SEED = 7
IMAGE_SEED = 2024
BATCH = 4
OUT = pathlib.Path(__file__).resolve().parent.parent / "tests" / "golden" / "bnn_logits.json"


def compute_logits() -> np.ndarray:
    params = init_bnn_params(jax.random.PRNGKey(PARAM_SEED))
    images = jax.random.normal(
        jax.random.PRNGKey(IMAGE_SEED), (BATCH, 32, 32, 3)
    )
    logits = bnn_apply(
        pack_bnn_params(params), images,
        BNNConfig(mode=QuantMode.PACKED, engine="xla"),
    )
    return np.asarray(logits, np.float32)


def main():
    logits = compute_logits()
    fixture = {
        "description": (
            "PACKED (engine=xla) logits of the CIFAR BNN for "
            f"init_bnn_params(PRNGKey({PARAM_SEED})) on "
            f"normal(PRNGKey({IMAGE_SEED}), ({BATCH}, 32, 32, 3)). "
            "float32 hex — exact. Regenerate ONLY for intentional "
            "numeric changes: scripts/gen_golden_logits.py"
        ),
        "param_seed": PARAM_SEED,
        "image_seed": IMAGE_SEED,
        "shape": list(logits.shape),
        "generated_with_jax": jax.__version__,
        "logits_hex": [[float(v).hex() for v in row] for row in logits],
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(fixture, indent=2) + "\n")
    print(f"wrote {OUT}")
    print(logits)


if __name__ == "__main__":
    main()
